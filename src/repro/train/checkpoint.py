"""Sharded checkpoint / restore with async host writes.

Production posture (DESIGN.md §5): every host writes only its addressable
shards (scales to thousands of hosts — no gather to host 0), doubled-buffer
``step-N.tmp`` -> atomic rename commit, manifest with pytree structure +
sharding specs, and background-thread writes so the train loop isn't
blocked on disk. Restore is resharding-aware: arrays come back with the
target sharding of the (possibly different-size) restart mesh — elastic
restart after a node failure re-lowers on the surviving mesh and loads the
same checkpoint.

Format: one ``.npy``-like raw file per (leaf, shard) + ``manifest.json``.
No external deps (no orbax/tensorstore offline).
"""
from __future__ import annotations

import concurrent.futures
import json
import pathlib
import shutil
import threading

import jax
import numpy as np


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = []
    for path, _ in flat:
        parts = []
        for k in path:
            parts.append(str(getattr(k, "key", getattr(k, "idx", k))))
        keys.append("/".join(parts))
    return keys, [leaf for _, leaf in flat], treedef


class CheckpointManager:
    def __init__(self, directory: str, max_to_keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.max_to_keep = max_to_keep
        self._pool = concurrent.futures.ThreadPoolExecutor(max_workers=2)
        self._pending: list[concurrent.futures.Future] = []
        self._lock = threading.Lock()

    # -- save ---------------------------------------------------------------

    def save(self, step: int, state, *, blocking: bool = False):
        """Snapshot device shards, then write on a background thread."""
        keys, leaves, treedef = _leaf_paths(state)
        # Pull addressable shards to host NOW (cheap copy) so training can
        # mutate the donated buffers immediately after.
        host_shards = []
        for leaf in leaves:
            arr = jax.device_get(leaf)
            host_shards.append(np.asarray(arr))
        fut = self._pool.submit(self._write, step, keys, host_shards)
        with self._lock:
            self._pending.append(fut)
        if blocking:
            fut.result()
        return fut

    def _write(self, step: int, keys, host_shards):
        tmp = self.dir / f"step-{step:09d}.tmp"
        final = self.dir / f"step-{step:09d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "leaves": {}}
        for key, arr in zip(keys, host_shards):
            fname = key.replace("/", ".") + ".npy"
            np.save(tmp / fname, arr)
            manifest["leaves"][key] = {
                "file": fname,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
            }
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        tmp.rename(final)  # atomic commit
        self._gc()
        return final

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.max_to_keep]:
            shutil.rmtree(self.dir / f"step-{s:09d}", ignore_errors=True)

    def wait(self):
        with self._lock:
            pending, self._pending = self._pending, []
        for f in pending:
            f.result()

    # -- restore --------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step-*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            out.append(int(p.name.split("-")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target, shardings=None):
        """Load into the structure of `target` (pytree of arrays or
        ShapeDtypeStructs); reshard onto `shardings` when given — this is
        the elastic-restart path after re-meshing."""
        d = self.dir / f"step-{step:09d}"
        manifest = json.loads((d / "manifest.json").read_text())
        keys, leaves, treedef = _leaf_paths(target)
        out = []
        shard_list = None
        if shardings is not None:
            _, shard_list, _ = _leaf_paths(shardings)
        for i, (key, leaf) in enumerate(zip(keys, leaves)):
            meta = manifest["leaves"].get(key)
            if meta is None:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            arr = np.load(d / meta["file"])
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"shape mismatch for {key}: ckpt {arr.shape} vs target {leaf.shape}"
                )
            if shard_list is not None:
                out.append(jax.device_put(arr, shard_list[i]))
            else:
                out.append(jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, out)
