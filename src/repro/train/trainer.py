"""Fault-tolerant training loop.

Wraps a CellProgram-style step with: periodic async checkpointing, restart
from the latest commit (``resume()``), and a crash hook for tests to verify
exactly-once-per-step semantics across restarts. On a real cluster the
restart path re-lowers on the surviving mesh (elastic) and restores with
the new shardings — the same CheckpointManager.restore call.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterator

import jax

from repro.train.checkpoint import CheckpointManager


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    max_to_keep: int = 3


class Trainer:
    def __init__(self, step_fn: Callable, init_state_fn: Callable,
                 batches: Iterator, cfg: TrainerConfig,
                 state_shardings=None):
        self.step_fn = step_fn
        self.init_state_fn = init_state_fn
        self.batches = batches
        self.cfg = cfg
        self.ckpt = CheckpointManager(cfg.ckpt_dir, cfg.max_to_keep)
        self.state_shardings = state_shardings
        self.history: list[dict] = []

    def resume_or_init(self, key):
        state = self.init_state_fn(key)
        latest = self.ckpt.latest_step()
        if latest is None:
            return state, 0
        state = self.ckpt.restore(latest, state, self.state_shardings)
        return state, latest

    def run(self, key, *, crash_at: int | None = None):
        """Train to total_steps; ``crash_at`` simulates a node failure (for
        the fault-tolerance tests). Returns (state, history)."""
        state, start = self.resume_or_init(key)
        for step in range(start, self.cfg.total_steps):
            if crash_at is not None and step == crash_at:
                self.ckpt.wait()
                raise RuntimeError(f"injected crash at step {step}")
            batch = next(self.batches)
            t0 = time.perf_counter()
            state, metrics = self.step_fn(state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            if (step + 1) % self.cfg.log_every == 0 or step == start:
                self.history.append({"step": step + 1, "loss": loss,
                                     "step_time_s": dt})
            if (step + 1) % self.cfg.ckpt_every == 0:
                self.ckpt.save(step + 1, state)
        self.ckpt.save(self.cfg.total_steps, state, blocking=True)
        self.ckpt.wait()
        return state, self.history
