"""Optimizers (pytree-functional, no optax dependency).

- ``adamw``  : LM / GNN training.
- ``adagrad``: DLRM-style embedding training (row-wise variant keeps one
  accumulator scalar per embedding row — the production recsys choice,
  8x less optimizer memory on multi-GB tables).
- ``sgd``    : baseline.

Each factory returns (init_fn, update_fn):
    state = init_fn(params)
    params, state = update_fn(params, grads, state)
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable
    name: str = ""


def sgd(lr: float = 0.01, momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum:
            return {"mu": jax.tree.map(jnp.zeros_like, params)}
        return {}

    def update(params, grads, state):
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g, state["mu"], grads)
            params = jax.tree.map(lambda p, m: p - lr * m, params, mu)
            return params, {"mu": mu}
        return jax.tree.map(lambda p, g: p - lr * g, params, grads), state

    return Optimizer(init, update, f"sgd(lr={lr})")


def adamw(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {
            "m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(params, grads, state):
        step = state["step"] + 1
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m_, v_):
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        params = jax.tree.map(upd, params, m, v)
        return params, {"m": m, "v": v, "step": step}

    return Optimizer(init, update, f"adamw(lr={lr})")


def rowwise_adagrad(lr: float = 0.01, eps: float = 1e-8,
                    embedding_keys: tuple[str, ...] = ("table", "hot", "cold"),
                    ) -> Optimizer:
    """AdaGrad with row-wise accumulators for 2-D embedding tables (one
    scalar per row) and full accumulators elsewhere."""

    def _is_embedding(path) -> bool:
        return any(getattr(k, "key", None) in embedding_keys for k in path)

    def init(params):
        def acc(path, p):
            if _is_embedding(path) and p.ndim == 2:
                return jnp.zeros((p.shape[0], 1), jnp.float32)
            return jnp.zeros_like(p, jnp.float32)
        return {"acc": jax.tree_util.tree_map_with_path(acc, params)}

    def update(params, grads, state):
        def upd(path, p, g, a):
            g32 = g.astype(jnp.float32)
            if _is_embedding(path) and p.ndim == 2:
                a_new = a + jnp.mean(jnp.square(g32), axis=1, keepdims=True)
            else:
                a_new = a + jnp.square(g32)
            p_new = p.astype(jnp.float32) - lr * g32 / (jnp.sqrt(a_new) + eps)
            return p_new.astype(p.dtype), a_new

        flat = jax.tree_util.tree_map_with_path(
            lambda path, p, g, a: upd(path, p, g, a), params, grads, state["acc"]
        )
        params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
        acc = jax.tree.map(lambda t: t[1], flat,
                           is_leaf=lambda x: isinstance(x, tuple))
        return params, {"acc": acc}

    return Optimizer(init, update, f"rowwise_adagrad(lr={lr})")
