"""GraphSAGE (arXiv:1706.02216) — mean aggregator, 2 layers.

JAX has no sparse message-passing; aggregation is built from gather +
``jax.ops.segment_sum`` over an edge list (src -> dst), per the kernel
taxonomy §GNN. Three execution modes cover the assigned shapes:

- full   : full-graph training (cora / ogb_products scales) over an edge
           list [2, E]; distributed by sharding edges and psum-ing partial
           aggregations (repro.dist.gnn).
- mini   : layer-wise sampled mini-batch (reddit) with *fixed fanout* —
           dense [B, f1, f2] id blocks from the real neighbor sampler in
           repro.data.graph; aggregation is a masked mean over the fanout
           axis (no segment ops needed — static shapes by construction).
- batched: many small graphs (molecule) packed block-diagonally; per-graph
           readout via segment_sum over graph ids.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.common.init import xavier_init
from repro.dist import logical


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    d_feat: int
    d_hidden: int = 128
    n_layers: int = 2
    n_classes: int = 41
    aggregator: str = "mean"
    fanout: tuple[int, ...] = (25, 10)
    mode: str = "full"  # full | mini | batched
    readout: str = "node"  # node | graph
    dtype: Any = jnp.float32


def init(key, cfg: GNNConfig):
    keys = jax.random.split(key, cfg.n_layers * 2 + 1)
    layers = []
    d_in = cfg.d_feat
    for i in range(cfg.n_layers):
        layers.append(
            {
                "w_self": xavier_init(keys[2 * i], (d_in, cfg.d_hidden), dtype=cfg.dtype),
                "w_neigh": xavier_init(keys[2 * i + 1], (d_in, cfg.d_hidden), dtype=cfg.dtype),
                "b": jnp.zeros((cfg.d_hidden,), cfg.dtype),
            }
        )
        d_in = cfg.d_hidden
    return {
        "layers": layers,
        "cls": xavier_init(keys[-1], (cfg.d_hidden, cfg.n_classes), dtype=cfg.dtype),
    }


def _degree(dst, n_nodes, dtype):
    ones = jnp.ones_like(dst, dtype=dtype)
    deg = jax.ops.segment_sum(ones, dst, num_segments=n_nodes)
    return jnp.maximum(deg, 1.0)[:, None]


def aggregate_full(h, edges, n_nodes, aggregator="mean"):
    """Gather-scatter aggregation over an edge list. edges: [2, E]."""
    src, dst = edges[0], edges[1]
    msg = jnp.take(h, src, axis=0)  # [E, d]
    if aggregator == "max":
        agg = jax.ops.segment_max(msg, dst, num_segments=n_nodes)
        return jnp.where(jnp.isfinite(agg), agg, 0.0)
    agg = jax.ops.segment_sum(msg, dst, num_segments=n_nodes)
    if aggregator == "mean":
        agg = agg / _degree(dst, n_nodes, h.dtype)
    return agg


def _sage_combine(layer, h_self, h_agg, activate=True):
    out = h_self @ layer["w_self"] + h_agg @ layer["w_neigh"] + layer["b"]
    return jax.nn.relu(out) if activate else out


def apply_full(params, feats, edges, cfg: GNNConfig):
    """Full-graph forward: feats [N, d_feat], edges [2, E] -> logits [N, C]."""
    n_nodes = feats.shape[0]
    h = feats.astype(cfg.dtype)
    for i, layer in enumerate(params["layers"]):
        agg = aggregate_full(h, edges, n_nodes, cfg.aggregator)
        h = _sage_combine(layer, h, agg, activate=True)
        h = logical.constrain(h, ("nodes", None))
    return h @ params["cls"]


def apply_minibatch(params, hop_feats, hop_masks, cfg: GNNConfig):
    """Sampled mini-batch forward with fixed fanout.

    hop_feats: list of L+1 arrays — hop_feats[j] has shape
      [B, f1, ..., fj, d_feat] (features of the j-hop frontier).
    hop_masks: matching validity masks [B, f1, ..., fj] (True = real edge).
    Layer i aggregates hop j=i+1 into hop j, shrinking the pyramid until
    only the seeds [B, d_hidden] remain. Returns logits [B, C].
    """
    L = cfg.n_layers
    h = [f.astype(cfg.dtype) for f in hop_feats]
    for i, layer in enumerate(params["layers"]):
        nxt = []
        for j in range(L - i):
            m = hop_masks[j + 1][..., None].astype(h[0].dtype)
            if cfg.aggregator == "max":
                neg = jnp.asarray(-1e30, h[0].dtype)
                agg = jnp.where(m > 0, h[j + 1], neg).max(axis=-2)
                agg = jnp.where(jnp.isfinite(agg), agg, 0.0)
            else:
                s = (h[j + 1] * m).sum(axis=-2)
                if cfg.aggregator == "mean":
                    s = s / jnp.maximum(m.sum(axis=-2), 1.0)
                agg = s
            nxt.append(_sage_combine(layer, h[j], agg, activate=True))
        h = nxt
    return h[0] @ params["cls"]


def apply_batched(params, feats, edges, node_mask, graph_ids, n_graphs, cfg: GNNConfig):
    """Packed small graphs: feats [Nt, d], edges [2, Et] (block-diagonal),
    graph_ids [Nt] -> graph logits [G, C] via mean readout."""
    n_nodes = feats.shape[0]
    h = feats.astype(cfg.dtype)
    for layer in params["layers"]:
        agg = aggregate_full(h, edges, n_nodes, cfg.aggregator)
        h = _sage_combine(layer, h, agg, activate=True)
    h = h * node_mask[:, None].astype(h.dtype)
    summed = jax.ops.segment_sum(h, graph_ids, num_segments=n_graphs)
    counts = jax.ops.segment_sum(
        node_mask.astype(h.dtype), graph_ids, num_segments=n_graphs
    )
    pooled = summed / jnp.maximum(counts, 1.0)[:, None]
    return pooled @ params["cls"]


def softmax_ce(logits, labels, mask=None):
    """Cross-entropy with integer labels; mask selects supervised rows."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = logz - gold
    if mask is not None:
        m = mask.astype(jnp.float32)
        return (loss * m).sum() / jnp.maximum(m.sum(), 1.0)
    return loss.mean()


def input_specs(cfg: GNNConfig, shape_dims: dict):
    """ShapeDtypeStruct stand-ins per GNN shape cell."""
    d = shape_dims
    if cfg.mode == "full":
        n, e = d["n_nodes"], d["n_edges"]
        return {
            "feats": jax.ShapeDtypeStruct((n, cfg.d_feat), cfg.dtype),
            "edges": jax.ShapeDtypeStruct((2, e), jnp.int32),
            "labels": jax.ShapeDtypeStruct((n,), jnp.int32),
            "label_mask": jax.ShapeDtypeStruct((n,), jnp.bool_),
        }
    if cfg.mode == "mini":
        B = d["batch_nodes"]
        fan = d.get("fanout", cfg.fanout)
        specs = {}
        shape = (B,)
        for j in range(cfg.n_layers + 1):
            specs[f"hop{j}_feats"] = jax.ShapeDtypeStruct((*shape, cfg.d_feat), cfg.dtype)
            if j > 0:
                specs[f"hop{j}_mask"] = jax.ShapeDtypeStruct(shape, jnp.bool_)
            if j < cfg.n_layers:
                shape = (*shape, fan[j])
        specs["labels"] = jax.ShapeDtypeStruct((B,), jnp.int32)
        return specs
    if cfg.mode == "batched":
        G, n, e = d["batch"], d["n_nodes"], d["n_edges"]
        Nt, Et = G * n, G * e
        return {
            "feats": jax.ShapeDtypeStruct((Nt, cfg.d_feat), cfg.dtype),
            "edges": jax.ShapeDtypeStruct((2, Et), jnp.int32),
            "node_mask": jax.ShapeDtypeStruct((Nt,), jnp.bool_),
            "graph_ids": jax.ShapeDtypeStruct((Nt,), jnp.int32),
            "labels": jax.ShapeDtypeStruct((G,), jnp.int32),
        }
    raise ValueError(f"unknown mode {cfg.mode}")
