"""LM transformer family: dense (qwen2/llama3/deepseek-67b) and MoE
(qwen2-moe, olmoe) decoder-only models.

Structure is MaxText-style for compile efficiency at depth: per-layer params
are stacked on a leading L axis and the forward pass is a ``lax.scan`` over
layers (O(1) HLO size — deepseek-67b's 95 layers compile as one block), with
``jax.checkpoint`` remat inside the scan for training.

Sharding is annotated with *logical* axes (repro.dist.logical): "batch",
"seq", "embed", "heads", "kv_heads", "ffn", "vocab", "expert". The launcher
binds them to mesh axes; single-device tests run the same code un-annotated.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.init import normal_init
from repro.dist import logical
from repro.dist.moe import moe_apply
from repro.models.layers import (
    AttentionConfig,
    MoEConfig,
    apply_rmsnorm,
    apply_rope,
    apply_swiglu,
    attention_output,
    init_attention,
    init_rmsnorm,
    init_swiglu,
    qkv_projection,
    rope_angles,
)


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    moe: MoEConfig | None = None
    remat: bool = True
    # unroll the layer loop instead of lax.scan — used by the roofline
    # correction (XLA cost_analysis counts a while body once, regardless of
    # trip count; unrolled 1- vs 2-layer lowering recovers the true
    # per-layer cost). Production configs keep scan for O(1) HLO size.
    unroll_layers: bool = False
    # attention schedule: "naive" materializes [Tq, Tk] scores (baseline);
    # "chunked" is the flash-style online-softmax scan over KV chunks —
    # the XLA-level analogue of kernels/flash_attention (§Perf iteration).
    attn_impl: str = "naive"
    attn_chunk: int = 1024
    # sequence-shard the residual stream over the model axis between blocks
    # (Megatron-SP): converts the TP activation all-reduces into
    # reduce-scatter/all-gather pairs and stores activations 1/TP-sized.
    seq_shard: bool = False
    # KV-cache quantization (KIVI-style per-token-per-head int8): halves the
    # cache residency -> 2x decode batch per chip (§Perf iteration).
    kv_quant: str = "none"  # "none" | "int8"
    # one-token decode attention: "naive" single-block matmul; "flash"
    # routes through the split-KV Pallas kernel, and — under a binding with
    # a "kv_seq" rule (seq-sharded cache) — the cross-shard partial merge
    # in repro.dist.decode. The launcher flips this on for mesh decode
    # cells; it needs a static write position (decode_step from launch
    # passes a Python int).
    decode_impl: str = "naive"  # "naive" | "flash"
    dtype: Any = jnp.bfloat16

    @property
    def attn(self) -> AttentionConfig:
        return AttentionConfig(
            d_model=self.d_model,
            n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads,
            head_dim=self.head_dim,
            qkv_bias=self.qkv_bias,
            rope_theta=self.rope_theta,
        )

    def param_count(self) -> int:
        """Total parameters (for MODEL_FLOPS = 6·N·D accounting)."""
        return sum(
            int(np.prod(x.shape))
            for x in jax.tree.leaves(
                jax.eval_shape(lambda: init(jax.random.PRNGKey(0), self))
            )
        )

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        total = self.param_count()
        if self.moe is None:
            return total
        m = self.moe
        per_expert = 3 * self.d_model * m.d_ff
        inactive = (m.n_experts - m.top_k) * per_expert * self.n_layers
        return total - inactive


def _init_block(key, cfg: LMConfig):
    k_attn, k_ffn = jax.random.split(key)
    block = {
        "ln1": init_rmsnorm(cfg.d_model, cfg.dtype),
        "ln2": init_rmsnorm(cfg.d_model, cfg.dtype),
        "attn": init_attention(k_attn, cfg.attn, dtype=cfg.dtype),
    }
    if cfg.moe is not None:
        from repro.models.layers import init_moe

        block["ffn"] = init_moe(k_ffn, cfg.moe, dtype=cfg.dtype)
    else:
        block["ffn"] = init_swiglu(k_ffn, cfg.d_model, cfg.d_ff, dtype=cfg.dtype)
    return block


def init(key, cfg: LMConfig):
    k_emb, k_blocks, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_blocks, cfg.n_layers)
    blocks = jax.vmap(lambda k: _init_block(k, cfg))(layer_keys)
    params = {
        "embed": normal_init(k_emb, (cfg.vocab, cfg.d_model), dtype=cfg.dtype),
        "blocks": blocks,
        "final_norm": init_rmsnorm(cfg.d_model, cfg.dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = normal_init(k_head, (cfg.d_model, cfg.vocab), dtype=cfg.dtype)
    return params


def _block_apply(params_l, x, cos, sin, cfg: LMConfig, cache_l=None, pos=None):
    """One transformer block. cache_l: {"k","v"} [B, S, KVH, hd] or None.

    Returns (x, new_cache_l, aux_loss).
    """
    B, T, _ = x.shape
    h = apply_rmsnorm(params_l["ln1"], x)
    q, k, v = qkv_projection(params_l["attn"], h, cfg.attn)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    q = logical.constrain(q, ("batch", "seq", "heads", None))

    # chunked (flash-style) attention wins for Tq > 1 (train/prefill) but
    # loses badly for seq-sharded decode (measured: the per-chunk scan
    # forces GSPMD to gather every chunk) -> single-block path for Tq == 1.
    use_chunked = cfg.attn_impl == "chunked" and T > 1
    if use_chunked:
        attn_fn = functools.partial(_attention_chunked,
                                    unroll=cfg.unroll_layers)
    else:
        attn_fn = _attention
    new_cache_l = None
    if cache_l is not None:
        if cfg.kv_quant == "int8":
            kq, ks = _quantize_kv(k)
            vq, vs = _quantize_kv(v)
            new_cache_l = {
                "k": jax.lax.dynamic_update_slice(cache_l["k"], kq, (0, pos, 0, 0)),
                "v": jax.lax.dynamic_update_slice(cache_l["v"], vq, (0, pos, 0, 0)),
                "ks": jax.lax.dynamic_update_slice(cache_l["ks"], ks, (0, pos, 0, 0)),
                "vs": jax.lax.dynamic_update_slice(cache_l["vs"], vs, (0, pos, 0, 0)),
            }
            kc = new_cache_l["k"].astype(x.dtype) * new_cache_l["ks"].astype(x.dtype)
            vc = new_cache_l["v"].astype(x.dtype) * new_cache_l["vs"].astype(x.dtype)
        else:
            kc = jax.lax.dynamic_update_slice(cache_l["k"], k, (0, pos, 0, 0))
            vc = jax.lax.dynamic_update_slice(cache_l["v"], v, (0, pos, 0, 0))
            new_cache_l = {"k": kc, "v": vc}
        if cfg.decode_impl == "flash" and T == 1 and isinstance(pos, int):
            from repro.dist.decode import decode_attention

            # decode attends kv positions j <= pos, i.e. kv_len = pos + 1
            attn = decode_attention(q, kc, vc, kv_len=pos + 1)
        else:
            attn = attn_fn(q, kc, vc, q_offset=pos, chunk=cfg.attn_chunk)
    else:
        attn = attn_fn(q, k, v, q_offset=0, chunk=cfg.attn_chunk)
    x = x + logical.constrain(
        attention_output(params_l["attn"], attn), ("batch", "residual_seq", "embed"))

    h2 = apply_rmsnorm(params_l["ln2"], x)
    if cfg.moe is not None:
        flat = h2.reshape(B * T, cfg.d_model)
        out, aux = moe_apply(params_l["ffn"], flat, cfg.moe)
        ffn_out = out.reshape(B, T, cfg.d_model)
    else:
        ffn_out = apply_swiglu(params_l["ffn"], h2)
        aux = jnp.zeros((), jnp.float32)
    x = x + logical.constrain(ffn_out, ("batch", "residual_seq", "embed"))
    return x, new_cache_l, aux


def _attention(q, k, v, *, q_offset, chunk=None):
    """Causal GQA attention with a query-position offset (for KV caches).

    q: [B, Tq, H, hd]; k/v: [B, Tk, KVH, hd]. Query i's global position is
    q_offset + i; it attends to kv positions j <= q_offset + i.
    """
    B, Tq, H, hd = q.shape
    Tk, KVH = k.shape[1], k.shape[2]
    group = H // KVH
    qg = q.reshape(B, Tq, KVH, group, hd)
    scale = 1.0 / np.sqrt(hd)
    logits = jnp.einsum("btkgh,bskh->bkgts", qg, k) * scale
    jpos = jnp.arange(Tk)[None, :]
    ipos = jnp.arange(Tq)[:, None] + q_offset
    mask = jpos <= ipos
    logits = jnp.where(mask[None, None, None], logits, jnp.asarray(-1e30, logits.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgts,bskh->btkgh", probs, v)
    return out.reshape(B, Tq, H, hd)


NEG_INF = -1e30


def _attention_chunked(q, k, v, *, q_offset, chunk=1024, unroll=False):
    """Flash-style online-softmax attention as a lax.scan over KV chunks.

    Never materializes the [Tq, Tk] score matrix — per-step intermediates
    are [B, KVH, g, Tq, chunk] — which is what moves the memory roofline
    term for the long-sequence cells; the Pallas kernel
    (kernels/flash_attention) is the on-chip realization of the same
    schedule.
    """
    B, Tq, H, hd = q.shape
    Tk, KVH = k.shape[1], k.shape[2]
    chunk = min(chunk, Tk)
    if Tk % chunk:
        raise ValueError(f"Tk {Tk} % chunk {chunk} != 0")
    n_chunks = Tk // chunk
    group = H // KVH
    qg = q.reshape(B, Tq, KVH, group, hd)
    scale = 1.0 / np.sqrt(hd)

    kc = k.reshape(B, n_chunks, chunk, KVH, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, KVH, hd).transpose(1, 0, 2, 3, 4)

    qpos = (q_offset + jnp.arange(Tq))[:, None]  # [Tq, 1]

    def body(carry, inp):
        m, l, acc = carry
        k_i, v_i, idx = inp
        s = jnp.einsum("btkgh,bskh->bkgts", qg, k_i).astype(jnp.float32) * scale
        kpos = idx * chunk + jnp.arange(chunk)[None, :]
        mask = kpos <= qpos                       # [Tq, chunk]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bkgts,bskh->bkgth", p.astype(q.dtype), v_i)
        acc = acc * alpha[..., None].astype(acc.dtype) + pv
        return (m_new, l, acc), None

    m0 = jnp.full((B, KVH, group, Tq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KVH, group, Tq), jnp.float32)
    a0 = jnp.zeros((B, KVH, group, Tq, hd), q.dtype)
    if unroll:  # roofline-correction lowering: scan bodies count once in
        # cost_analysis, so the correction pass unrolls the chunk loop too
        carry = (m0, l0, a0)
        for i in range(n_chunks):
            carry, _ = body(carry, (kc[i], vc[i], jnp.asarray(i)))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, a0), (kc, vc, jnp.arange(n_chunks))
        )
    out = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
    # [B, KVH, g, Tq, hd] -> [B, Tq, H, hd]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Tq, H, hd)


def _embed_tokens(params, tokens, cfg: LMConfig):
    if cfg.tie_embeddings:
        # tied table is VOCAB-sharded (so the logits matmul needs no psum);
        # the token gather goes through the masked-local-gather + psum path.
        from repro.dist.sharded_embedding import sharded_row_gather

        x = sharded_row_gather(params["embed"], tokens, None)
    else:
        x = jnp.take(params["embed"], tokens, axis=0)
    return logical.constrain(x, ("batch", "seq", "embed"))


def _lm_logits(params, x, cfg: LMConfig):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    return logical.constrain(logits, ("batch", "seq", "vocab"))


def forward(params, tokens, cfg: LMConfig, *, cache=None, pos=None):
    """tokens [B, T] -> (logits [B, T, V], new_cache, aux_loss).

    cache: stacked {"k","v"} [L, B, S, KVH, hd] + scalar ``pos`` write
    offset, or None for plain training forward.
    """
    B, T = tokens.shape
    x = _embed_tokens(params, tokens, cfg)
    pos0 = 0 if pos is None else pos
    positions = pos0 + jnp.arange(T)
    cos, sin = rope_angles(positions[None, :], cfg.head_dim, cfg.rope_theta)
    cos, sin = jnp.broadcast_to(cos, (B, T, cfg.head_dim // 2)), jnp.broadcast_to(
        sin, (B, T, cfg.head_dim // 2)
    )

    if cache is None:

        def body(carry, params_l):
            h, aux = carry
            h, _, aux_l = _block_apply(params_l, h, cos, sin, cfg)
            return (h, aux + aux_l), None

        step = jax.checkpoint(body) if cfg.remat else body
        if cfg.unroll_layers:
            carry = (x, jnp.zeros((), jnp.float32))
            for i in range(cfg.n_layers):
                carry, _ = step(carry, jax.tree.map(lambda t: t[i], params["blocks"]))
            x, aux = carry
        else:
            (x, aux), _ = jax.lax.scan(step, (x, jnp.zeros((), jnp.float32)),
                                       params["blocks"])
        new_cache = None
    else:

        def body_c(carry, layer_in):
            h, aux = carry
            params_l, cache_l = layer_in
            h, new_cache_l, aux_l = _block_apply(
                params_l, h, cos, sin, cfg, cache_l=cache_l, pos=pos0
            )
            return (h, aux + aux_l), new_cache_l

        if cfg.unroll_layers:
            carry = (x, jnp.zeros((), jnp.float32))
            caches = []
            for i in range(cfg.n_layers):
                layer_in = jax.tree.map(lambda t: t[i], (params["blocks"], cache))
                carry, c_l = body_c(carry, layer_in)
                caches.append(c_l)
            x, aux = carry
            new_cache = jax.tree.map(lambda *ts: jnp.stack(ts), *caches)
        else:
            (x, aux), new_cache = jax.lax.scan(
                body_c, (x, jnp.zeros((), jnp.float32)), (params["blocks"], cache)
            )

    x = apply_rmsnorm(params["final_norm"], x)
    logits = _lm_logits(params, x, cfg)
    return logits, new_cache, aux


def lm_loss(params, batch, cfg: LMConfig):
    """Next-token cross-entropy. batch: {"tokens": [B, T]} (shift internally).

    Loss over positions 0..T-2 predicting 1..T-1, mean per token; MoE aux
    loss added with weight 0.01.
    """
    from repro.dist.loss import cast_grad, ce_loss

    tokens = batch["tokens"]
    logits, _, aux = forward(params, tokens, cfg)
    ce = ce_loss(cast_grad(logits[:, :-1]), tokens[:, 1:])
    return ce + 0.01 * aux


def _quantize_kv(x):
    """Per-(token, head) symmetric int8: x [B, T, KVH, hd]."""
    x32 = x.astype(jnp.float32)
    s = jnp.maximum(jnp.max(jnp.abs(x32), axis=-1, keepdims=True) / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x32 / s), -127, 127).astype(jnp.int8)
    return q, s.astype(jnp.float32)


def init_kv_cache(cfg: LMConfig, batch: int, seq: int, dtype=None):
    dtype = dtype or cfg.dtype
    shape = (cfg.n_layers, batch, seq, cfg.n_kv_heads, cfg.head_dim)
    if cfg.kv_quant == "int8":
        sshape = (*shape[:-1], 1)
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "ks": jnp.ones(sshape, jnp.float32),
            "vs": jnp.ones(sshape, jnp.float32),
        }
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


def kv_cache_specs(cfg: LMConfig, batch: int, seq: int, dtype=None):
    dtype = dtype or cfg.dtype
    shape = (cfg.n_layers, batch, seq, cfg.n_kv_heads, cfg.head_dim)
    if cfg.kv_quant == "int8":
        sshape = (*shape[:-1], 1)
        return {
            "k": jax.ShapeDtypeStruct(shape, jnp.int8),
            "v": jax.ShapeDtypeStruct(shape, jnp.int8),
            "ks": jax.ShapeDtypeStruct(sshape, jnp.float32),
            "vs": jax.ShapeDtypeStruct(sshape, jnp.float32),
        }
    return {
        "k": jax.ShapeDtypeStruct(shape, dtype),
        "v": jax.ShapeDtypeStruct(shape, dtype),
    }


def prefill(params, tokens, cache, cfg: LMConfig):
    """Fill the cache from position 0; returns (last-token logits, cache)."""
    logits, new_cache, _ = forward(params, tokens, cfg, cache=cache, pos=0)
    return logits[:, -1], new_cache


def decode_step(params, token, cache, pos, cfg: LMConfig):
    """One decode step. token [B, 1]; pos: scalar write position."""
    logits, new_cache, _ = forward(params, token, cfg, cache=cache, pos=pos)
    return logits[:, -1], new_cache


def input_specs(cfg: LMConfig, batch: int, seq: int):
    return {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
