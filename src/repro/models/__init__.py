"""Model zoo: recsys (DLRM/WnD/DIN/DIEN/MIND), LM transformers, GraphSAGE."""
