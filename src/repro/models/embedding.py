"""Embedding substrate: EmbeddingBag / SparseLengthsSum in pure JAX.

JAX has no native ``nn.EmbeddingBag`` and no CSR sparse — the multi-hot
gather+pool that dominates recommendation inference (the paper's SparseNet)
is built here from ``jnp.take`` + masked reduction / ``jax.ops.segment_sum``.
This module is single-device semantics; the distributed (model-axis sharded)
lookup lives in ``repro.dist.sharded_embedding`` and the fused TPU kernel in
``repro.kernels.embedding_bag``.

Layout: all feature tables are concatenated row-wise into ONE combined
``[total_rows, dim]`` array (FBGEMM table-batched-embedding style); feature
``f``'s ids are shifted by ``row_offsets[f]``. This gives a single gather for
the whole SparseNet and a single row-sharded array for the model axis.

Hot/cold split (paper §IV-B, locality-aware partition): ids are assumed
frequency-ranked per table (the synthetic data generator produces them that
way), so "row < hot_rows[f]" identifies the hot set. ``split_hot_cold``
re-lays the combined table into a small hot replica + a cold remainder, and
``embedding_bag_hot_cold`` computes hot and cold partial sums separately —
the Psum dataflow of the paper's Figure 10(d).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.init import embedding_init


@dataclasses.dataclass(frozen=True)
class EmbeddingConfig:
    """Combined multi-table embedding-bag configuration.

    vocab_sizes: rows per sparse feature table.
    dim: shared embedding dimension.
    pooling: max multi-hot pooling factor per feature (ids padded with -1).
    combine: "sum" (SparseLengthsSum) or "mean".
    qr_features: features using the quotient-remainder trick (huge vocabs);
        their storage is ``ceil(V/qr_buckets) + qr_buckets`` rows instead of V.
    """

    vocab_sizes: tuple[int, ...]
    dim: int
    pooling: tuple[int, ...]
    combine: str = "sum"
    qr_features: tuple[int, ...] = ()
    qr_buckets: int = 65536
    dtype: Any = jnp.float32
    # combined table rows are padded to a multiple of this so the row-wise
    # model-axis shard is always even (512 covers every production mesh).
    row_pad: int = 512

    def __post_init__(self):
        if len(self.vocab_sizes) != len(self.pooling):
            raise ValueError("vocab_sizes and pooling must have equal length")
        if self.combine not in ("sum", "mean"):
            raise ValueError(f"unknown combine mode {self.combine!r}")

    @property
    def num_features(self) -> int:
        return len(self.vocab_sizes)

    def storage_rows(self, f: int) -> int:
        """Physical rows stored for feature f (QR-compressed if enabled)."""
        v = self.vocab_sizes[f]
        if f in self.qr_features:
            q = -(-v // self.qr_buckets)  # ceil
            return q + self.qr_buckets
        return v

    @property
    def row_offsets(self) -> np.ndarray:
        """Start row of each feature in the combined table; len = F+1."""
        sizes = [self.storage_rows(f) for f in range(self.num_features)]
        return np.concatenate([[0], np.cumsum(sizes)]).astype(np.int64)

    @property
    def total_rows(self) -> int:
        raw = int(self.row_offsets[-1])
        return -(-raw // self.row_pad) * self.row_pad

    @property
    def max_pooling(self) -> int:
        return max(self.pooling)

    def bytes(self, dtype_bytes: int = 4) -> int:
        return self.total_rows * self.dim * dtype_bytes


def init_embedding(key, cfg: EmbeddingConfig):
    """One combined [total_rows, dim] table, DLRM uniform init per table."""
    # Init the whole combined table in one draw with a per-table scale:
    # equivalent in distribution to per-table U(-1/sqrt(V), 1/sqrt(V)).
    table = jax.random.uniform(
        key, (cfg.total_rows, cfg.dim), minval=-1.0, maxval=1.0, dtype=jnp.float32
    )
    offsets = cfg.row_offsets
    scales = np.ones((cfg.total_rows, 1), np.float32)
    for f in range(cfg.num_features):
        v = cfg.vocab_sizes[f]
        scales[offsets[f] : offsets[f + 1]] = 1.0 / np.sqrt(v)
    return {"table": (table * jnp.asarray(scales)).astype(cfg.dtype)}


def _feature_row_index(cfg: EmbeddingConfig, ids: jax.Array) -> jax.Array:
    """Map per-feature logical ids [B, F, P] to combined physical row ids.

    Padding ids (< 0) map to row 0 (they are masked out of the pool anyway).
    For QR features each logical id expands *virtually*: we fold quotient and
    remainder into two gathers handled by ``embedding_bag`` directly, so here
    plain features only; QR handled in the caller.
    """
    offsets = jnp.asarray(cfg.row_offsets[:-1], jnp.int32)  # [F]
    safe = jnp.maximum(ids, 0)
    return safe + offsets[None, :, None]


def embedding_bag(params, ids: jax.Array, cfg: EmbeddingConfig) -> jax.Array:
    """Multi-hot gather + pool. ids: [B, F, Pmax] int32, -1-padded.

    Returns pooled embeddings [B, F, dim]. Under a mesh context the lookup
    routes through the model-axis-sharded Psum dataflow
    (repro.dist.sharded_embedding); single-device semantics otherwise.
    """
    from repro.dist import logical

    if logical.model_axis_name() is not None:
        from repro.dist.sharded_embedding import embedding_bag_sharded

        return embedding_bag_sharded(params, ids, cfg)
    return embedding_bag_local(params, ids, cfg)


def embedding_bag_local(params, ids: jax.Array, cfg: EmbeddingConfig) -> jax.Array:
    """Single-shard EmbeddingBag (jnp.take + masked pool)."""
    table = params["table"]
    B, F, P = ids.shape
    if F != cfg.num_features:
        raise ValueError(f"expected {cfg.num_features} features, got {F}")
    mask = (ids >= 0).astype(table.dtype)[..., None]  # [B, F, P, 1]

    if not cfg.qr_features:
        rows = jnp.take(
            table, _feature_row_index(cfg, ids).reshape(-1), axis=0
        ).reshape(B, F, P, cfg.dim)
    else:
        rows = _gather_with_qr(table, ids, cfg)

    pooled = (rows * mask).sum(axis=2)  # [B, F, dim]
    if cfg.combine == "mean":
        counts = jnp.maximum(mask.sum(axis=2), 1.0)
        pooled = pooled / counts
    return pooled


def _gather_with_qr(table, ids, cfg: EmbeddingConfig):
    """Gather rows where some features use quotient-remainder compression.

    QR feature f of vocab V stores ``q = ceil(V/Q)`` quotient rows followed by
    ``Q`` remainder rows; emb(id) = quot[id // Q] * rem[id % Q] (Hadamard,
    per the QR-embedding paper's best-performing combiner).
    """
    B, F, P = ids.shape
    offsets = cfg.row_offsets
    safe = jnp.maximum(ids, 0)
    per_feature = []
    for f in range(cfg.num_features):
        fid = safe[:, f, :]  # [B, P]
        base = int(offsets[f])
        if f in cfg.qr_features:
            q_rows = -(-cfg.vocab_sizes[f] // cfg.qr_buckets)
            quot = jnp.take(table, base + fid // cfg.qr_buckets, axis=0)
            rem = jnp.take(table, base + q_rows + fid % cfg.qr_buckets, axis=0)
            per_feature.append(quot * rem)
        else:
            per_feature.append(jnp.take(table, base + fid, axis=0))
    return jnp.stack(per_feature, axis=1)  # [B, F, P, dim]


def embedding_bag_ragged(
    table: jax.Array,
    ids: jax.Array,
    segment_ids: jax.Array,
    num_segments: int,
    combine: str = "sum",
) -> jax.Array:
    """Ragged EmbeddingBag: flat ids + segment ids -> [num_segments, dim].

    This is the ``jnp.take`` + ``jax.ops.segment_sum`` form used where bags
    are genuinely variable-length (GNN aggregation, ragged serving path).
    """
    rows = jnp.take(table, ids, axis=0)
    out = jax.ops.segment_sum(rows, segment_ids, num_segments=num_segments)
    if combine == "mean":
        ones = jnp.ones((ids.shape[0], 1), dtype=rows.dtype)
        counts = jax.ops.segment_sum(ones, segment_ids, num_segments=num_segments)
        out = out / jnp.maximum(counts, 1.0)
    return out


# ---------------------------------------------------------------------------
# Hot/cold locality-aware partition (paper §IV-B, Figure 10)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HotColdLayout:
    """Physical layout after locality-aware partition.

    hot_rows[f]: number of hottest rows of feature f replicated in the hot
    table (``G_s.hot``); the remainder stays in the sharded cold table
    (``G_s``). Row offsets are recomputed for both tables.
    """

    cfg: EmbeddingConfig
    hot_rows: tuple[int, ...]

    @property
    def hot_offsets(self) -> np.ndarray:
        return np.concatenate([[0], np.cumsum(self.hot_rows)]).astype(np.int64)

    @property
    def cold_rows(self) -> tuple[int, ...]:
        return tuple(
            self.cfg.storage_rows(f) - self.hot_rows[f]
            for f in range(self.cfg.num_features)
        )

    @property
    def cold_offsets(self) -> np.ndarray:
        return np.concatenate([[0], np.cumsum(self.cold_rows)]).astype(np.int64)

    @property
    def total_hot(self) -> int:
        return int(self.hot_offsets[-1])

    @property
    def total_cold(self) -> int:
        return int(self.cold_offsets[-1])


def make_hot_cold_layout(
    cfg: EmbeddingConfig, capacity_rows: int, access_freq: Sequence[np.ndarray] | None = None
) -> HotColdLayout:
    """Size the hot set under a row-capacity budget (memory capacity /
    co-location degree, per the paper).

    With frequency-ranked ids, the optimal hot set under a shared budget fills
    tables proportionally to their access mass; ``access_freq`` (per-feature
    access counts, optional) weights the split, else pooling factors are used
    as the access-mass proxy (a table looked up P times per query is P times
    hotter).
    """
    F = cfg.num_features
    if access_freq is not None:
        mass = np.array([float(np.sum(a)) for a in access_freq], np.float64)
    else:
        mass = np.array(cfg.pooling, np.float64)
    mass = mass / mass.sum()
    hot = [
        int(min(cfg.storage_rows(f), np.floor(mass[f] * capacity_rows)))
        for f in range(F)
    ]
    return HotColdLayout(cfg=cfg, hot_rows=tuple(hot))


def split_hot_cold(params, layout: HotColdLayout):
    """Re-lay the combined table into {hot, cold} per the layout."""
    cfg = layout.cfg
    table = params["table"]
    hots, colds = [], []
    off = cfg.row_offsets
    for f in range(cfg.num_features):
        t = table[int(off[f]) : int(off[f + 1])]
        hots.append(t[: layout.hot_rows[f]])
        colds.append(t[layout.hot_rows[f] :])
    return {
        "hot": jnp.concatenate(hots, axis=0) if layout.total_hot else jnp.zeros((0, cfg.dim), table.dtype),
        "cold": jnp.concatenate(colds, axis=0),
    }


def embedding_bag_hot_cold(
    split_params, ids: jax.Array, layout: HotColdLayout
) -> tuple[jax.Array, jax.Array]:
    """Pooled lookup returning separate (hot_psum, cold_psum), each [B, F, D].

    The caller adds them; keeping them separate mirrors the paper's pipeline
    where the hot partial sum is produced on the accelerator and the cold
    partial sum (Psum) arrives from the host/sharded side.
    """
    cfg = layout.cfg
    B, F, P = ids.shape
    hot_rows = jnp.asarray(layout.hot_rows, jnp.int32)[None, :, None]
    hot_off = jnp.asarray(layout.hot_offsets[:-1], jnp.int32)[None, :, None]
    cold_off = jnp.asarray(layout.cold_offsets[:-1], jnp.int32)[None, :, None]

    valid = ids >= 0
    safe = jnp.maximum(ids, 0)
    is_hot = valid & (safe < hot_rows)
    is_cold = valid & ~(safe < hot_rows)

    # masked slots index row 0 of the right table; clip because fully-hot
    # (or fully-cold) features leave the other table's offset out of range
    # (jnp.take's default OOB mode is 'fill' = NaN).
    n_hot = max(layout.total_hot, 1)
    n_cold = max(layout.total_cold, 1)
    hot_idx = jnp.clip(jnp.where(is_hot, safe, 0) + hot_off, 0, n_hot - 1)
    cold_idx = jnp.clip(jnp.where(is_cold, safe - hot_rows, 0) + cold_off, 0,
                        n_cold - 1)

    dim = cfg.dim
    if layout.total_hot:
        hot_rows_g = jnp.take(split_params["hot"], hot_idx.reshape(-1), axis=0)
        hot_psum = (
            hot_rows_g.reshape(B, F, P, dim)
            * is_hot[..., None].astype(hot_rows_g.dtype)
        ).sum(axis=2)
    else:
        hot_psum = jnp.zeros((B, F, dim), split_params["cold"].dtype)

    cold_rows_g = jnp.take(split_params["cold"], cold_idx.reshape(-1), axis=0)
    cold_psum = (
        cold_rows_g.reshape(B, F, P, dim)
        * is_cold[..., None].astype(cold_rows_g.dtype)
    ).sum(axis=2)
    return hot_psum, cold_psum
