"""DLRM family (Facebook, arXiv:1906.00091): RMC1 / RMC2 / RMC3 / dlrm-rm2.

Dense features -> Bottom-MLP; sparse features -> EmbeddingBag (SparseNet);
pairwise dot-product interaction; Top-MLP -> CTR logit.

The SparseNet / DenseNet decomposition used by the paper's HW-aware model
partition is explicit here: ``apply_sparse`` is exactly `G_s` and
``apply_dense_given_pooled`` is `G_d`, so the S-D pipeline scheduler can
launch them as separate stages with the pooled [B, F, D] tensor as the
intermediate-queue payload.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.init import he_init
from repro.models import embedding as emb_lib
from repro.models.layers import apply_mlp, init_mlp
from repro.models.recsys_base import RecsysConfig


def init(key, cfg: RecsysConfig):
    k_emb, k_bot, k_top = jax.random.split(key, 3)
    d = cfg.embed_dim
    params = {"embedding": emb_lib.init_embedding(k_emb, cfg.embedding)}
    if cfg.n_dense:
        params["bottom_mlp"] = init_mlp(
            k_bot, (cfg.n_dense, *cfg.bottom_mlp), dtype=cfg.dtype
        )
        if cfg.bottom_mlp[-1] != d:
            raise ValueError("bottom MLP must project dense features to embed_dim")
    n_vec = cfg.embedding.num_features + (1 if cfg.n_dense else 0)
    n_inter = n_vec * (n_vec - 1) // 2
    top_in = n_inter + (d if cfg.n_dense else 0)
    params["top_mlp"] = init_mlp(k_top, (top_in, *cfg.top_mlp, 1), dtype=cfg.dtype)
    return params


def apply_sparse(params, batch, cfg: RecsysConfig) -> jax.Array:
    """G_s: the SparseNet — multi-hot EmbeddingBag -> pooled [B, F, D]."""
    return emb_lib.embedding_bag(params["embedding"], batch["sparse_ids"], cfg.embedding)


def dot_interaction(vectors: jax.Array) -> jax.Array:
    """Pairwise dots among n feature vectors: [B, n, D] -> [B, n(n-1)/2]."""
    B, n, _ = vectors.shape
    z = jnp.einsum("bnd,bmd->bnm", vectors, vectors)
    iu, ju = jnp.triu_indices(n, k=1)
    return z[:, iu, ju]


def apply_dense_given_pooled(params, batch, pooled, cfg: RecsysConfig) -> jax.Array:
    """G_d: DenseNet given pooled sparse embeddings [B, F, D] -> logit [B]."""
    feats = [pooled]
    if cfg.n_dense:
        dense_v = apply_mlp(params["bottom_mlp"], batch["dense"].astype(cfg.dtype),
                            final_activation="relu")
        feats.insert(0, dense_v[:, None, :])
    vectors = jnp.concatenate(feats, axis=1)  # [B, n_vec, D]
    inter = dot_interaction(vectors)
    top_in = jnp.concatenate([dense_v, inter], axis=-1) if cfg.n_dense else inter
    return apply_mlp(params["top_mlp"], top_in)[:, 0]


def apply(params, batch, cfg: RecsysConfig) -> jax.Array:
    pooled = apply_sparse(params, batch, cfg)
    return apply_dense_given_pooled(params, batch, pooled, cfg)
