"""Wide & Deep (arXiv:1606.07792) and MT-WnD (multi-task, arXiv RecSys'19).

Wide: generalized linear part over sparse features (dim-1 embedding bags =
per-id scalar weights) + dense features. Deep: concat embeddings + dense
-> MLP. MT-WnD (cfg.n_tasks > 1): N task towers, each its own predict MLP,
matching the paper's "N×(1024-512-256)" Predict-FC column.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import embedding as emb_lib
from repro.models.embedding import EmbeddingConfig
from repro.models.layers import apply_mlp, init_mlp
from repro.models.recsys_base import RecsysConfig


def _wide_cfg(cfg: RecsysConfig) -> EmbeddingConfig:
    """Dim-1 clone of the embedding config for the wide (linear) part."""
    return dataclasses.replace(cfg.embedding, dim=1)


def init(key, cfg: RecsysConfig):
    k_emb, k_wide, k_deep, k_tower = jax.random.split(key, 4)
    emb = cfg.embedding
    params = {
        "embedding": emb_lib.init_embedding(k_emb, emb),
        "wide": emb_lib.init_embedding(k_wide, _wide_cfg(cfg)),
    }
    deep_in = emb.num_features * emb.dim + cfg.n_dense
    if cfg.n_dense:
        params["wide_dense"] = jnp.zeros((cfg.n_dense,), cfg.dtype)
    params["deep_mlp"] = init_mlp(k_deep, (deep_in, *cfg.top_mlp), dtype=cfg.dtype)
    tower_keys = jax.random.split(k_tower, cfg.n_tasks)
    params["towers"] = [
        init_mlp(tk, (cfg.top_mlp[-1], 1), dtype=cfg.dtype) for tk in tower_keys
    ]
    return params


def apply_sparse(params, batch, cfg: RecsysConfig):
    """G_s: deep embeddings [B, F, D] and wide scalar sums [B, F, 1]."""
    deep = emb_lib.embedding_bag(params["embedding"], batch["sparse_ids"], cfg.embedding)
    wide = emb_lib.embedding_bag(params["wide"], batch["sparse_ids"], _wide_cfg(cfg))
    return deep, wide


def apply_dense_given_pooled(params, batch, pooled, cfg: RecsysConfig) -> jax.Array:
    deep_emb, wide_emb = pooled
    B = deep_emb.shape[0]
    deep_in = deep_emb.reshape(B, -1)
    wide_logit = wide_emb.sum(axis=(1, 2))
    if cfg.n_dense:
        dense = batch["dense"].astype(cfg.dtype)
        deep_in = jnp.concatenate([deep_in, dense], axis=-1)
        wide_logit = wide_logit + dense @ params["wide_dense"]
    hidden = apply_mlp(params["deep_mlp"], deep_in, final_activation="relu")
    logits = jnp.stack(
        [apply_mlp(t, hidden)[:, 0] for t in params["towers"]], axis=-1
    )  # [B, n_tasks]
    logits = logits + wide_logit[:, None]
    return logits[:, 0] if cfg.n_tasks == 1 else logits


def apply(params, batch, cfg: RecsysConfig) -> jax.Array:
    pooled = apply_sparse(params, batch, cfg)
    return apply_dense_given_pooled(params, batch, pooled, cfg)
