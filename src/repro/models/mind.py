"""MIND — Multi-Interest Network with Dynamic routing (arXiv:1904.08030).

History item embeddings are routed into K interest capsules via B2I dynamic
routing (behaviour-to-interest); serving scores a candidate item against the
max-activated interest (label-aware attention with pow -> hard max at
serving, per the paper). The retrieval_cand shape scores one user's K
interests against ~1e6 candidate items with a single [K, D] @ [D, N] matmul —
batched-dot, never a loop.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.init import normal_init
from repro.models import embedding as emb_lib
from repro.models.layers import apply_mlp, init_mlp
from repro.models.recsys_base import RecsysConfig


def _item_lookup(params, ids, cfg: RecsysConfig):
    from repro.dist.sharded_embedding import sharded_row_gather

    base = int(cfg.embedding.row_offsets[0])
    return sharded_row_gather(
        params["embedding"]["table"], base + jnp.maximum(ids, 0), None)


def init(key, cfg: RecsysConfig):
    k_emb, k_s, k_mlp = jax.random.split(key, 3)
    d = cfg.embed_dim
    return {
        "embedding": emb_lib.init_embedding(k_emb, cfg.embedding),
        # shared bilinear routing map S (B2I routing uses one shared S)
        "S": normal_init(k_s, (d, d), stddev=0.05, dtype=cfg.dtype),
        # per-interest projection head (paper: H-layer FC after capsules)
        "head": init_mlp(k_mlp, (d, 2 * d, d), dtype=cfg.dtype),
    }


def squash(x, axis=-1, eps=1e-9):
    n2 = jnp.sum(jnp.square(x), axis=axis, keepdims=True)
    return (n2 / (1.0 + n2)) * x / jnp.sqrt(n2 + eps)


def interest_capsules(params, history_ids, cfg: RecsysConfig) -> jax.Array:
    """[B, T] history -> [B, K, D] interest capsules via dynamic routing."""
    mask = history_ids >= 0                              # [B, T]
    e = _item_lookup(params, history_ids, cfg)           # [B, T, D]
    e = e * mask[..., None].astype(e.dtype)
    u = e @ params["S"]                                  # behaviour -> routing space
    B, T, D = u.shape
    K = cfg.n_interests
    # Routing logits b are fixed (non-trainable) and start at zero; iterate.
    b = jnp.zeros((B, T, K), u.dtype)
    neg = jnp.asarray(-1e30, u.dtype)
    caps = jnp.zeros((B, K, D), u.dtype)
    for _ in range(cfg.capsule_iters):
        w = jax.nn.softmax(jnp.where(mask[..., None], b, neg), axis=1)  # over T
        caps = squash(jnp.einsum("btk,btd->bkd", w, u))
        b = b + jnp.einsum("bkd,btd->btk", caps, u)
    # per-interest head MLP (applied per capsule)
    caps = apply_mlp(params["head"], caps.reshape(B * K, D)).reshape(B, K, D)
    return caps


def apply(params, batch, cfg: RecsysConfig) -> jax.Array:
    """Ranking form: score one target per user -> [B] logits."""
    caps = interest_capsules(params, batch["history_ids"], cfg)   # [B, K, D]
    target = _item_lookup(params, batch["target_id"], cfg)        # [B, D]
    scores = jnp.einsum("bkd,bd->bk", caps, target)
    return scores.max(axis=-1)  # label-aware hard attention at serving


def retrieval_scores(params, batch, candidate_ids, cfg: RecsysConfig) -> jax.Array:
    """Retrieval form: [B] users x [N] candidates -> [B, N] scores."""
    caps = interest_capsules(params, batch["history_ids"], cfg)   # [B, K, D]
    cand = _item_lookup(params, candidate_ids, cfg)               # [N, D]
    scores = jnp.einsum("bkd,nd->bkn", caps, cand)
    return scores.max(axis=1)                                     # [B, N]
