"""Shared recsys model protocol.

Every recsys model module exposes::

    init(key, cfg)                 -> params pytree
    apply(params, batch, cfg)      -> logits [B] (or [B, n_tasks])
    input_specs(cfg, batch, ...)   -> dict of ShapeDtypeStruct

Batch layout (dense dict of arrays; unused keys absent):
    dense       [B, n_dense] f32    continuous features
    sparse_ids  [B, F, P]   i32     multi-hot ids, -1-padded
    history_ids [B, T]      i32     behaviour sequence (DIN/DIEN/MIND)
    target_id   [B]         i32     candidate item (DIN/DIEN/MIND)
    label       [B]         f32     click label (training)
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.embedding import EmbeddingConfig


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    """Family-agnostic recsys configuration; models read what they need."""

    name: str
    embedding: EmbeddingConfig
    n_dense: int = 0
    bottom_mlp: tuple[int, ...] = ()       # hidden+out sizes after n_dense input
    top_mlp: tuple[int, ...] = ()          # hidden+out sizes, output appended
    interaction: str = "dot"               # dot | concat | target-attn | multi-interest
    # DIN/DIEN/MIND:
    seq_len: int = 0
    attn_mlp: tuple[int, ...] = ()         # DIN attention-unit hidden sizes
    use_gru: bool = False                  # DIEN
    n_interests: int = 0                   # MIND
    capsule_iters: int = 3                 # MIND routing iterations
    # MT-WnD:
    n_tasks: int = 1
    dtype: Any = jnp.float32

    @property
    def embed_dim(self) -> int:
        return self.embedding.dim


def binary_ce(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Numerically-stable sigmoid cross-entropy, mean over batch/tasks."""
    logits = logits.astype(jnp.float32)
    labels = labels.astype(jnp.float32)
    if logits.ndim > labels.ndim:
        labels = labels[..., None]  # broadcast labels over task dim
    zeros = jnp.zeros_like(logits)
    loss = jnp.maximum(logits, zeros) - logits * labels + jnp.log1p(
        jnp.exp(-jnp.abs(logits))
    )
    return loss.mean()


def input_specs(
    cfg: RecsysConfig,
    batch_size: int,
    *,
    with_labels: bool = False,
    n_candidates: int = 0,
):
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
    emb = cfg.embedding
    specs: dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.n_dense:
        specs["dense"] = jax.ShapeDtypeStruct((batch_size, cfg.n_dense), cfg.dtype)
    if cfg.interaction in ("dot", "concat"):
        specs["sparse_ids"] = jax.ShapeDtypeStruct(
            (batch_size, emb.num_features, emb.max_pooling), jnp.int32
        )
    if cfg.seq_len:
        specs["history_ids"] = jax.ShapeDtypeStruct((batch_size, cfg.seq_len), jnp.int32)
        if not n_candidates:
            specs["target_id"] = jax.ShapeDtypeStruct((batch_size,), jnp.int32)
        if emb.num_features > 1:
            specs["profile_ids"] = jax.ShapeDtypeStruct(
                (batch_size, emb.num_features - 1), jnp.int32
            )
    if n_candidates:
        specs["candidate_ids"] = jax.ShapeDtypeStruct((n_candidates,), jnp.int32)
    if with_labels:
        shape = (batch_size,) if cfg.n_tasks == 1 else (batch_size, cfg.n_tasks)
        specs["label"] = jax.ShapeDtypeStruct(shape, cfg.dtype)
    return specs
