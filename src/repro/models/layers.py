"""Dense building blocks: MLPs, norms, rotary embedding, GQA attention, MoE.

Functional convention: ``init_*(key, ...) -> params`` pytree and a matching
apply function. No framework dependency — params are plain dicts so they
shard cleanly with pjit/shard_map and checkpoint as raw arrays.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.common.init import he_init, normal_init, xavier_init


# ---------------------------------------------------------------------------
# MLP (the recsys DenseNet primitive: Bottom-FC / Predict-FC / attention MLPs)
# ---------------------------------------------------------------------------


def init_mlp(key, sizes: Sequence[int], dtype=jnp.float32):
    """sizes = [in, h1, ..., out]; ReLU hidden, linear output."""
    params = []
    keys = jax.random.split(key, len(sizes) - 1)
    for i, k in enumerate(keys):
        params.append(
            {
                "w": he_init(k, (sizes[i], sizes[i + 1]), dtype=dtype),
                "b": jnp.zeros((sizes[i + 1],), dtype),
            }
        )
    return params


def apply_mlp(params, x, *, final_activation=None):
    """ReLU between layers; ``final_activation`` in {None,'relu','sigmoid'}."""
    n = len(params)
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < n - 1:
            x = jax.nn.relu(x)
        elif final_activation == "relu":
            x = jax.nn.relu(x)
        elif final_activation == "sigmoid":
            x = jax.nn.sigmoid(x)
    return x


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rmsnorm(dim, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}


def apply_rmsnorm(params, x, eps=1e-6):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(dtype)


def init_layernorm(dim, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def apply_layernorm(params, x, eps=1e-5):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * params["scale"] + params["bias"]).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------


def rope_angles(positions: jax.Array, head_dim: int, theta: float = 10000.0):
    """positions [*, T] -> (cos, sin) each [*, T, head_dim/2] in f32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [*, T, half]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: [..., T, n_heads, head_dim]; cos/sin: [..., T, head_dim/2]."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(x.dtype)
    s = sin[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# ---------------------------------------------------------------------------
# GQA attention (shared by all assigned LM archs)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False  # qwen2 uses bias on QKV
    rope_theta: float = 10000.0


def init_attention(key, cfg: AttentionConfig, dtype=jnp.float32):
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": normal_init(kq, (d, h * hd), dtype=dtype),
        "wk": normal_init(kk, (d, kvh * hd), dtype=dtype),
        "wv": normal_init(kv, (d, kvh * hd), dtype=dtype),
        "wo": normal_init(ko, (h * hd, d), dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kvh * hd,), dtype)
        p["bv"] = jnp.zeros((kvh * hd,), dtype)
    return p


def qkv_projection(params, x, cfg: AttentionConfig):
    """x [B, T, d] -> q [B, T, H, hd], k/v [B, T, KVH, hd]."""
    B, T, _ = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(B, T, cfg.n_heads, cfg.head_dim)
    k = k.reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
    return q, k, v


def gqa_attention(q, k, v, *, causal: bool = True, kv_valid_len=None):
    """Reference dot-product GQA attention (pure jnp; the Pallas flash
    kernel in repro/kernels/flash_attention is the production path).

    q: [B, Tq, H, hd]; k/v: [B, Tk, KVH, hd]. H must be a multiple of KVH.
    kv_valid_len: optional [B] — mask KV positions >= this (decode cache).
    """
    B, Tq, H, hd = q.shape
    KVH = k.shape[2]
    group = H // KVH
    qg = q.reshape(B, Tq, KVH, group, hd)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32)).astype(q.dtype)
    logits = jnp.einsum("btkgh,bskh->bkgts", qg, k) * scale  # [B,KVH,g,Tq,Tk]
    Tk = k.shape[1]
    neg = jnp.asarray(-1e30, logits.dtype)
    if causal and Tq > 1:
        # offset alignment: query i attends kv j <= i + (Tk - Tq)
        mask = jnp.arange(Tk)[None, :] <= (jnp.arange(Tq)[:, None] + (Tk - Tq))
        logits = jnp.where(mask[None, None, None], logits, neg)
    if kv_valid_len is not None:
        mask = jnp.arange(Tk)[None, :] < kv_valid_len[:, None]  # [B, Tk]
        logits = jnp.where(mask[:, None, None, None], logits, neg)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgts,bskh->btkgh", probs, v)
    return out.reshape(B, Tq, H, hd)


def attention_output(params, attn_out):
    B, T = attn_out.shape[:2]
    return attn_out.reshape(B, T, -1) @ params["wo"]


# ---------------------------------------------------------------------------
# SwiGLU FFN + MoE
# ---------------------------------------------------------------------------


def init_swiglu(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": normal_init(k1, (d_model, d_ff), dtype=dtype),
        "w_up": normal_init(k2, (d_model, d_ff), dtype=dtype),
        "w_down": normal_init(k3, (d_ff, d_model), dtype=dtype),
    }


def apply_swiglu(params, x):
    return (jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])) @ params["w_down"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int                 # per-expert FFN width
    n_experts: int
    top_k: int
    n_shared: int = 0         # shared (always-on) experts, qwen2-moe style
    shared_d_ff: int = 0      # width of the fused shared expert (0 = d_ff * n_shared)
    router_dtype: Any = jnp.float32
    capacity_factor: float = 1.25
    # expert arrays are stored zero-padded to a multiple of this so the
    # E dimension shards evenly over the model axis (EP); the router only
    # ever routes to the first n_experts.
    pad_to: int = 16

    @property
    def n_experts_padded(self) -> int:
        return -(-self.n_experts // self.pad_to) * self.pad_to


def init_moe(key, cfg: MoEConfig, dtype=jnp.float32):
    kr, ke, ks = jax.random.split(key, 3)
    E, d, f = cfg.n_experts_padded, cfg.d_model, cfg.d_ff
    # Experts stored stacked [E_pad, ...] so they shard evenly over the
    # model axis; rows >= n_experts are zero-padded and never routed to.
    ekeys = jax.random.split(ke, 3)

    def experts_init(k, shape):
        w = normal_init(k, shape, dtype=dtype)
        if E > cfg.n_experts:
            zero = jnp.zeros((E - cfg.n_experts, *shape[1:]), dtype)
            w = jnp.concatenate([w[: cfg.n_experts], zero], axis=0)
        return w

    params = {
        "router": normal_init(kr, (d, cfg.n_experts), stddev=0.006,
                              dtype=jnp.float32),
        "experts": {
            "w_gate": experts_init(ekeys[0], (E, d, f)),
            "w_up": experts_init(ekeys[1], (E, d, f)),
            "w_down": experts_init(ekeys[2], (E, f, d)),
        },
    }
    if cfg.n_shared:
        sf = cfg.shared_d_ff or cfg.d_ff * cfg.n_shared
        params["shared"] = init_swiglu(ks, d, sf, dtype=dtype)
    return params


def moe_router(params, x, cfg: MoEConfig):
    """x [N, d] -> (topk_idx [N,k], topk_weight [N,k], aux_loss scalar)."""
    logits = x.astype(cfg.router_dtype) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    topk_w, topk_idx = jax.lax.top_k(probs, cfg.top_k)
    topk_w = topk_w / jnp.maximum(topk_w.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance auxiliary loss.
    E = cfg.n_experts
    me = probs.mean(axis=0)                                   # mean router prob
    ce = jnp.zeros((E,), probs.dtype).at[topk_idx.reshape(-1)].add(
        1.0 / (topk_idx.size)
    )                                                          # token fraction
    aux = E * jnp.sum(me * ce)
    return topk_idx, topk_w.astype(x.dtype), aux


def apply_moe_dense(params, x, cfg: MoEConfig):
    """Reference dense-dispatch MoE: every expert runs on every token via a
    one-hot mixing matrix. O(E·N·d·f) — used for correctness tests and tiny
    smoke configs; the EP all_to_all path lives in repro/dist/moe.py.

    x: [N, d]; returns ([N, d], aux_loss).
    """
    topk_idx, topk_w, aux = moe_router(params, x, cfg)
    E = cfg.n_experts
    # combine[n, e] = weight of expert e for token n (0 if not routed)
    combine = jnp.zeros((x.shape[0], E), x.dtype)
    for j in range(cfg.top_k):
        combine = combine.at[jnp.arange(x.shape[0]), topk_idx[:, j]].add(topk_w[:, j])
    ex = jax.tree.map(lambda t: t[: cfg.n_experts], params["experts"])
    h_gate = jnp.einsum("nd,edf->enf", x, ex["w_gate"])
    h_up = jnp.einsum("nd,edf->enf", x, ex["w_up"])
    h = jax.nn.silu(h_gate) * h_up
    y_e = jnp.einsum("enf,efd->end", h, ex["w_down"])  # [E, N, d]
    y = jnp.einsum("end,ne->nd", y_e, combine)
    if cfg.n_shared:
        y = y + apply_swiglu(params["shared"], x)
    return y, aux
