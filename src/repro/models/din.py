"""DIN (arXiv:1706.06978) and DIEN (arXiv:1809.03672).

Embedding layout convention for behaviour-sequence models: feature 0 of the
EmbeddingConfig is the ITEM table (shared by history_ids and target_id);
features 1..F-1 are 1-hot profile/context tables looked up via
batch["profile_ids"] [B, F-1].

DIN: local activation unit — per history item, an MLP over
[e_h, e_t, e_h - e_t, e_h * e_t] produces an attention weight; the weighted
sum of history embeddings is the user interest vector.

DIEN (cfg.use_gru): interest-extractor GRU over history, then AUGRU
(attention-update-gate GRU) with DIN-style scores drives interest evolution.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.init import normal_init
from repro.models import embedding as emb_lib
from repro.models.layers import apply_mlp, init_mlp
from repro.models.recsys_base import RecsysConfig


def _item_lookup(params, ids, cfg: RecsysConfig):
    """Lookup into the item table (feature 0). ids >= 0; -1 padded -> 0 row.

    Routes through the model-axis-sharded gather under a mesh context."""
    from repro.dist.sharded_embedding import sharded_row_gather

    base = int(cfg.embedding.row_offsets[0])
    safe = jnp.maximum(ids, 0)
    return sharded_row_gather(params["embedding"]["table"], base + safe, None)


def _profile_lookup(params, profile_ids, cfg: RecsysConfig):
    """1-hot lookups for features 1..F-1 -> [B, (F-1)*D]."""
    from repro.dist.sharded_embedding import sharded_row_gather

    offs = cfg.embedding.row_offsets
    outs = []
    for f in range(1, cfg.embedding.num_features):
        outs.append(
            sharded_row_gather(
                params["embedding"]["table"],
                int(offs[f]) + profile_ids[:, f - 1],
                None,
            )
        )
    return jnp.concatenate(outs, axis=-1)


def init(key, cfg: RecsysConfig):
    k_emb, k_attn, k_top, k_gru1, k_gru2 = jax.random.split(key, 5)
    d = cfg.embed_dim
    params = {
        "embedding": emb_lib.init_embedding(k_emb, cfg.embedding),
        # attention unit input: [e_h, e_t, e_h - e_t, e_h * e_t]
        "attn_mlp": init_mlp(k_attn, (4 * d, *cfg.attn_mlp, 1), dtype=cfg.dtype),
    }
    n_profile = cfg.embedding.num_features - 1
    top_in = 2 * d + n_profile * d  # [interest, e_target, profiles]
    params["top_mlp"] = init_mlp(k_top, (top_in, *cfg.top_mlp, 1), dtype=cfg.dtype)
    if cfg.use_gru:
        params["gru"] = _init_gru(k_gru1, d, d, dtype=cfg.dtype)
        params["augru"] = _init_gru(k_gru2, d, d, dtype=cfg.dtype)
    return params


def attention_scores(params, hist_emb, target_emb, mask, cfg: RecsysConfig):
    """DIN local activation unit -> [B, T] weights (not normalized, per paper;
    masked positions get zero weight)."""
    B, T, d = hist_emb.shape
    t = jnp.broadcast_to(target_emb[:, None, :], (B, T, d))
    feat = jnp.concatenate([hist_emb, t, hist_emb - t, hist_emb * t], axis=-1)
    logit = apply_mlp(params["attn_mlp"], feat)[..., 0]  # [B, T]
    return jnp.where(mask, logit, 0.0)


def _init_gru(key, in_dim, hidden, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    def gate(k):
        return {
            "wx": normal_init(k, (in_dim, hidden), stddev=0.05, dtype=dtype),
            "wh": normal_init(jax.random.fold_in(k, 1), (hidden, hidden), stddev=0.05, dtype=dtype),
            "b": jnp.zeros((hidden,), dtype),
        }
    return {"r": gate(ks[0]), "z": gate(ks[1]), "h": gate(ks[2])}


def _gru_cell(p, h, x, update_scale=None):
    r = jax.nn.sigmoid(x @ p["r"]["wx"] + h @ p["r"]["wh"] + p["r"]["b"])
    z = jax.nn.sigmoid(x @ p["z"]["wx"] + h @ p["z"]["wh"] + p["z"]["b"])
    hh = jnp.tanh(x @ p["h"]["wx"] + (r * h) @ p["h"]["wh"] + p["h"]["b"])
    if update_scale is not None:  # AUGRU: attention scales the update gate
        z = z * update_scale[:, None]
    return (1.0 - z) * h + z * hh


def _run_gru(p, xs, att=None):
    """xs [B, T, D] -> all hidden states [B, T, D] via lax.scan over T."""
    B, T, D = xs.shape
    h0 = jnp.zeros((B, D), xs.dtype)
    xs_t = xs.swapaxes(0, 1)  # [T, B, D]
    if att is None:
        def step(h, x):
            h = _gru_cell(p, h, x)
            return h, h
        _, hs = jax.lax.scan(step, h0, xs_t)
    else:
        def step_a(h, inp):
            x, a = inp
            h = _gru_cell(p, h, x, update_scale=a)
            return h, h
        _, hs = jax.lax.scan(step_a, h0, (xs_t, att.swapaxes(0, 1)))
    return hs.swapaxes(0, 1)  # [B, T, D]


def apply(params, batch, cfg: RecsysConfig) -> jax.Array:
    hist = batch["history_ids"]                     # [B, T]
    mask = hist >= 0
    hist_emb = _item_lookup(params, hist, cfg) * mask[..., None].astype(cfg.dtype)
    target_emb = _item_lookup(params, batch["target_id"], cfg)  # [B, D]

    if cfg.use_gru:  # DIEN
        states = _run_gru(params["gru"], hist_emb)              # interest extractor
        att = attention_scores(params, states, target_emb, mask, cfg)
        att = jax.nn.softmax(jnp.where(mask, att, -1e30), axis=-1)
        final = _run_gru(params["augru"], states, att=att)[:, -1, :]
        interest = final
    else:  # DIN
        att = attention_scores(params, hist_emb, target_emb, mask, cfg)
        interest = jnp.einsum("bt,btd->bd", att, hist_emb)

    feats = [interest, target_emb]
    if cfg.embedding.num_features > 1 and "profile_ids" in batch:
        feats.append(_profile_lookup(params, batch["profile_ids"], cfg))
    x = jnp.concatenate(feats, axis=-1)
    return apply_mlp(params["top_mlp"], x)[:, 0]


def retrieval_scores(params, batch, candidate_ids, cfg: RecsysConfig) -> jax.Array:
    """Score one user's history against N candidate items -> [N].

    DIN's attention depends on the target, so each candidate re-attends over
    the history — but the history embeddings are gathered ONCE (not N times)
    and broadcast; the N x T attention-unit MLP is the honest cost.
    """
    hist = batch["history_ids"][0]                       # [T]
    mask = hist >= 0
    hist_emb = _item_lookup(params, hist, cfg)           # [T, D]
    hist_emb = hist_emb * mask[:, None].astype(cfg.dtype)
    cand_emb = _item_lookup(params, candidate_ids, cfg)  # [N, D]
    N, D = cand_emb.shape
    T = hist.shape[0]
    h = jnp.broadcast_to(hist_emb[None], (N, T, D))
    att = attention_scores(params, h, cand_emb, jnp.broadcast_to(mask[None], (N, T)), cfg)
    interest = jnp.einsum("nt,ntd->nd", att, h)
    feats = [interest, cand_emb]
    if cfg.embedding.num_features > 1 and "profile_ids" in batch:
        prof = _profile_lookup(params, batch["profile_ids"], cfg)  # [1, (F-1)D]
        feats.append(jnp.broadcast_to(prof, (N, prof.shape[-1])))
    x = jnp.concatenate(feats, axis=-1)
    return apply_mlp(params["top_mlp"], x)[:, 0]
