"""Parameter initializers (functional, rng-splitting convention).

All model ``init`` functions thread a single PRNGKey and split per parameter;
these helpers keep the scale conventions in one place.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def normal_init(key, shape, stddev=0.02, dtype=jnp.float32):
    return (stddev * jax.random.normal(key, shape)).astype(dtype)


def uniform_init(key, shape, scale, dtype=jnp.float32):
    return jax.random.uniform(key, shape, minval=-scale, maxval=scale).astype(dtype)


def he_init(key, shape, dtype=jnp.float32):
    """Kaiming-normal for ReLU MLPs (fan_in = shape[0])."""
    fan_in = shape[0]
    return (jax.random.normal(key, shape) * jnp.sqrt(2.0 / fan_in)).astype(dtype)


def xavier_init(key, shape, dtype=jnp.float32):
    fan_in, fan_out = shape[0], shape[-1]
    scale = jnp.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, minval=-scale, maxval=scale).astype(dtype)


def embedding_init(key, shape, dtype=jnp.float32):
    """DLRM convention: U(-1/sqrt(vocab), 1/sqrt(vocab))."""
    vocab = shape[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(vocab, jnp.float32))
    return jax.random.uniform(key, shape, minval=-scale, maxval=scale).astype(dtype)
