"""Common typed configuration objects shared across the framework.

Every architecture config (src/repro/configs/<id>.py) produces one of the
model-family dataclasses defined alongside the model code; this module holds
the pieces that are family-agnostic: the shape specs that pair with each
architecture and small helpers.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Mapping

import jax.numpy as jnp


class ArchKind(enum.Enum):
    """Model family — drives which step functions and shardings exist."""

    LM_DENSE = "lm_dense"
    LM_MOE = "lm_moe"
    GNN = "gnn"
    RECSYS = "recsys"


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell assigned to an architecture.

    ``step`` selects which program the dry-run lowers:
      - "train"   -> train_step (fwd+bwd+update)
      - "prefill" -> serve_step over a full sequence (inference-prefill)
      - "decode"  -> serve_step producing one token against a KV cache
      - "serve"   -> batched inference forward (recsys / gnn serving)
    Remaining fields are family-specific free-form dims.
    """

    name: str
    step: str
    dims: Mapping[str, Any]

    def __getitem__(self, key: str) -> Any:
        return self.dims[key]

    def get(self, key: str, default: Any = None) -> Any:
        return self.dims.get(key, default)


def dtype_of(name: str):
    """Resolve a dtype name ('bf16'/'f32'/'i32'/...) to a jnp dtype."""
    table = {
        "bf16": jnp.bfloat16,
        "f32": jnp.float32,
        "f16": jnp.float16,
        "i32": jnp.int32,
        "i64": jnp.int64,
        "u32": jnp.uint32,
        "bool": jnp.bool_,
    }
    if name not in table:
        raise ValueError(f"unknown dtype name: {name!r}")
    return table[name]
