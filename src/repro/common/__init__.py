"""Shared primitives: typed configs, init helpers, pytree utilities."""
from repro.common.types import (
    ArchKind,
    ShapeSpec,
    dtype_of,
)
from repro.common.init import (
    normal_init,
    uniform_init,
    he_init,
    xavier_init,
)

__all__ = [
    "ArchKind",
    "ShapeSpec",
    "dtype_of",
    "normal_init",
    "uniform_init",
    "he_init",
    "xavier_init",
]
