"""Synthetic click-log generator for recsys training/serving.

Reproduces the production distributions the paper characterizes (Fig. 2):

- **Ids are power-law (Zipf) distributed and frequency-ranked**: id 0 is the
  hottest row of each table. This ranked layout is what makes the paper's
  locality-aware hot/cold partition a simple ``id < hot_rows`` test
  (repro.models.embedding) and is how production tables are laid out after
  frequency remapping.
- **Pooling factors are lognormal with a heavy tail** (Fig. 2c): per-lookup
  multi-hot counts vary widely around the table's nominal pooling factor.
- **Query sizes (items-to-rank per request) are lognormal between ~10 and
  ~1000** (Fig. 2b).

Everything is numpy (host-side input pipeline); batches convert to jnp at
the step boundary.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.models.embedding import EmbeddingConfig
from repro.models.recsys_base import RecsysConfig


@dataclasses.dataclass
class ClickLogConfig:
    zipf_alpha: float = 1.05          # id popularity skew (alpha -> 1: heavier)
    pooling_sigma: float = 0.6        # lognormal sigma around nominal pooling
    query_size_mu: float = np.log(64) # Fig 2b: median query ~ tens of items
    query_size_sigma: float = 1.1
    query_size_max: int = 1024


class ClickLogGenerator:
    """Stateful numpy generator of recsys batches for one model config."""

    def __init__(self, cfg: RecsysConfig, seed: int = 0,
                 log_cfg: ClickLogConfig | None = None):
        self.cfg = cfg
        self.log = log_cfg or ClickLogConfig()
        self.rng = np.random.default_rng(seed)

    # -- low-level samplers ------------------------------------------------

    def _zipf_ids(self, vocab: int, size) -> np.ndarray:
        """Frequency-ranked power-law ids in [0, vocab): id 0 hottest.

        Log-uniform construction (Zipf with exponent ~1): id = V^u - 1 for
        u ~ U(0,1), so pmf(id) ∝ 1/(id+1). ``zipf_alpha`` > 1 sharpens the
        head by raising u to a power."""
        u = self.rng.random(size) ** self.log.zipf_alpha
        ids = np.floor(np.power(float(vocab), u)) - 1.0
        return np.clip(ids, 0, vocab - 1).astype(np.int64)

    def _pooling_counts(self, nominal: int, size) -> np.ndarray:
        """Heavy-tailed per-bag lookup counts, clipped to [1, nominal]."""
        if nominal <= 1:
            return np.ones(size, np.int64)
        ln = self.rng.lognormal(np.log(max(nominal, 2) * 0.6),
                                self.log.pooling_sigma, size)
        return np.clip(ln.astype(np.int64), 1, nominal)

    def query_sizes(self, n: int) -> np.ndarray:
        """Items-to-rank per inference query (Fig. 2b)."""
        s = self.rng.lognormal(self.log.query_size_mu, self.log.query_size_sigma, n)
        return np.clip(s.astype(np.int64), 1, self.log.query_size_max)

    # -- batch builders ----------------------------------------------------

    def sparse_ids(self, batch: int) -> np.ndarray:
        """[B, F, Pmax] int32, -1-padded multi-hot ids."""
        emb = self.cfg.embedding
        F, P = emb.num_features, emb.max_pooling
        out = np.full((batch, F, P), -1, np.int32)
        for f in range(F):
            p_nom = emb.pooling[f]
            counts = self._pooling_counts(p_nom, batch)
            total = int(counts.sum())
            ids = self._zipf_ids(emb.vocab_sizes[f], total)
            pos = 0
            for b in range(batch):
                c = counts[b]
                out[b, f, :c] = ids[pos : pos + c]
                pos += c
        return out

    def batch(self, batch_size: int, *, with_labels: bool = True) -> dict:
        """One model batch matching recsys_base.input_specs."""
        cfg = self.cfg
        emb = cfg.embedding
        b: dict[str, np.ndarray] = {}
        if cfg.n_dense:
            b["dense"] = self.rng.normal(size=(batch_size, cfg.n_dense)).astype(np.float32)
        if cfg.interaction in ("dot", "concat"):
            b["sparse_ids"] = self.sparse_ids(batch_size)
        if cfg.seq_len:
            item_vocab = emb.vocab_sizes[0]
            hist = self._zipf_ids(item_vocab, (batch_size, cfg.seq_len)).astype(np.int32)
            lengths = np.clip(
                self.rng.lognormal(np.log(cfg.seq_len * 0.5), 0.5, batch_size),
                1, cfg.seq_len,
            ).astype(np.int64)
            mask = np.arange(cfg.seq_len)[None, :] < lengths[:, None]
            b["history_ids"] = np.where(mask, hist, -1).astype(np.int32)
            b["target_id"] = self._zipf_ids(item_vocab, batch_size).astype(np.int32)
            if emb.num_features > 1:
                b["profile_ids"] = np.stack(
                    [
                        self._zipf_ids(emb.vocab_sizes[f], batch_size)
                        for f in range(1, emb.num_features)
                    ],
                    axis=1,
                ).astype(np.int32)
        if with_labels:
            shape = (batch_size,) if cfg.n_tasks == 1 else (batch_size, cfg.n_tasks)
            b["label"] = (self.rng.random(shape) < 0.03).astype(np.float32)  # CTR ~3%
        return b

    def access_frequencies(self, n_queries: int = 512) -> list[np.ndarray]:
        """Per-feature id access histograms from a sampled trace — the input
        to the paper's locality-aware hot-set sizing (Fig. 10a)."""
        emb = self.cfg.embedding
        freqs = []
        ids = self.sparse_ids(n_queries) if self.cfg.interaction in ("dot", "concat") else None
        for f in range(emb.num_features):
            if ids is None:
                freqs.append(np.ones(1))
                continue
            col = ids[:, f, :].reshape(-1)
            col = col[col >= 0]
            freqs.append(np.bincount(col, minlength=emb.vocab_sizes[f]).astype(np.float64))
        return freqs
