"""Synthetic LM token stream (structured, learnable): a tiny mixture of
Markov chains over the vocab so a ~100M model trained a few hundred steps
shows a falling loss curve (examples/train_lm.py)."""
from __future__ import annotations

import numpy as np


class TokenStream:
    def __init__(self, vocab: int, seed: int = 0, order_states: int = 512):
        self.vocab = vocab
        self.rng = np.random.default_rng(seed)
        self.states = order_states
        # sparse-ish transition structure: each state prefers 8 tokens
        self.pref = self.rng.integers(0, vocab, (order_states, 8))

    def batch(self, batch_size: int, seq_len: int) -> dict:
        toks = np.empty((batch_size, seq_len), np.int32)
        state = self.rng.integers(0, self.states, batch_size)
        for t in range(seq_len):
            choice = self.rng.integers(0, 8, batch_size)
            noise = self.rng.random(batch_size) < 0.1
            tok = self.pref[state, choice]
            tok = np.where(noise, self.rng.integers(0, self.vocab, batch_size), tok)
            toks[:, t] = tok
            state = (state * 31 + tok) % self.states
        return {"tokens": toks}
