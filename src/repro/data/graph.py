"""Graph data: synthetic power-law graphs in CSR form + the real layerwise
uniform neighbor sampler that feeds GraphSAGE mini-batch training.

The sampler is the production piece (minibatch_lg requires it): given a CSR
adjacency, it draws fixed-fanout uniform samples per hop, padding nodes with
degree < fanout (mask=False), producing the dense [B, f1, ..., fj] id blocks
that repro.models.gnn.apply_minibatch consumes.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray   # [N+1]
    indices: np.ndarray  # [E] neighbor ids
    feats: np.ndarray    # [N, d]
    labels: np.ndarray   # [N]

    @property
    def n_nodes(self) -> int:
        return len(self.indptr) - 1

    @property
    def n_edges(self) -> int:
        return len(self.indices)

    def edge_list(self) -> np.ndarray:
        """[2, E] (src, dst): CSR row = dst, entries = src (in-neighbors)."""
        dst = np.repeat(np.arange(self.n_nodes), np.diff(self.indptr))
        return np.stack([self.indices, dst]).astype(np.int32)


def synthetic_graph(n_nodes: int, avg_degree: int, d_feat: int, n_classes: int,
                    seed: int = 0) -> CSRGraph:
    """Power-law (preferential-attachment-ish) synthetic graph in CSR."""
    rng = np.random.default_rng(seed)
    n_edges = n_nodes * avg_degree
    # power-law target popularity for edge endpoints
    pop = rng.zipf(1.3, n_edges * 2) % n_nodes
    src = pop[:n_edges].astype(np.int64)
    dst = rng.integers(0, n_nodes, n_edges)
    order = np.argsort(dst, kind="stable")
    src, dst = src[order], dst[order]
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.add.at(indptr, dst + 1, 1)
    indptr = np.cumsum(indptr)
    feats = rng.normal(size=(n_nodes, d_feat)).astype(np.float32)
    labels = rng.integers(0, n_classes, n_nodes).astype(np.int32)
    return CSRGraph(indptr=indptr, indices=src.astype(np.int32),
                    feats=feats, labels=labels)


class NeighborSampler:
    """Uniform fixed-fanout layerwise sampler (GraphSAGE §3.1)."""

    def __init__(self, graph: CSRGraph, fanout: tuple[int, ...], seed: int = 0):
        self.g = graph
        self.fanout = fanout
        self.rng = np.random.default_rng(seed)

    def _sample_neighbors(self, nodes: np.ndarray, k: int):
        """nodes [M] -> (ids [M, k], mask [M, k]); no-neighbor rows masked."""
        g = self.g
        starts = g.indptr[nodes]
        degs = g.indptr[nodes + 1] - starts
        # uniform with replacement; degree-0 nodes get mask=False
        r = self.rng.integers(0, np.maximum(degs, 1)[:, None], (len(nodes), k))
        ids = g.indices[starts[:, None] + r]
        mask = (degs > 0)[:, None] & np.ones((1, k), bool)
        return ids.astype(np.int32), mask

    def sample_block(self, seeds: np.ndarray) -> dict:
        """Seeds [B] -> dense hop pyramid matching gnn.input_specs('mini')."""
        g = self.g
        out: dict[str, np.ndarray] = {"hop0_feats": g.feats[seeds]}
        frontier = seeds
        shape = (len(seeds),)
        mask_prev = np.ones(shape, bool)
        for j, k in enumerate(self.fanout, start=1):
            ids, mask = self._sample_neighbors(frontier.reshape(-1), k)
            shape = (*shape, k)
            ids = ids.reshape(shape)
            mask = mask.reshape(shape) & mask_prev[..., None]
            out[f"hop{j}_feats"] = g.feats[np.maximum(ids, 0)]
            out[f"hop{j}_mask"] = mask
            frontier, mask_prev = ids, mask
        out["labels"] = g.labels[seeds]
        return out


def pack_graphs(feats, edges, max_nodes: int, max_edges: int):
    """Pack G small graphs block-diagonally for gnn.apply_batched.

    feats: list of [n_i, d]; edges: list of [2, e_i]. Pads each graph to
    (max_nodes, max_edges); padded edges self-loop on a padded node.
    """
    G = len(feats)
    d = feats[0].shape[1]
    f_out = np.zeros((G * max_nodes, d), np.float32)
    e_out = np.zeros((2, G * max_edges), np.int32)
    node_mask = np.zeros((G * max_nodes,), bool)
    graph_ids = np.repeat(np.arange(G), max_nodes).astype(np.int32)
    for i, (f, e) in enumerate(zip(feats, edges)):
        n, ne = f.shape[0], e.shape[1]
        base_n, base_e = i * max_nodes, i * max_edges
        f_out[base_n : base_n + n] = f
        node_mask[base_n : base_n + n] = True
        e_out[:, base_e : base_e + ne] = e + base_n
        if ne < max_edges:  # pad: self-loops on the last padded node
            pad_node = base_n + max_nodes - 1
            e_out[:, base_e + ne : base_e + max_edges] = pad_node
    return f_out, e_out, node_mask, graph_ids
