"""Data pipelines: synthetic click logs (paper Fig. 2 distributions),
graph loaders + neighbor sampler, LM token batches."""
