"""Gradient-based task-scheduling search (paper Algorithm 1, Figs. 11/12).

Explores P(M+D+O) for every feasible partition plan of a (workload, server)
pair. Exploiting the convexity of the P(M+D) throughput surface, the walk
starts at the minimal (m, d) corner and repeatedly evaluates the three-
candidate frontier — grow m, grow d, grow both — moving to the best QPS
improvement that still meets the SLA latency and provisioned-power
constraints; it terminates when all three regress. The outer loop sweeps
op-parallelism o and stops when the per-o peak starts decreasing (paper's
early stop).

Every evaluation is a latency-bounded-throughput measurement from the
discrete-event simulator; evaluations are memoized, and the search reports
how much of the exhaustive space it visited (the paper's search-efficiency
claim).  All evaluations of one search — the frontier candidates of every
step, every o, every plan, and every bisection probe inside them — run
through one shared :class:`~repro.serving.simulator.SimCache`, so arrival
streams (common random numbers), query splits and duration tables are
computed once per (workload, server) pair instead of once per probe.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.devices import DeviceProfile
from repro.core.partition import Placement, enumerate_placements
from repro.core.workload import ModelProfile
from repro.serving.simulator import (
    SchedConfig,
    SimCache,
    SimResult,
    max_sustainable_qps,
)

BATCH_GRID = (32, 64, 128, 256, 512, 1024)


@dataclasses.dataclass
class SearchResult:
    workload: str
    server: str
    placement: Placement
    sched: SchedConfig
    qps: float
    power_w: float
    p95_ms: float
    evals: int
    space_size: int
    trajectory: list


def _space(plan: str, device: DeviceProfile, o: int):
    """Feasible (m, d) coordinates for one plan at op-parallelism o."""
    cores = device.cpu.cores
    if plan == "cpu_model":
        max_m = max(cores // o, 1)
    elif plan == "cpu_sd":
        max_m = max(cores - o, 1)  # m dense threads; >=1 sparse thread of o cores
    else:
        max_m = device.accel.max_colocate if device.accel else 1
    return max_m


def _mk_sched(plan: str, device: DeviceProfile, m: int, d: int, o: int) -> SchedConfig | None:
    cores = device.cpu.cores
    if plan == "cpu_model":
        if m * o > cores:
            return None
        return SchedConfig(batch=d, m=m, o=o)
    if plan == "cpu_sd":
        sparse = (cores - m) // o
        if sparse < 1 or m < 1:
            return None
        return SchedConfig(batch=d, m=m, o=o, sd_sparse=sparse)
    if device.accel and m > device.accel.max_colocate:
        return None
    return SchedConfig(batch=d, m=m, o=o)


def gradient_search(
    profile: ModelProfile,
    device: DeviceProfile,
    query_sizes: np.ndarray,
    power_budget_w: float | None = None,
    seed: int = 0,
    o_grid: tuple[int, ...] | None = None,
    engine: str = "fast",
    cache: SimCache | None = None,
    qps_tol: float = 0.0,
) -> SearchResult:
    sla = profile.sla_ms
    if cache is None:
        cache = SimCache(query_sizes, seed)
    memo: dict[tuple, tuple[float, SimResult | None]] = {}
    trajectory: list = []

    def evaluate(pl: Placement, m: int, di: int, o: int):
        key = (pl.plan, m, di, o)
        if key in memo:
            return memo[key]
        sched = _mk_sched(pl.plan, device, m, BATCH_GRID[di], o)
        if sched is None:
            memo[key] = (0.0, None)
            return memo[key]
        qps, res = max_sustainable_qps(
            pl, device, sched, sla, query_sizes, power_budget_w, seed,
            cache=cache, engine=engine, qps_tol=qps_tol,
        )
        memo[key] = (qps, res)
        trajectory.append((pl.plan, m, BATCH_GRID[di], o, qps))
        return memo[key]

    def evaluate_frontier(pl: Placement, cands, o: int):
        """Evaluate a frontier of (m, d-index) candidates through the shared
        engine context (one SimCache: common arrival streams, splits and
        duration tables across all of them) and return the best feasible."""
        best = None
        for cm, cd in cands:
            if cd >= len(BATCH_GRID):
                continue
            cq, cr = evaluate(pl, cm, cd, o)
            if cr is None:
                continue
            if best is None or cq > best[0]:
                best = (cq, cr, cm, cd)
        return best

    def md_walk(pl: Placement, o: int):
        """Gradient walk over the (m, d) grid for one op-parallelism."""
        m, di = 1, 0
        qps, res = evaluate(pl, m, di, o)
        while True:
            best = evaluate_frontier(
                pl, [(m + 1, di), (m, di + 1), (m + 1, di + 1)], o)
            if best is None or best[0] <= qps:
                return qps, res, m, di
            qps, res, m, di = best

    best: SearchResult | None = None
    space_size = 0
    for pl in enumerate_placements(profile, device):
        if pl.plan in ("cpu_model", "cpu_sd"):
            grid = o_grid or (1, 2, 4, 5, 10)
        else:
            grid = o_grid or (1, 2)  # host-pool workers for the accel plans
        prev_peak = -1.0
        for o in grid:
            space_size += _space(pl.plan, device, o) * len(BATCH_GRID)
            qps, res, m, di = md_walk(pl, o)
            if res is not None and (best is None or qps > best.qps):
                best = SearchResult(
                    workload=profile.name,
                    server=device.name,
                    placement=pl,
                    sched=_mk_sched(pl.plan, device, m, BATCH_GRID[di], o),
                    qps=qps,
                    power_w=res.avg_power_w,
                    p95_ms=res.p95_ms,
                    evals=0,
                    space_size=0,
                    trajectory=[],
                )
            if qps < prev_peak:  # outer-loop early stop (Algorithm 1)
                break
            prev_peak = qps
    if best is None:
        # workload infeasible on this server at any configuration
        best = SearchResult(profile.name, device.name,
                            enumerate_placements(profile, device)[0],
                            SchedConfig(batch=8, m=1), 0.0,
                            device.idle_power_w, float("inf"), 0, 0, [])
    best.evals = len(memo)
    best.space_size = max(space_size, 1)
    best.trajectory = trajectory
    return best
