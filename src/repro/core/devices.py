"""Server/device profiles — the heterogeneous hardware pool (paper Table II).

The paper evaluates CPU-only, CPU+NMP and CPU+GPU servers with real
measurement plus a cycle-level NMP LUT; on this CPU-only container the same
role is played by analytic profiles (DESIGN.md §2): each profile carries the
roofline constants (compute rate, stream bandwidth, random-gather bandwidth,
host link bandwidth) and the power envelope. ``repro.core.perfmodel``
executes a model's operator profile against a profile; calibration constants
are fitted from real JAX timings on this host (repro.core.calibrate).

Profiles T1–T10 mirror Table II; TPU v5e is added as the TPU-era extension
with a SparseCore-style gather-offload standing in for NMP rank parallelism.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class CPUSpec:
    cores: int
    gflops_per_core: float     # effective dense GFLOP/s per physical core
    tdp_w: float
    idle_w: float


@dataclasses.dataclass(frozen=True)
class MemSpec:
    bw_gbs: float              # stream bandwidth
    gather_eff: float          # random-gather fraction of stream bw
    nmp_factor: float          # gather-bandwidth multiplier (rank parallelism)
    capacity_gb: float
    tdp_w: float
    idle_w: float


@dataclasses.dataclass(frozen=True)
class AccelSpec:
    peak_gflops: float         # dense compute
    hbm_gbs: float
    gather_eff: float
    link_gbs: float            # host<->device (PCIe) or ICI
    capacity_gb: float
    tdp_w: float
    idle_w: float
    kernel_overhead_us: float  # per-op launch overhead
    max_colocate: int = 8      # MPS-style co-location limit


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    name: str
    cpu: CPUSpec
    mem: MemSpec
    accel: AccelSpec | None = None

    @property
    def has_accel(self) -> bool:
        return self.accel is not None

    @property
    def peak_power_w(self) -> float:
        p = self.cpu.tdp_w + self.mem.tdp_w
        if self.accel:
            p += self.accel.tdp_w
        return p

    @property
    def idle_power_w(self) -> float:
        p = self.cpu.idle_w + self.mem.idle_w
        if self.accel:
            p += self.accel.idle_w
        return p


# -- component library (paper Table II) -------------------------------------

# Xeon D-2191: 18 cores @ 1.6 GHz. Effective DL GEMM throughput per core
# (AVX-512 with frequency throttling, ~60% efficiency): ~31 GFLOP/s f32.
CPU_T1 = CPUSpec(cores=18, gflops_per_core=31.0, tdp_w=86.0, idle_w=25.0)
# Xeon Gold 6138: 20 cores @ 2.0 GHz, 2 FMA units: ~77 GFLOP/s effective.
CPU_T2 = CPUSpec(cores=20, gflops_per_core=77.0, tdp_w=125.0, idle_w=36.0)

DDR4_T1 = MemSpec(bw_gbs=77.0, gather_eff=0.35, nmp_factor=1.0,
                  capacity_gb=64.0, tdp_w=28.0, idle_w=8.0)
DDR4_T2 = MemSpec(bw_gbs=85.0, gather_eff=0.35, nmp_factor=1.0,
                  capacity_gb=128.0, tdp_w=50.0, idle_w=14.0)


def _nmp(n: int) -> MemSpec:
    """RecNMP-style DIMM: N-rank parallel gather-reduce. Random-gather
    bandwidth scales ~N× (rank-level parallelism + on-DIMM pooling also
    removes the CPU-side reduce traffic); stream bandwidth unchanged."""
    return MemSpec(bw_gbs=85.0, gather_eff=0.8, nmp_factor=float(n),
                   capacity_gb=128.0 * n, tdp_w=50.0 * n, idle_w=14.0 * n)


P100 = AccelSpec(peak_gflops=9_300.0, hbm_gbs=732.0, gather_eff=0.5,
                 link_gbs=16.0, capacity_gb=16.0, tdp_w=300.0, idle_w=30.0,
                 kernel_overhead_us=8.0)
V100 = AccelSpec(peak_gflops=14_000.0, hbm_gbs=900.0, gather_eff=0.5,
                 link_gbs=16.0, capacity_gb=16.0, tdp_w=300.0, idle_w=30.0,
                 kernel_overhead_us=8.0)

# TPU v5e: bf16 MXU 197 TFLOP/s, 819 GB/s HBM, 16 GB; host link modeled at
# PCIe-class 32 GB/s; SparseCore-style gather offload -> high gather_eff.
TPU_V5E = AccelSpec(peak_gflops=197_000.0, hbm_gbs=819.0, gather_eff=0.75,
                    link_gbs=32.0, capacity_gb=16.0, tdp_w=250.0, idle_w=40.0,
                    kernel_overhead_us=4.0)


SERVER_TYPES: dict[str, DeviceProfile] = {
    "T1": DeviceProfile("T1", CPU_T1, DDR4_T1),
    "T2": DeviceProfile("T2", CPU_T2, DDR4_T2),
    "T3": DeviceProfile("T3", CPU_T2, _nmp(2)),
    "T4": DeviceProfile("T4", CPU_T2, _nmp(4)),
    "T5": DeviceProfile("T5", CPU_T2, _nmp(8)),
    "T6": DeviceProfile("T6", CPU_T1, DDR4_T1, P100),
    "T7": DeviceProfile("T7", CPU_T2, DDR4_T2, V100),
    "T8": DeviceProfile("T8", CPU_T2, _nmp(2), V100),
    "T9": DeviceProfile("T9", CPU_T2, _nmp(4), V100),
    "T10": DeviceProfile("T10", CPU_T2, _nmp(8), V100),
    # TPU-era extension (DESIGN.md §2)
    "T11-v5e": DeviceProfile("T11-v5e", CPU_T2, DDR4_T2, TPU_V5E),
}

# Paper §III-C / §VI availability limits N_h.
DEFAULT_AVAILABILITY: dict[str, int] = {
    "T1": 100, "T2": 100, "T3": 15, "T4": 10, "T5": 5,
    "T6": 10, "T7": 5, "T8": 6, "T9": 4, "T10": 2,
    "T11-v5e": 4,
}
