"""Operator-level workload profiles.

Hercules classifies workloads by executing them on each server type; on this
container the execution engine is an analytic roofline over an *operator
profile* extracted from the real model configs (DESIGN.md §2). Each op
carries per-item (item = one candidate to rank / one token / one seed node)
flops and byte counts split by traffic class:

- stream_bytes : sequential activation traffic (DRAM/HBM streaming)
- gather_bytes : random-access embedding/table traffic (the NMP target)
- host_bytes   : host->accelerator input transfer (sparse ids, dense feats)
- weight_bytes : per-invocation weight reads (amortized over the batch)

``level`` encodes the dependency depth for op-parallelism modeling: ops at
the same level are independent (paper Fig. 5 — SparseNet ops parallelize,
the FC chain does not), so elapsed time with ``o`` workers is
``sum_level max(longest_op, level_work / o)`` — list-scheduling, which
reproduces the measured idle-cycle growth.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Sequence

import numpy as np

if TYPE_CHECKING:  # config classes are used as annotations only — keeping
    # these type-only means repro.core stays importable without dragging in
    # the JAX model stack (and repro.dist) behind it
    from repro.models.gnn import GNNConfig
    from repro.models.recsys_base import RecsysConfig
    from repro.models.transformer import LMConfig


@dataclasses.dataclass(frozen=True)
class OpCost:
    name: str
    stage: str                 # "sparse" | "dense"
    level: int                 # dependency depth (for op-parallel modeling)
    flops: float = 0.0         # per item
    stream_bytes: float = 0.0  # per item
    gather_bytes: float = 0.0  # per item
    host_bytes: float = 0.0    # per item
    weight_bytes: float = 0.0  # per invocation
    sequential: bool = False   # recurrent op (GRU): no batch-dim speedup on MXU


@dataclasses.dataclass(frozen=True)
class ModelProfile:
    name: str
    ops: tuple[OpCost, ...]
    table_gb: float            # embedding table footprint
    weight_gb: float           # dense weight footprint
    sla_ms: float              # paper Fig. 15 SLA targets
    # analytic hot-set hit rate: fraction of gather traffic served by a hot
    # cache holding `h` of `V` rows under the log-uniform popularity law.
    zipf_alpha: float = 1.05

    def hot_hit_rate(self, hot_frac: float) -> float:
        """P(access hits hottest `hot_frac` of rows) under log-uniform ids.

        ids ~ floor(V^u) with u = U(0,1)^alpha =>
        P(id < h) = P(u < log(h+1)/log V) = (log(h+1)/log V)^(1/alpha).
        """
        if hot_frac <= 0.0:
            return 0.0
        if hot_frac >= 1.0:
            return 1.0
        base = np.log1p(hot_frac * 1e7) / np.log(1e7)  # V-independent proxy
        return float(base ** (1.0 / self.zipf_alpha))

    @property
    def sparse_ops(self) -> tuple[OpCost, ...]:
        return tuple(op for op in self.ops if op.stage == "sparse")

    @property
    def dense_ops(self) -> tuple[OpCost, ...]:
        return tuple(op for op in self.ops if op.stage == "dense")

    def totals(self, ops: Sequence[OpCost] | None = None):
        ops = self.ops if ops is None else ops
        return {
            "flops": sum(o.flops for o in ops),
            "stream_bytes": sum(o.stream_bytes for o in ops),
            "gather_bytes": sum(o.gather_bytes for o in ops),
            "host_bytes": sum(o.host_bytes for o in ops),
            "weight_bytes": sum(o.weight_bytes for o in ops),
        }


def _mlp_cost(name, stage, level, sizes, dtype_bytes=4.0, seq=False):
    """Per-item FLOPs/bytes of an MLP [in, h1, ..., out]."""
    flops = 2.0 * sum(sizes[i] * sizes[i + 1] for i in range(len(sizes) - 1))
    act = sum(sizes) * dtype_bytes
    weights = sum(sizes[i] * sizes[i + 1] for i in range(len(sizes) - 1)) * dtype_bytes
    return OpCost(name=name, stage=stage, level=level, flops=flops,
                  stream_bytes=act, weight_bytes=weights, sequential=seq)


def profile_recsys(cfg: RecsysConfig, sla_ms: float) -> ModelProfile:
    """Build the operator profile from a RecsysConfig (per ranked item)."""
    emb = cfg.embedding
    ops: list[OpCost] = []
    d = emb.dim
    db = 4.0  # f32 serving

    if cfg.interaction in ("dot", "concat"):
        # one embedding-bag op per table: independent -> all level 0 sparse
        for f in range(emb.num_features):
            p = emb.pooling[f]
            ops.append(OpCost(
                name=f"emb_{f}", stage="sparse", level=0,
                flops=p * d,                      # pooling adds
                gather_bytes=p * d * db,          # random row reads
                host_bytes=p * 8.0,               # int64 ids
                stream_bytes=d * db,              # pooled output write
            ))
    if cfg.n_dense:
        ops.append(dataclasses.replace(
            _mlp_cost("bottom_mlp", "dense", 0, (cfg.n_dense, *cfg.bottom_mlp), db),
            host_bytes=cfg.n_dense * db))
    if cfg.interaction == "dot":
        n_vec = emb.num_features + (1 if cfg.n_dense else 0)
        ops.append(OpCost(
            name="interaction", stage="dense", level=1,
            flops=2.0 * n_vec * n_vec * d,
            stream_bytes=(n_vec * d + n_vec * n_vec) * db,
        ))
        top_in = n_vec * (n_vec - 1) // 2 + (d if cfg.n_dense else 0)
        ops.append(_mlp_cost("top_mlp", "dense", 2, (top_in, *cfg.top_mlp, 1), db))
    elif cfg.interaction == "concat":
        deep_in = emb.num_features * d + cfg.n_dense
        ops.append(_mlp_cost("deep_mlp", "dense", 1, (deep_in, *cfg.top_mlp), db))
        for t in range(cfg.n_tasks):
            ops.append(_mlp_cost(f"tower_{t}", "dense", 2, (cfg.top_mlp[-1], 1), db))
    elif cfg.interaction == "target-attn":
        T = cfg.seq_len
        # history embedding gather (the model's SparseNet)
        ops.append(OpCost(
            name="emb_hist", stage="sparse", level=0,
            flops=T * d, gather_bytes=(T + 1) * d * db, host_bytes=(T + 1) * 8.0,
            stream_bytes=T * d * db,
        ))
        attn_sizes = (4 * d, *cfg.attn_mlp, 1)
        attn = _mlp_cost("attn_unit", "dense", 1, attn_sizes, db)
        ops.append(dataclasses.replace(
            attn, flops=attn.flops * T, stream_bytes=attn.stream_bytes * T))
        if cfg.use_gru:  # DIEN: two GRU passes, sequential over T
            gru_flops = 2 * T * 6.0 * d * d * 2.0
            ops.append(OpCost(
                name="gru", stage="dense", level=1, flops=gru_flops,
                stream_bytes=2 * T * d * db, weight_bytes=12 * d * d * db,
                sequential=True,
            ))
        n_profile = cfg.embedding.num_features - 1
        ops.append(_mlp_cost(
            "top_mlp", "dense", 2, ((2 + n_profile) * d, *cfg.top_mlp, 1), db))
    elif cfg.interaction == "multi-interest":
        T, K = cfg.seq_len, cfg.n_interests
        ops.append(OpCost(
            name="emb_hist", stage="sparse", level=0,
            flops=T * d, gather_bytes=(T + 1) * d * db, host_bytes=(T + 1) * 8.0,
            stream_bytes=T * d * db,
        ))
        routing = cfg.capsule_iters * (2.0 * T * K * d * 2 + K * d)
        ops.append(OpCost(
            name="capsule_routing", stage="dense", level=1,
            flops=2.0 * T * d * d + routing,  # S-map + iterations
            stream_bytes=(T * d + T * K) * db, weight_bytes=d * d * db,
        ))
        head = _mlp_cost("head", "dense", 2, (d, 2 * d, d), db)
        ops.append(dataclasses.replace(
            head, flops=head.flops * K, stream_bytes=head.stream_bytes * K))

    table_gb = emb.bytes(4) / 1e9
    weight_gb = sum(o.weight_bytes for o in ops) / 1e9
    return ModelProfile(name=cfg.name, ops=tuple(ops), table_gb=table_gb,
                        weight_gb=weight_gb, sla_ms=sla_ms)


def profile_lm_decode(cfg: LMConfig, context: int, sla_ms: float) -> ModelProfile:
    """LM serving profile: one item = one decode token against `context` KV."""
    db = 2.0  # bf16 serving
    n_active = cfg.active_param_count()
    weight_bytes = cfg.param_count() * db
    kv_bytes = 2.0 * cfg.n_layers * context * cfg.n_kv_heads * cfg.head_dim * db
    ops = (
        OpCost(name="token_embed", stage="sparse", level=0,
               gather_bytes=cfg.d_model * db, host_bytes=4.0),
        OpCost(name="decode_blocks", stage="dense", level=1,
               flops=2.0 * n_active + 2.0 * 2.0 * cfg.n_layers * context
               * cfg.n_kv_heads * cfg.head_dim,
               stream_bytes=kv_bytes + cfg.n_layers * cfg.d_model * db * 4,
               weight_bytes=weight_bytes),
        OpCost(name="lm_head", stage="dense", level=2,
               flops=2.0 * cfg.d_model * cfg.vocab,
               stream_bytes=cfg.vocab * db),
    )
    return ModelProfile(name=cfg.name, ops=ops, table_gb=0.0,
                        weight_gb=weight_bytes / 1e9, sla_ms=sla_ms)


def profile_gnn(cfg: GNNConfig, sla_ms: float, d_feat: int | None = None) -> ModelProfile:
    """GNN serving profile: one item = one seed node (sampled fanout)."""
    db = 4.0
    d_in = d_feat or cfg.d_feat
    fan = cfg.fanout
    n_gathered = 1 + fan[0] + (fan[0] * fan[1] if len(fan) > 1 else 0)
    ops = [OpCost(
        name="neighbor_gather", stage="sparse", level=0,
        flops=n_gathered * d_in,
        gather_bytes=n_gathered * d_in * db,
        host_bytes=n_gathered * 8.0,
        stream_bytes=n_gathered * d_in * db,
    )]
    d = d_in
    n_nodes_level = [1 + fan[0], 1]
    for i in range(cfg.n_layers):
        mult = n_nodes_level[i] if i < len(n_nodes_level) else 1
        ops.append(OpCost(
            name=f"sage_layer_{i}", stage="dense", level=i + 1,
            flops=mult * 2.0 * 2.0 * d * cfg.d_hidden,
            stream_bytes=mult * (d + cfg.d_hidden) * db,
            weight_bytes=2.0 * d * cfg.d_hidden * db,
        ))
        d = cfg.d_hidden
    ops.append(_mlp_cost("classifier", "dense", cfg.n_layers + 1,
                         (cfg.d_hidden, cfg.n_classes), db))
    return ModelProfile(name=cfg.name, ops=tuple(ops), table_gb=0.0,
                        weight_gb=sum(o.weight_bytes for o in ops) / 1e9,
                        sla_ms=sla_ms)
