"""LP solver for the provisioning problem (paper Eq. 1-3).

minimize    sum_{h,m} x[h,m] * Power[h,m]
subject to  sum_h x[h,m] * QPS[h,m] >= load[m] * (1 + R)   (per workload)
            sum_m x[h,m] <= N[h]                            (per server type)
            x >= 0

Solved with scipy's HiGHS when available (the paper uses an interior-point
solver), else a built-in dense simplex on the same standard form. The
integer repair (`round_and_repair`) floors the relaxation and greedily adds
the cheapest-per-QPS feasible servers until every load constraint holds —
re-checked post-hoc, since the paper does not specify its rounding.
"""
from __future__ import annotations

import numpy as np

try:
    from scipy.optimize import linprog as _scipy_linprog
except Exception:  # pragma: no cover - scipy always present in this env
    _scipy_linprog = None


def solve_relaxation(qps: np.ndarray, power: np.ndarray, load: np.ndarray,
                     avail: np.ndarray, overprovision: float = 0.0) -> np.ndarray | None:
    """qps/power: [H, M]; load: [M]; avail: [H] -> x [H, M] or None."""
    H, M = qps.shape
    c = power.reshape(-1)
    # A_ub x <= b_ub : capacity rows (H) and negated load rows (M)
    A = np.zeros((H + M, H * M))
    b = np.zeros(H + M)
    for h in range(H):
        A[h, h * M : (h + 1) * M] = 1.0
        b[h] = avail[h]
    for m in range(M):
        for h in range(H):
            A[H + m, h * M + m] = -qps[h, m]
        b[H + m] = -load[m] * (1.0 + overprovision)
    if _scipy_linprog is not None:
        r = _scipy_linprog(c, A_ub=A, b_ub=b, bounds=(0, None), method="highs")
        if not r.success:
            return None
        return r.x.reshape(H, M)
    return _simplex(c, A, b, H, M)


def _simplex(c, A, b, H, M):  # pragma: no cover - scipy fallback
    """Big-M dense simplex on A x <= b (with possibly negative b)."""
    n = len(c)
    m = len(b)
    # convert to equalities with slacks; rows with b<0 need artificials
    T = np.hstack([A, np.eye(m), b.reshape(-1, 1)])
    art_rows = [i for i in range(m) if b[i] < 0]
    for i in art_rows:
        T[i] = -T[i]
    n_art = len(art_rows)
    if n_art:
        art = np.zeros((m, n_art))
        for j, i in enumerate(art_rows):
            art[i, j] = 1.0
        T = np.hstack([T[:, :-1], art, T[:, -1:]])
    big_m = 1e7
    cost = np.concatenate([c, np.zeros(m), big_m * np.ones(n_art), [0.0]])
    basis = []
    for i in range(m):
        if i in art_rows:
            basis.append(n + m + art_rows.index(i))
        else:
            basis.append(n + i)
    for _ in range(2000):
        z = cost[basis] @ T[:, :-1] - cost[:-1]
        j = int(np.argmax(z))
        if z[j] <= 1e-9:
            break
        col = T[:, j]
        ratios = np.where(col > 1e-12, T[:, -1] / np.maximum(col, 1e-12), np.inf)
        i = int(np.argmin(ratios))
        if not np.isfinite(ratios[i]):
            return None
        T[i] /= T[i, j]
        for k in range(m):
            if k != i:
                T[k] -= T[k, j] * T[i]
        basis[i] = j
    x = np.zeros(n + m + n_art)
    for i, bi in enumerate(basis):
        x[bi] = T[i, -1]
    if n_art and x[n + m :].sum() > 1e-6:
        return None
    return x[:n].reshape(H, M)


def solve_geo_spill(loads: np.ndarray,
                    qps_by_region: list[np.ndarray],
                    power_by_region: list[np.ndarray],
                    avail_by_region: list[np.ndarray],
                    allowed: dict[tuple[int, int], np.ndarray],
                    link_cap: dict[tuple[int, int], float],
                    rtt_ms: dict[tuple[int, int], float],
                    must_spill: np.ndarray | None = None,
                    overprovision: np.ndarray | float = 0.0,
                    spill_penalty: float = 1e-6):
    """Helix-style geo placement relaxation for one interval (MILP relaxed).

    Joint LP over per-region fractional server counts ``x_r`` [H_r, M] and
    directed spill rates ``s[(i, j)]`` [M] (QPS of workload m originating
    in region i served in region j):

    minimize    sum_r x_r . power_r  +  eps * sum (1 + rtt) * s
    subject to  sum_h x_r[h,m] qps_r[h,m] >= (1+R_r) * served_r[m]
                served_r[m] = loads[r,m] - out_r[m] + in_r[m]
                out_r[m] <= loads[r,m];  out_r[m] >= must_spill[r,m]
                sum_m s[(i,j)][m] <= link_cap[(i,j)]   (per directed link)
                sum_m x_r[h,m] <= avail_r[h]
                s[(i,j)][m] = 0 where not allowed[(i,j)][m]

    ``loads``/``must_spill``: [R, M]; ``allowed`` masks spill by the caller's
    link/RTT/SLA budgets (Helix's "which models are servable from where").
    The tiny RTT-weighted penalty breaks power ties toward local serving
    and the shortest feasible link without distorting the power objective.
    Returns ``(spill, x)`` — ``spill`` keyed like ``allowed``, ``x`` a list
    of [H_r, M] — or ``None`` when scipy is unavailable or the program is
    infeasible (the caller falls back to greedy water-filling).
    """
    if _scipy_linprog is None:  # pragma: no cover - scipy present in CI
        return None
    R, M = loads.shape
    over = np.broadcast_to(np.asarray(overprovision, dtype=float), (R,))
    if must_spill is None:
        must_spill = np.zeros((R, M))
    pairs = sorted(allowed)
    x_off, n_x = [], 0
    for r in range(R):
        x_off.append(n_x)
        n_x += qps_by_region[r].shape[0] * M
    s_off = {p: n_x + k * M for k, p in enumerate(pairs)}
    n_var = n_x + len(pairs) * M

    c = np.zeros(n_var)
    for r in range(R):
        c[x_off[r]:x_off[r] + power_by_region[r].size] = \
            power_by_region[r].reshape(-1)
    for p in pairs:
        c[s_off[p]:s_off[p] + M] = spill_penalty * (1.0 + rtt_ms[p])

    rows, b = [], []

    def add_row(coeffs: dict[int, float], rhs: float) -> None:
        row = np.zeros(n_var)
        for j, v in coeffs.items():
            row[j] += v
        rows.append(row)
        b.append(rhs)

    for r in range(R):
        H_r = qps_by_region[r].shape[0]
        for m in range(M):
            co: dict[int, float] = {}
            for h in range(H_r):
                co[x_off[r] + h * M + m] = -float(qps_by_region[r][h, m])
            for p in pairs:
                if p[0] == r:
                    co[s_off[p] + m] = co.get(s_off[p] + m, 0.0) \
                        - (1.0 + over[r])
                if p[1] == r:
                    co[s_off[p] + m] = co.get(s_off[p] + m, 0.0) \
                        + (1.0 + over[r])
            add_row(co, -float(loads[r, m]) * (1.0 + over[r]))
            out_idx = {s_off[p] + m: 1.0 for p in pairs if p[0] == r}
            if out_idx:
                add_row(out_idx, float(loads[r, m]))
                if must_spill[r, m] > 0:
                    add_row({j: -1.0 for j in out_idx},
                            -float(must_spill[r, m]))
            elif must_spill[r, m] > 0:
                return None  # evacuation ordered but no outgoing link
        for h in range(H_r):
            add_row({x_off[r] + h * M + m: 1.0 for m in range(M)},
                    float(avail_by_region[r][h]))
    for p in pairs:
        add_row({s_off[p] + m: 1.0 for m in range(M)},
                float(link_cap[p]))

    bounds = [(0, None)] * n_var
    for p in pairs:
        mask = np.asarray(allowed[p], dtype=bool)
        for m in range(M):
            if not mask[m]:
                bounds[s_off[p] + m] = (0, 0)
    r_ = _scipy_linprog(c, A_ub=np.array(rows), b_ub=np.array(b),
                        bounds=bounds, method="highs")
    if not r_.success:
        return None
    spill = {p: np.maximum(r_.x[s_off[p]:s_off[p] + M], 0.0) for p in pairs}
    x = [r_.x[x_off[r]:x_off[r] + qps_by_region[r].size]
         .reshape(qps_by_region[r].shape) for r in range(R)]
    return spill, x


def round_and_repair(x: np.ndarray, qps: np.ndarray, power: np.ndarray,
                     load: np.ndarray, avail: np.ndarray,
                     overprovision: float = 0.0) -> np.ndarray | None:
    """Integerize the relaxation: floor, then greedily add the cheapest
    power-per-QPS feasible server until all loads are covered."""
    H, M = qps.shape
    n = np.floor(x + 1e-9).astype(np.int64)
    target = load * (1.0 + overprovision)
    for _ in range(int(avail.sum()) + H * M):
        served = (n * qps).sum(axis=0)
        deficit = target - served
        m = int(np.argmax(deficit))
        if deficit[m] <= 1e-9:
            return n
        # cheapest marginal power per unit of *useful* QPS for workload m
        cand, best_cost = None, np.inf
        used = n.sum(axis=1)
        for h in range(H):
            if used[h] >= avail[h] or qps[h, m] <= 0:
                continue
            cost = power[h, m] / min(qps[h, m], deficit[m])
            if cost < best_cost:
                best_cost, cand = cost, h
        if cand is None:
            return None  # infeasible: not enough capacity
        n[cand, m] += 1
    return None
