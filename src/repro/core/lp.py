"""LP solver for the provisioning problem (paper Eq. 1-3).

minimize    sum_{h,m} x[h,m] * Power[h,m]
subject to  sum_h x[h,m] * QPS[h,m] >= load[m] * (1 + R)   (per workload)
            sum_m x[h,m] <= N[h]                            (per server type)
            x >= 0

Solved with scipy's HiGHS when available (the paper uses an interior-point
solver), else a built-in dense simplex on the same standard form. The
integer repair (`round_and_repair`) floors the relaxation and greedily adds
the cheapest-per-QPS feasible servers until every load constraint holds —
re-checked post-hoc, since the paper does not specify its rounding.
"""
from __future__ import annotations

import numpy as np

try:
    from scipy.optimize import linprog as _scipy_linprog
except Exception:  # pragma: no cover - scipy always present in this env
    _scipy_linprog = None


def solve_relaxation(qps: np.ndarray, power: np.ndarray, load: np.ndarray,
                     avail: np.ndarray, overprovision: float = 0.0) -> np.ndarray | None:
    """qps/power: [H, M]; load: [M]; avail: [H] -> x [H, M] or None."""
    H, M = qps.shape
    c = power.reshape(-1)
    # A_ub x <= b_ub : capacity rows (H) and negated load rows (M)
    A = np.zeros((H + M, H * M))
    b = np.zeros(H + M)
    for h in range(H):
        A[h, h * M : (h + 1) * M] = 1.0
        b[h] = avail[h]
    for m in range(M):
        for h in range(H):
            A[H + m, h * M + m] = -qps[h, m]
        b[H + m] = -load[m] * (1.0 + overprovision)
    if _scipy_linprog is not None:
        r = _scipy_linprog(c, A_ub=A, b_ub=b, bounds=(0, None), method="highs")
        if not r.success:
            return None
        return r.x.reshape(H, M)
    return _simplex(c, A, b, H, M)


def _simplex(c, A, b, H, M):  # pragma: no cover - scipy fallback
    """Big-M dense simplex on A x <= b (with possibly negative b)."""
    n = len(c)
    m = len(b)
    # convert to equalities with slacks; rows with b<0 need artificials
    T = np.hstack([A, np.eye(m), b.reshape(-1, 1)])
    art_rows = [i for i in range(m) if b[i] < 0]
    for i in art_rows:
        T[i] = -T[i]
    n_art = len(art_rows)
    if n_art:
        art = np.zeros((m, n_art))
        for j, i in enumerate(art_rows):
            art[i, j] = 1.0
        T = np.hstack([T[:, :-1], art, T[:, -1:]])
    big_m = 1e7
    cost = np.concatenate([c, np.zeros(m), big_m * np.ones(n_art), [0.0]])
    basis = []
    for i in range(m):
        if i in art_rows:
            basis.append(n + m + art_rows.index(i))
        else:
            basis.append(n + i)
    for _ in range(2000):
        z = cost[basis] @ T[:, :-1] - cost[:-1]
        j = int(np.argmax(z))
        if z[j] <= 1e-9:
            break
        col = T[:, j]
        ratios = np.where(col > 1e-12, T[:, -1] / np.maximum(col, 1e-12), np.inf)
        i = int(np.argmin(ratios))
        if not np.isfinite(ratios[i]):
            return None
        T[i] /= T[i, j]
        for k in range(m):
            if k != i:
                T[k] -= T[k, j] * T[i]
        basis[i] = j
    x = np.zeros(n + m + n_art)
    for i, bi in enumerate(basis):
        x[bi] = T[i, -1]
    if n_art and x[n + m :].sum() > 1e-6:
        return None
    return x[:n].reshape(H, M)


def round_and_repair(x: np.ndarray, qps: np.ndarray, power: np.ndarray,
                     load: np.ndarray, avail: np.ndarray,
                     overprovision: float = 0.0) -> np.ndarray | None:
    """Integerize the relaxation: floor, then greedily add the cheapest
    power-per-QPS feasible server until all loads are covered."""
    H, M = qps.shape
    n = np.floor(x + 1e-9).astype(np.int64)
    target = load * (1.0 + overprovision)
    for _ in range(int(avail.sum()) + H * M):
        served = (n * qps).sum(axis=0)
        deficit = target - served
        m = int(np.argmax(deficit))
        if deficit[m] <= 1e-9:
            return n
        # cheapest marginal power per unit of *useful* QPS for workload m
        cand, best_cost = None, np.inf
        used = n.sum(axis=1)
        for h in range(H):
            if used[h] >= avail[h] or qps[h, m] <= 0:
                continue
            cost = power[h, m] / min(qps[h, m], deficit[m])
            if cost < best_cost:
                best_cost, cand = cost, h
        if cand is None:
            return None  # infeasible: not enough capacity
        n[cand, m] += 1
    return None
