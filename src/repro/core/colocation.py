"""Interference-aware multi-tenant packing (ROADMAP item 2, Hera direction).

Every machine in the base provisioner serves one workload.  This module
lets the offline stage pack *pairs* of complementary tenants onto shared
servers:

- :func:`build_colocation_table` profiles every admissible
  (server, tenant-set) cell — each tenant's solo record dilated by the
  co-resident tenant's measured pressure
  (:func:`repro.core.perfmodel.colocation_dilation`) — with SLA-aware
  admission per tenant: a tenant whose *inflated* p95 would breach its SLA
  is rejected from that packing, and accelerator hosts are bounded by
  their ``AccelSpec.max_colocate`` slots.

- :func:`pack_colocated` improves a single-tenant ``ProvisionResult`` by a
  deterministic greedy merge pass: remove one machine from (h1, m1) and
  one from (h2, m2), add one shared machine of type h serving both
  residual contributions.  A merge is feasible when the shared machine's
  fractional utilization ``need1/qps_c1 + need2/qps_c2 <= 1`` (dilated
  rates) and the pool has a free machine of type h; it is applied only
  when it strictly reduces provisioned power, best-saving first with
  deterministic tie-breaks.  With an empty
  :class:`ColocationTable` the pass is the identity — single-tenant
  packings reproduce the base allocation bitwise.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.cluster import EfficiencyTable, ProvisionResult
from repro.core.devices import DeviceProfile
from repro.core.efficiency import (TABLE_QPS_TOL, default_query_sizes,
                                   profile_colocated)
from repro.core.workload import ModelProfile

# Shared-machine utilization budget for the merge pass: the two tenants'
# fractional loads (at their dilated full-machine rates) may fill at most
# this much of the machine, keeping online tails clear of the SLA edge.
COLOC_PACK_UTIL = 0.85


@dataclasses.dataclass(frozen=True)
class ColoCell:
    """One admissible (server, tenant-set) packing: per-tenant dilated
    full-machine throughput/tail, aligned with ``tenants`` order."""

    server: str
    tenants: tuple[str, ...]       # sorted workload names
    qps: tuple[float, ...]         # dilated full-machine QPS per tenant
    p95_ms: tuple[float, ...]      # dilated tail per tenant
    dilation: tuple[float, ...]
    power_w: float                 # provisioned power (device peak)


@dataclasses.dataclass(frozen=True)
class CoMachine:
    """One shared machine in a packing: per-tenant assigned rates."""

    server: str
    tenants: tuple[str, ...]       # sorted workload names
    rates: tuple[float, ...]       # per-tenant QPS assigned to this machine
    qps: tuple[float, ...]         # per-tenant dilated full-machine QPS
    dilation: tuple[float, ...]    # per-tenant duration inflation (>= 1)
    power_w: float

    def rate_of(self, workload: str) -> float:
        return self.rates[self.tenants.index(workload)]

    def qps_of(self, workload: str) -> float:
        return self.qps[self.tenants.index(workload)]

    def dilation_of(self, workload: str) -> float:
        return self.dilation[self.tenants.index(workload)]


@dataclasses.dataclass(frozen=True)
class ColocationTable:
    """Admissible packings plus the SLA/slot rejections (for reporting)."""

    cells: tuple[ColoCell, ...]
    rejected: tuple[tuple[str, tuple[str, ...], str], ...] = ()

    def cell(self, server: str, tenants: tuple[str, ...]) -> ColoCell | None:
        key = tuple(sorted(tenants))
        for c in self.cells:
            if c.server == server and c.tenants == key:
                return c
        return None


def build_colocation_table(
    profiles: dict[str, ModelProfile],
    servers: dict[str, DeviceProfile],
    query_sizes: np.ndarray | None = None,
    seed: int = 0,
    engine: str = "fast",
    use_cache: bool = True,
    qps_tol: float = TABLE_QPS_TOL,
) -> ColocationTable:
    """Profile every (server, unordered tenant pair) cell with SLA-aware
    admission.  CPU hosts contend on shared memory bandwidth; accelerator
    hosts additionally require a free co-location slot
    (``AccelSpec.max_colocate``)."""
    qs = query_sizes if query_sizes is not None else default_query_sizes()
    names = sorted(profiles)
    cells: list[ColoCell] = []
    rejected: list[tuple[str, tuple[str, ...], str]] = []
    for sname in sorted(servers):
        dev = servers[sname]
        for i, n1 in enumerate(names):
            for n2 in names[i + 1:]:
                tenants = (n1, n2)
                if dev.accel is not None and len(tenants) > dev.accel.max_colocate:
                    rejected.append((sname, tenants, "no co-location slot"))
                    continue
                pairs = []
                breach = None
                for victim, other in ((n1, n2), (n2, n1)):
                    p = profile_colocated(
                        profiles[victim], dev, (profiles[other],), qs,
                        seed=seed, engine=engine, use_cache=use_cache,
                        qps_tol=qps_tol)
                    if p.qps <= 0.0 or p.p95_ms > profiles[victim].sla_ms:
                        breach = (f"{victim}: dilated p95 {p.p95_ms:.2f}ms > "
                                  f"SLA {profiles[victim].sla_ms:.0f}ms")
                        break
                    pairs.append(p)
                if breach is not None:
                    rejected.append((sname, tenants, breach))
                    continue
                cells.append(ColoCell(
                    server=sname, tenants=tenants,
                    qps=tuple(p.qps for p in pairs),
                    p95_ms=tuple(p.p95_ms for p in pairs),
                    dilation=tuple(p.dilation for p in pairs),
                    power_w=dev.peak_power_w,
                ))
    return ColocationTable(cells=tuple(cells), rejected=tuple(rejected))


@dataclasses.dataclass
class ColoProvision:
    """A packing: solo allocation plus shared machines."""

    alloc: np.ndarray                 # [H, M] solo machines (post-merge)
    co_machines: tuple[CoMachine, ...]
    provisioned_power_w: float
    capacity: int                     # activated machines incl. shared ones
    feasible: bool
    merges: int                       # merge moves applied


def co_served(co_machines: tuple[CoMachine, ...],
              workloads: tuple[str, ...]) -> np.ndarray:
    """Per-workload QPS ([M]) carried by the shared machines."""
    out = np.zeros(len(workloads))
    for c in co_machines:
        for name, rate in zip(c.tenants, c.rates):
            out[workloads.index(name)] += rate
    return out


def pack_colocated(
    table: EfficiencyTable,
    coloc: ColocationTable,
    load: np.ndarray,
    base: ProvisionResult,
    overprovision: float = 0.0,
) -> ColoProvision:
    """Greedy merge-improvement of `base` using the admissible packings.

    Deterministic: candidate moves are enumerated in index order and the
    best saving wins with ``(h, h1, h2, m1, m2)`` ascending tie-breaks.
    Returns the base allocation unchanged (``merges == 0``) when no merge
    is feasible or `coloc` has no cells.
    """
    H, M = table.qps.shape
    if not base.feasible:
        return ColoProvision(base.alloc.copy(), (), base.provisioned_power_w,
                             base.capacity, False, 0)
    target = np.asarray(load, np.float64) * (1.0 + overprovision)
    alloc = base.alloc.astype(np.int64).copy()
    machines: list[CoMachine] = []
    names = table.workloads

    def used_of(h: int) -> int:
        return int(alloc[h].sum()) + sum(
            1 for c in machines if c.server == table.servers[h])

    merges = 0
    while coloc.cells:
        served = (alloc * table.qps).sum(axis=0) + co_served(tuple(machines),
                                                             names)
        slack = served - target
        best = None  # (saving, -h, -h1, -h2, -m1, -m2, move) — max() picks it
        for m1 in range(M):
            for m2 in range(m1 + 1, M):
                key = tuple(sorted((names[m1], names[m2])))
                for h1 in range(H):
                    if alloc[h1, m1] <= 0:
                        continue
                    need1 = max(table.qps[h1, m1] - slack[m1], 0.0)
                    for h2 in range(H):
                        if alloc[h2, m2] <= 0:
                            continue
                        need2 = max(table.qps[h2, m2] - slack[m2], 0.0)
                        for h in range(H):
                            cell = coloc.cell(table.servers[h], key)
                            if cell is None:
                                continue
                            qc1 = cell.qps[cell.tenants.index(names[m1])]
                            qc2 = cell.qps[cell.tenants.index(names[m2])]
                            if qc1 <= 0.0 or qc2 <= 0.0:
                                continue
                            if need1 / qc1 + need2 / qc2 > \
                                    COLOC_PACK_UTIL + 1e-9:
                                continue
                            free = int(table.avail[h]) - used_of(h) \
                                + (h == h1) + (h == h2)
                            if free < 1:
                                continue
                            saving = float(table.power[h1, m1]
                                           + table.power[h2, m2]
                                           - cell.power_w)
                            if saving <= 1e-9:
                                continue
                            cand = (saving, -h, -h1, -h2, -m1, -m2,
                                    (h, h1, h2, m1, m2, need1, need2, cell))
                            if best is None or cand[:6] > best[:6]:
                                best = cand
        if best is None:
            break
        h, h1, h2, m1, m2, need1, need2, cell = best[6]
        alloc[h1, m1] -= 1
        alloc[h2, m2] -= 1
        rates = {names[m1]: need1, names[m2]: need2}
        machines.append(CoMachine(
            server=table.servers[h], tenants=cell.tenants,
            rates=tuple(rates[t] for t in cell.tenants),
            qps=cell.qps, dilation=cell.dilation, power_w=cell.power_w))
        merges += 1
    power = float((alloc * table.power).sum()) + sum(c.power_w
                                                     for c in machines)
    capacity = int(alloc.sum()) + len(machines)
    return ColoProvision(alloc, tuple(machines), power, capacity, True,
                         merges)
