"""Analytic execution of an operator profile on a device profile.

This is the evaluation engine behind Hercules' offline profiling: it turns
(model profile, device profile, scheduling configuration) into stage service
times that the discrete-event serving simulator composes into latency-bounded
throughput, and into component utilizations for the power model.

CPU threads: ``o`` operator workers (one physical core each, paper §II-B);
elapsed time per dependency level is the list-scheduling bound
``max(longest op, level work / o)`` which reproduces the idle-cycle growth of
paper Fig. 5. Memory bandwidth is shared across co-located threads; NMP
DIMMs multiply *gather* bandwidth only (rank-parallel SLS offload).

Accelerators: a two-resource pipeline — host link (PCIe: input/ids/psum
transfer) and engine (kernels). Co-location overlaps one thread's link phase
with another's engine phase (this is where Baymax/query-fusion wins come
from, Fig. 6/7); batch efficiency saturates as eff(b) = b/(b + b_half).
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Sequence

from repro.core.devices import DeviceProfile
from repro.core.workload import ModelProfile, OpCost

# Engine batch-efficiency half-point: batch at which an accelerator kernel
# reaches 50% of peak (GEMM-shaped ops).
B_HALF = 48.0
# Sequential (recurrent) ops cap achievable engine efficiency.
SEQ_EFF = 0.15
# Per-thread LLC/prefetcher interference on CPUs (paper Fig. 4 territory).
CPU_INTERFERENCE = 0.05
# Batch-split (intra-op data parallel) efficiency across operator workers.
WORKER_EFF = 0.85
# Per-core achievable bandwidth (limited outstanding misses): a thread of o
# workers cannot pull more than o x these, no matter its share of the bus.
CORE_STREAM_GBS = 14.0
CORE_GATHER_GBS = 4.0


@dataclasses.dataclass(frozen=True)
class CpuAlloc:
    threads: int          # m co-located inference threads
    workers: int          # o operator workers (cores) per thread

    @property
    def cores(self) -> int:
        return self.threads * self.workers


def cpu_stage_time(
    ops: Sequence[OpCost],
    batch: int,
    workers: int,
    device: DeviceProfile,
    active_threads: int,
    nmp_offload: bool = True,
) -> float:
    """Elapsed seconds for one thread to run `ops` on a `batch` of items.

    Compute scales with op-workers via list scheduling
    (max(longest op, level work / o)); memory traffic is bounded by the
    thread's *bandwidth share* regardless of workers — extra workers cannot
    mint bandwidth, which is what keeps total system throughput conserved
    across (m × o) splits for memory-bound models (paper Fig. 4's modest,
    not multiplicative, wins)."""
    cpu, mem = device.cpu, device.mem
    core_rate = cpu.gflops_per_core * 1e9
    interference = 1.0 + CPU_INTERFERENCE * max(active_threads - 1, 0)
    share = max(active_threads, 1)
    w = max(workers, 1)
    nmp = mem.nmp_factor if nmp_offload else 1.0
    stream_bw = min(
        mem.bw_gbs * 1e9 / share,
        CORE_STREAM_GBS * 1e9 * w,
    ) / interference
    gather_bw = min(
        mem.bw_gbs * 1e9 * mem.gather_eff / share,
        CORE_GATHER_GBS * 1e9 * w,
    ) * nmp / interference
    levels: dict[int, list[OpCost]] = defaultdict(list)
    for op in ops:
        levels[op.level].append(op)
    total = 0.0
    w = max(workers, 1)
    for lvl in sorted(levels):
        lops = levels[lvl]
        # Batched ops split the batch across workers (intra-op data
        # parallelism at WORKER_EFF); independent ops also spread across
        # workers — the binding term is total level work / effective cores.
        cts = [op.flops * batch / core_rate for op in lops]
        eff_w = 1.0 + (w - 1.0) * WORKER_EFF
        t_compute = max(max(cts) / eff_w, sum(cts) / (w * WORKER_EFF + (1 - WORKER_EFF)))
        t_mem = (
            sum(op.stream_bytes * batch + op.weight_bytes for op in lops) / stream_bw
            + sum(op.gather_bytes for op in lops) * batch / gather_bw
        )
        total += max(t_compute, t_mem)
    return total


def cpu_stage_core_seconds(
    ops: Sequence[OpCost], batch: int, device: DeviceProfile
) -> float:
    """Busy core-seconds (for utilization/power accounting)."""
    core_rate = device.cpu.gflops_per_core * 1e9
    return sum(op.flops * batch / core_rate for op in ops)


def accel_engine_time(
    ops: Sequence[OpCost], batch: int, device: DeviceProfile
) -> float:
    """Engine-resident seconds for one batched kernel sequence."""
    acc = device.accel
    assert acc is not None
    total = 0.0
    for op in ops:
        eff = batch / (batch + B_HALF)
        if op.sequential:
            eff = min(eff, SEQ_EFF)
        t_compute = op.flops * batch / (acc.peak_gflops * 1e9 * max(eff, 1e-3))
        t_stream = (op.stream_bytes * batch + op.weight_bytes) / (acc.hbm_gbs * 1e9)
        t_gather = op.gather_bytes * batch / (acc.hbm_gbs * 1e9 * acc.gather_eff)
        total += max(t_compute, t_stream, t_gather) + acc.kernel_overhead_us * 1e-6
    return total


def accel_link_time(host_bytes_per_item: float, batch: int, device: DeviceProfile) -> float:
    acc = device.accel
    assert acc is not None
    return host_bytes_per_item * batch / (acc.link_gbs * 1e9) + 10e-6  # DMA setup


# -- multi-tenant interference (Hera direction, ROADMAP item 2) --------------
#
# Co-located tenants share the server's bottleneck resources: stream and
# gather memory bandwidth on CPU hosts, the engine and the host link on
# accelerator hosts.  The contention model is deliberately *measured at the
# solo operating point*: each tenant's pressure on a resource is the fraction
# of that resource its solo profile consumes at its solo peak QPS, and a
# victim's duration tables dilate by a queueing-shaped penalty
# ``1 + sens_r * alpha_r * u / (1 - u)`` summed over resources — exact 1.0
# for an empty co-set, monotone non-decreasing in every pressure component.

PRESSURE_RESOURCES = ("stream", "gather", "engine", "link")

# Per-resource contention coefficients (alpha_r): how strongly a unit of
# co-tenant utilization on the resource inflates a fully-sensitive victim.
COLOC_ALPHA = {
    "stream": 0.9,   # shared DDR stream bandwidth (CPU hosts)
    "gather": 0.7,   # random-gather bandwidth (SLS contention)
    "engine": 0.6,   # accel co-location slots (MPS-style time sharing)
    "link": 0.5,     # host<->device link (PCIe DMA contention)
}
# Cap on the aggregate co-tenant utilization entering the 1/(1-u) law — a
# saturated co-tenant dilates a lot, not infinitely.
COLOC_UTIL_CAP = 0.85


def _resource_seconds(profile: ModelProfile, device: DeviceProfile) -> dict:
    """Seconds per item each shared resource spends on `profile`'s totals."""
    t = profile.totals()
    mem = device.mem
    out = {
        "stream": t["stream_bytes"] / (mem.bw_gbs * 1e9),
        "gather": t["gather_bytes"] / (
            mem.bw_gbs * 1e9 * mem.gather_eff * mem.nmp_factor),
        "engine": 0.0,
        "link": 0.0,
    }
    acc = device.accel
    if acc is not None:
        out["engine"] = t["flops"] / (acc.peak_gflops * 1e9)
        out["link"] = t["host_bytes"] / (acc.link_gbs * 1e9)
    return out


def tenant_pressure(profile: ModelProfile, device: DeviceProfile,
                    qps: float, mean_query_items: float) -> dict:
    """Shared-resource utilization fractions a tenant exerts on `device`
    at an operating point of ``qps`` queries/s (``mean_query_items`` items
    per query, paper Fig. 2b sample mean).  Values are >= 0 and not capped
    here — :func:`colocation_dilation` applies ``COLOC_UTIL_CAP``."""
    items_s = max(qps, 0.0) * max(mean_query_items, 0.0)
    sec = _resource_seconds(profile, device)
    return {r: sec[r] * items_s for r in PRESSURE_RESOURCES}


def resource_sensitivity(profile: ModelProfile, device: DeviceProfile) -> dict:
    """Victim-side sensitivity shares: the fraction of `profile`'s
    resource-seconds bound to each shared resource on `device` (sparse
    models weight gather, dense models weight stream/engine).  Sums to 1
    for a non-empty profile."""
    sec = _resource_seconds(profile, device)
    total = sum(sec.values())
    if total <= 0.0:
        return {r: 0.0 for r in PRESSURE_RESOURCES}
    return {r: sec[r] / total for r in PRESSURE_RESOURCES}


def colocation_dilation(profile: ModelProfile, device: DeviceProfile,
                        co_pressures: Sequence[dict]) -> float:
    """Multiplicative duration dilation (>= 1.0) that the co-resident
    tenants' aggregate pressure imposes on `profile` when sharing `device`.

    Exactly 1.0 for an empty co-set (single-tenant packings reproduce the
    solo tables bitwise); monotone non-decreasing in every pressure
    component (adding a tenant never shortens durations)."""
    pressures = list(co_pressures)
    if not pressures:
        return 1.0
    sens = resource_sensitivity(profile, device)
    d = 1.0
    for r in PRESSURE_RESOURCES:
        u = sum(max(p.get(r, 0.0), 0.0) for p in pressures)
        u = min(u, COLOC_UTIL_CAP)
        if u > 0.0:
            d += COLOC_ALPHA[r] * sens[r] * u / (1.0 - u)
    return d


@dataclasses.dataclass(frozen=True)
class PowerModel:
    """Average power from component utilizations (paper: RAPL + nvidia-smi)."""

    device: DeviceProfile

    def average_power(self, util: dict) -> float:
        """util keys: cores (0-1), mem (0-1), engine (0-1), link (0-1)."""
        d = self.device
        p = d.cpu.idle_w + (d.cpu.tdp_w - d.cpu.idle_w) * util.get("cores", 0.0)
        p += d.mem.idle_w + (d.mem.tdp_w - d.mem.idle_w) * util.get("mem", 0.0)
        if d.accel:
            p += d.accel.idle_w + (d.accel.tdp_w - d.accel.idle_w) * util.get(
                "engine", 0.0
            )
        return p

    def provisioned_power(self) -> float:
        return self.device.peak_power_w


def memory_utilization(
    profile_bytes_per_s: float, device: DeviceProfile
) -> float:
    return min(profile_bytes_per_s / (device.mem.bw_gbs * 1e9), 1.0)
