"""HW-aware model partition (paper §IV-B, Figure 10).

Splits a workload's operator profile into placed stages under the device's
memory-capacity constraint:

- ``cpu_model``      : whole graph G_m on host threads (model-based).
- ``cpu_sd``         : SparseNet pool + DenseNet pool on host, pipelined
                       through an intermediate queue (Fig. 10b).
- ``accel_sd``       : G_s on host, G_d on accelerator (Fig. 10c); link
                       carries the pooled [F, D] embeddings.
- ``accel_hot``      : locality-aware split (Fig. 10a/d): hot embedding rows
                       + G_d on the accelerator, cold rows pooled on host and
                       shipped as a partial sum (Psum) over the link.
- ``accel_full``     : entire model on the accelerator (small models only —
                       this is the Baymax/DeepRecSys regime and why they
                       "do not scale to large recommendation models").

hot_frac is sized from the capacity budget per co-located thread:
(capacity / m − dense weights − margin) / table_size (paper: "capacity
budget per thread = memory capacity / model co-location").
"""
from __future__ import annotations

import dataclasses

from repro.core.devices import DeviceProfile
from repro.core.workload import ModelProfile, OpCost


@dataclasses.dataclass(frozen=True)
class Placement:
    """Operator placement + link traffic for one partition plan."""

    plan: str                      # cpu_model | cpu_sd | accel_sd | accel_hot | accel_full
    host_sparse: tuple[OpCost, ...]
    host_dense: tuple[OpCost, ...]
    accel_ops: tuple[OpCost, ...]
    link_bytes_per_item: float     # host -> accel transfer per ranked item
    hot_frac: float = 0.0
    pipelined: bool = False        # host-side S-D pipelining

    @property
    def uses_accel(self) -> bool:
        return bool(self.accel_ops)

    @property
    def host_ops(self) -> tuple[OpCost, ...]:
        return self.host_sparse + self.host_dense


HBM_MARGIN_GB = 1.0  # activations/workspace reserve per accelerator


def hot_capacity_frac(profile: ModelProfile, device: DeviceProfile, colocate: int) -> float:
    """Fraction of the embedding table that fits on the accelerator."""
    acc = device.accel
    if acc is None or profile.table_gb <= 0:
        return 0.0
    budget = acc.capacity_gb / max(colocate, 1) - profile.weight_gb - HBM_MARGIN_GB
    return max(0.0, min(1.0, budget / profile.table_gb))


def _scale_gather(ops, factor):
    return tuple(
        dataclasses.replace(
            op,
            gather_bytes=op.gather_bytes * factor,
            flops=op.flops * factor if op.stage == "sparse" else op.flops,
            host_bytes=op.host_bytes * factor,
        )
        for op in ops
    )


def sparse_output_bytes(profile: ModelProfile) -> float:
    """Pooled SparseNet output per item (the S-D intermediate payload)."""
    return sum(op.stream_bytes for op in profile.sparse_ops)


def sparse_id_bytes(profile: ModelProfile) -> float:
    return sum(op.host_bytes for op in profile.sparse_ops)


def dense_input_bytes(profile: ModelProfile) -> float:
    return sum(op.host_bytes for op in profile.dense_ops)


def enumerate_placements(
    profile: ModelProfile, device: DeviceProfile, colocate: int = 1
) -> list[Placement]:
    """All feasible partition plans for (workload, server, co-location)."""
    s_ops, d_ops = profile.sparse_ops, profile.dense_ops
    out = [
        Placement("cpu_model", s_ops, d_ops, (), 0.0),
    ]
    if s_ops and d_ops:
        out.append(Placement("cpu_sd", s_ops, d_ops, (), 0.0, pipelined=True))
    acc = device.accel
    if acc is None:
        return out

    total_gb = profile.table_gb + profile.weight_gb
    weights_fit = profile.weight_gb + HBM_MARGIN_GB <= acc.capacity_gb / max(colocate, 1)
    if not weights_fit:
        return out

    if s_ops:
        # Fig 10c: sparse on host, dense on accel; link = pooled embeddings
        # + the dense features.
        out.append(Placement(
            "accel_sd", s_ops, (), d_ops,
            link_bytes_per_item=sparse_output_bytes(profile) + dense_input_bytes(profile),
        ))
        hf = hot_capacity_frac(profile, device, colocate)
        if 0.0 < hf < 1.0:
            hit = profile.hot_hit_rate(hf)
            accel_sparse = _scale_gather(s_ops, hit)
            host_cold = _scale_gather(s_ops, 1.0 - hit)
            # Fig 10d link: cold Psum [F, D] + hot ids + dense features.
            link = (
                sparse_output_bytes(profile)
                + sparse_id_bytes(profile) * hit
                + dense_input_bytes(profile)
            )
            out.append(Placement(
                "accel_hot", host_cold, (), accel_sparse + d_ops,
                link_bytes_per_item=link, hot_frac=hf,
            ))
        if hf >= 1.0 or total_gb + HBM_MARGIN_GB <= acc.capacity_gb / max(colocate, 1):
            out.append(Placement(
                "accel_full", (), (), s_ops + d_ops,
                link_bytes_per_item=sparse_id_bytes(profile) + dense_input_bytes(profile),
            ))
    else:
        out.append(Placement(
            "accel_full", (), (), d_ops,
            link_bytes_per_item=dense_input_bytes(profile),
        ))
    return out
