"""Persistent offline-profiling cache (``artifacts/profiles/*.json``).

Hercules' provisioning pipeline keeps re-deriving the same efficiency
tuples: every ``build_table`` call, cluster benchmark and example re-runs
the gradient search for each (workload, server) cell, and the baseline
sweeps re-run their grid scans.  This module caches one record per
profiled cell, keyed by everything that determines the result:

- profiling kind (``hercules`` search, ``deeprecsys``/``baymax`` baseline),
- workload fingerprint (name + operator profile + footprints + SLA),
- server fingerprint (the full device profile),
- search seed, o-grid, batch grid, power budget,
- the query-size sample (hashed bytes), and
- ``ENGINE_VERSION`` — bump it when simulator semantics change to
  invalidate every cached profile at once.

Cache files are ``<workload>__<server>__<kind>__<key12>.json`` so stale
entries for a cell are overwritten in place and ``invalidate()`` can
target a workload/server subset.  A record whose stored key does not
match (hash collision on the truncated filename, hand-edited file) is
recomputed, never trusted.
"""
from __future__ import annotations

import hashlib
import json
import pathlib

import numpy as np

ARTIFACTS = pathlib.Path(__file__).resolve().parents[3] / "artifacts"
PROFILE_DIR = ARTIFACTS / "profiles"

# Bump to invalidate all cached profiles when the simulator/search changes
# in a result-affecting way.
ENGINE_VERSION = 2


def _fingerprint(obj) -> str:
    return hashlib.sha1(repr(obj).encode()).hexdigest()


def pair_key(
    kind: str,
    profile,
    device,
    query_sizes: np.ndarray,
    seed: int = 0,
    o_grid=None,
    batch_grid=None,
    power_budget_w: float | None = None,
    qps_tol: float = 0.0,
    engine: str = "fast",
    coloc: tuple | None = None,
) -> str:
    """Deterministic key for one profiled (workload, server) cell."""
    h = hashlib.sha1()
    payload = {
        "v": ENGINE_VERSION,
        "kind": kind,
        "workload": _fingerprint((profile.name, profile.ops, profile.table_gb,
                                  profile.weight_gb, profile.sla_ms,
                                  profile.zipf_alpha)),
        "server": _fingerprint(device),
        "seed": int(seed),
        "o_grid": list(o_grid) if o_grid else None,
        "batch_grid": list(batch_grid) if batch_grid else None,
        "power_budget_w": power_budget_w,
    }
    if qps_tol:  # keep bit-exact (tol=0) keys unchanged across this addition
        payload["qps_tol"] = float(qps_tol)
    if engine != "fast":  # reference-engine records must never satisfy a
        payload["engine"] = engine  # fast lookup or vice versa
    if coloc:  # co-located records key on the co-tenant set; solo (coloc
        payload["coloc"] = list(coloc)  # empty/None) keys stay unchanged

    h.update(json.dumps(payload, sort_keys=True).encode())
    h.update(np.ascontiguousarray(np.asarray(query_sizes, np.int64)).tobytes())
    return h.hexdigest()


def _path(kind: str, workload: str, server: str, key: str,
          root: pathlib.Path | None = None) -> pathlib.Path:
    root = root or PROFILE_DIR
    safe = "".join(c if c.isalnum() or c in "-._" else "_" for c in f"{workload}__{server}")
    return root / f"{safe}__{kind}__{key[:12]}.json"


def load(kind: str, workload: str, server: str, key: str,
         root: pathlib.Path | None = None) -> dict | None:
    """Cached record for this key, or None (missing / stale / corrupt)."""
    p = _path(kind, workload, server, key, root)
    if not p.exists():
        return None
    try:
        blob = json.loads(p.read_text())
    except (json.JSONDecodeError, OSError):
        return None
    if blob.get("key") != key:
        return None
    return blob.get("record")


def store(kind: str, workload: str, server: str, key: str, record: dict,
          root: pathlib.Path | None = None) -> pathlib.Path:
    p = _path(kind, workload, server, key, root)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(
        {"key": key, "kind": kind, "workload": workload, "server": server,
         "engine_version": ENGINE_VERSION, "record": record}, indent=1))
    return p


def invalidate(workload: str | None = None, server: str | None = None,
               root: pathlib.Path | None = None) -> int:
    """Delete cached profiles (all, or a workload/server subset); returns
    the number of files removed."""
    root = root or PROFILE_DIR
    if not root.exists():
        return 0
    removed = 0
    for p in root.glob("*.json"):
        try:
            blob = json.loads(p.read_text())
        except (json.JSONDecodeError, OSError):
            blob = {}
        if workload is not None and blob.get("workload") != workload:
            continue
        if server is not None and blob.get("server") != server:
            continue
        p.unlink()
        removed += 1
    return removed
