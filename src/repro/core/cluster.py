"""Cluster-level scheduling (paper §IV-C, §VI-C).

Three provisioning policies over the workload-classification table
(efficiency tuples):

- ``nh``       : heterogeneity-oblivious — activates servers in a random
                 order until each workload's load is covered.
- ``greedy``   : Paragon/Quasar-style — per workload, activates the
                 best-ranked (QPS/W) available server type; contention
                 between workloads for the same type is resolved in
                 arbitrary (workload-index) order, which is exactly the
                 failure mode of Fig. 8.
- ``hercules`` : the paper's contribution — global LP (Eq. 1-3) minimizing
                 total provisioned power, then integer repair.

``provision_day`` runs a policy across a diurnal trace and reports the
capacity (activated servers) and provisioned-power time series.  It
re-solves every interval statelessly; :class:`StatefulProvisioner` is the
online form — allocations carry over between intervals, allocation deltas
incur model-load/drain delays, a hysteresis band suppresses re-solving
(and thrashing) while the load stays near what the fleet was sized for,
and mid-day server failures shrink the pool and force an elastic
re-provision (`repro.serving.cluster_runtime` drives actual query streams
through the result).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.lp import round_and_repair, solve_relaxation


@dataclasses.dataclass(frozen=True)
class EfficiencyTable:
    """Workload classification (paper Fig. 9b): offline-profiled tuples."""

    servers: tuple[str, ...]       # H server-type names
    workloads: tuple[str, ...]     # M workload names
    qps: np.ndarray                # [H, M] latency-bounded throughput
    power: np.ndarray              # [H, M] provisioned power budget (W)
    avail: np.ndarray              # [H] available servers N_h

    def fleet_capacity(self) -> np.ndarray:
        """Best-case fleet QPS per workload ([M]): every available server
        of every type serving that workload alone.  Scenario load fractions
        (and the benchmarks' comparison fraction) are declared relative to
        this bound."""
        return (self.avail[:, None] * self.qps).sum(axis=0)

    def ranking(self, m: int, metric: str = "qps_per_watt") -> list[int]:
        """Server-type ranking for workload m (greedy scheduler input)."""
        if metric == "qps_per_watt":
            score = self.qps[:, m] / np.maximum(self.power[:, m], 1e-9)
        else:
            score = self.qps[:, m]
        return list(np.argsort(-score))

    def with_availability(self, availability: dict[str, int]) -> "EfficiencyTable":
        """The same profiled tuples under a different server pool.

        Availability only enters provisioning through the ``avail`` column
        — the per-pair (QPS, Power) tuples are properties of the hardware,
        not of how many machines a site owns — so a region (or a what-if
        sweep) that differs from an already-profiled topology only in pool
        sizes can reuse the table without re-profiling
        (``repro.serving.scenarios._bundle`` takes this fast path).
        Every server type in the table must be given a count."""
        missing = [s for s in self.servers if s not in availability]
        if missing:
            raise KeyError(
                f"with_availability: no count for server type(s) "
                f"{', '.join(missing)}")
        return dataclasses.replace(
            self, avail=np.array([availability[s] for s in self.servers],
                                 np.int64))


@dataclasses.dataclass
class ProvisionResult:
    alloc: np.ndarray              # [H, M] integer server counts
    provisioned_power_w: float
    capacity: int                  # total activated servers
    feasible: bool

    @staticmethod
    def infeasible(H: int, M: int) -> "ProvisionResult":
        return ProvisionResult(np.zeros((H, M), np.int64), 0.0, 0, False)


def _power_capacity(table: EfficiencyTable, alloc: np.ndarray) -> tuple[float, int]:
    return float((alloc * table.power).sum()), int(alloc.sum())


def provision_nh(table: EfficiencyTable, load: np.ndarray,
                 overprovision: float = 0.0, seed: int = 0) -> ProvisionResult:
    rng = np.random.default_rng(seed)
    H, M = table.qps.shape
    alloc = np.zeros((H, M), np.int64)
    remaining = table.avail.astype(np.int64).copy()
    target = load * (1.0 + overprovision)
    served = np.zeros(M)
    # random server activation order, round-robin over workloads needing load
    pool = np.repeat(np.arange(H), remaining)
    rng.shuffle(pool)
    for h in pool:
        deficit = target - served
        if (deficit <= 1e-9).all():
            break
        m = int(rng.choice(np.flatnonzero(deficit > 1e-9)))
        if table.qps[h, m] <= 0:
            continue
        alloc[h, m] += 1
        served[m] += table.qps[h, m]
    if ((target - served) > 1e-9).any():
        return ProvisionResult.infeasible(H, M)
    p, c = _power_capacity(table, alloc)
    return ProvisionResult(alloc, p, c, True)


def provision_greedy(table: EfficiencyTable, load: np.ndarray,
                     overprovision: float = 0.0,
                     metric: str = "qps_per_watt") -> ProvisionResult:
    H, M = table.qps.shape
    alloc = np.zeros((H, M), np.int64)
    remaining = table.avail.astype(np.int64).copy()
    target = load * (1.0 + overprovision)
    for m in range(M):  # arbitrary workload order: the Fig. 8 deficiency
        need = target[m]
        for h in table.ranking(m, metric):
            while need > 1e-9 and remaining[h] > 0 and table.qps[h, m] > 0:
                alloc[h, m] += 1
                remaining[h] -= 1
                need -= table.qps[h, m]
            if need <= 1e-9:
                break
        if need > 1e-9:
            return ProvisionResult.infeasible(H, M)
    p, c = _power_capacity(table, alloc)
    return ProvisionResult(alloc, p, c, True)


def provision_hercules(table: EfficiencyTable, load: np.ndarray,
                       overprovision: float = 0.0) -> ProvisionResult:
    """LP relaxation + integer repair; since rounding can regress past the
    greedy integer solution on small instances, return the cheaper of the
    two feasible allocations (the LP optimum is a lower bound on both)."""
    H, M = table.qps.shape
    candidates: list[ProvisionResult] = []
    x = solve_relaxation(table.qps, table.power, load, table.avail, overprovision)
    if x is not None:
        n = round_and_repair(x, table.qps, table.power, load, table.avail,
                             overprovision)
        if n is not None:
            p, c = _power_capacity(table, n)
            candidates.append(ProvisionResult(n, p, c, True))
    g = provision_greedy(table, load, overprovision)
    if g.feasible:
        candidates.append(g)
    if not candidates:
        return ProvisionResult.infeasible(H, M)
    return min(candidates, key=lambda r: r.provisioned_power_w)


POLICIES = {
    "nh": provision_nh,
    "greedy": provision_greedy,
    "hercules": provision_hercules,
}


# ---------------------------------------------------------------------------
# stateful online provisioning
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TransitionConfig:
    """Allocation-transition model for online (stateful) provisioning.

    A newly activated server must load model weights + embedding tables
    before it serves (``model_load_s``); a deactivated server drains its
    in-flight/handoff traffic for ``drain_s`` while still drawing power.
    With ``drain_s >= model_load_s`` transitions are make-before-break: the
    outgoing servers cover the load until the incoming ones are warm.
    ``hysteresis`` is the relative load band around the last provisioned
    point inside which the previous allocation is held (no re-solve, no
    churn) as long as it still covers the target.  ``feedback_boost`` is
    the extra relative headroom a re-solve provisions when the *achieved*
    tail violated the SLA in the previous interval (``tail_ok=False`` fed
    to :meth:`StatefulProvisioner.step`): offered load alone cannot see a
    backlog that queueing has already built, so the feedback both vetoes
    the hysteresis hold and sizes the fleet above the offered load to
    drain it.
    """

    interval_s: float = 900.0      # provisioning interval (24h / 96)
    model_load_s: float = 120.0    # weight/table load before serving starts
    drain_s: float = 150.0         # post-deactivation drain (power still drawn)
    hysteresis: float = 0.10       # relative load band that holds the alloc
    feedback_boost: float = 0.10   # extra headroom on a tail-violation resolve


@dataclasses.dataclass
class StatefulStep:
    """One interval of stateful provisioning."""

    alloc: np.ndarray              # [H, M] serving allocation this interval
    power_w: float                 # provisioned power incl. draining servers
    capacity: int                  # steady-state activated servers
    feasible: bool
    resolved: bool                 # False = hysteresis hold (no re-solve)
    added: np.ndarray              # [H, M] newly activated (loading) servers
    removed: np.ndarray            # [H, M] deactivated (draining) servers
    # multi-tenant packing (empty when the provisioner has no colocation
    # table — the defaults keep single-tenant behavior bitwise)
    coalloc: tuple = ()            # CoMachine shared machines this interval
    co_added: tuple = ()           # newly activated shared machines
    co_removed: tuple = ()         # draining shared machines

    @property
    def churn(self) -> int:
        return int(self.added.sum() + self.removed.sum()) + \
            len(self.co_added) + len(self.co_removed)


def _co_diff(new: tuple, old: tuple) -> tuple[tuple, tuple]:
    """Multiset diff of shared-machine tuples: (added, removed)."""
    remaining = list(old)
    added = []
    for c in new:
        if c in remaining:
            remaining.remove(c)
        else:
            added.append(c)
    return tuple(added), tuple(remaining)


class StatefulProvisioner:
    """Online cluster provisioning with allocation state across intervals.

    Differences from the stateless ``provision_day`` loop:

    - the previous allocation is *held* while every workload's load stays
      within the hysteresis band of the load it was sized for and the
      allocation still covers the (over-provisioned) target — single-
      interval load wiggles no longer flap servers on and off;
    - when the policy does re-solve, the allocation delta is reported as
      ``added``/``removed`` and charged for transitions: added servers draw
      power immediately but only start serving after ``model_load_s``;
      removed servers keep drawing power for ``drain_s`` while they drain;
    - ``fail()`` removes servers from the live pool *and* from the current
      allocation (elastic N_h), forcing a re-solve at the next step;
    - ``step(load, tail_ok=False)`` is the achieved-tail feedback path
      (the cluster runtime reports whether the previous interval met its
      SLAs): a violation vetoes the hysteresis hold — offered load looks
      fine while carried backlog is eating the tail — and the re-solve
      provisions ``feedback_boost`` extra headroom to drain the backlog.
    """

    def __init__(self, table: EfficiencyTable, policy: str = "hercules",
                 overprovision: float = 0.05,
                 transitions: TransitionConfig | None = None, seed: int = 0,
                 colocation=None):
        self.table = table
        self.policy = policy
        self.overprovision = overprovision
        self.transitions = transitions or TransitionConfig()
        self.seed = seed
        # optional repro.core.colocation.ColocationTable: when set, every
        # re-solve is followed by the interference-aware merge pass and the
        # step carries shared machines in ``coalloc``
        self.colocation = colocation
        self.avail = table.avail.astype(np.int64).copy()
        self._rng = np.random.default_rng(seed + 101)
        H, M = table.qps.shape
        self.alloc = np.zeros((H, M), np.int64)
        self.coalloc: tuple = ()
        self._provisioned_load: np.ndarray | None = None
        self._force = True          # first step / after failure: must solve
        self._warm = True           # day starts warm: no load delay at t=0
        self.t = 0
        self.n_resolves = 0
        self.n_holds = 0
        self.n_tail_resolves = 0    # re-solves forced by tail feedback

    # -- failures ------------------------------------------------------------

    def fail(self, h: int, count: int = 1) -> list[tuple[int, int]]:
        """Remove up to ``count`` servers of type ``h`` from the pool.

        The victim is a uniformly random machine of that type, so a serving
        instance dies with probability ``serving / available`` (and its
        workload is drawn proportionally to the allocation); idle spares
        absorb the rest.  Returns the affected ``(h, m)`` cells (one entry
        per failed *serving* instance) and forces a re-solve at the next
        :meth:`step`.
        """
        victims: list = []
        for _ in range(count):
            if self.avail[h] <= 0:
                break
            co_h = [c for c in self.coalloc
                    if c.server == self.table.servers[h]]
            serving = int(self.alloc[h].sum()) + len(co_h)
            hit_serving = self._rng.random() < serving / self.avail[h]
            self.avail[h] -= 1
            if (hit_serving or serving > self.avail[h]) and serving > 0:
                if co_h:
                    # shared machines are victimized first (deterministic;
                    # a no-op when coalloc is empty, which keeps the
                    # single-tenant victim stream bitwise unchanged); one
                    # failed shared machine yields a victim for every
                    # tenant packed on it, so the entry is the CoMachine
                    c = co_h[0]
                    i = next(j for j, x in enumerate(self.coalloc)
                             if x is c)
                    self.coalloc = self.coalloc[:i] + self.coalloc[i + 1:]
                    victims.append(c)
                else:
                    m = int(self._rng.choice(
                        len(self.alloc[h]), p=self.alloc[h] / serving))
                    self.alloc[h, m] -= 1
                    victims.append((h, m))
        self._force = True
        return victims

    # -- stepping ------------------------------------------------------------

    def _covers(self, target: np.ndarray) -> bool:
        served = (self.alloc * self.table.qps).sum(axis=0)
        for c in self.coalloc:
            for name, rate in zip(c.tenants, c.rates):
                served[self.table.workloads.index(name)] += rate
        return bool((served >= target - 1e-9).all())

    def _within_band(self, load: np.ndarray) -> bool:
        if self._provisioned_load is None:
            return False
        ref = np.maximum(self._provisioned_load, 1e-9)
        return bool((np.abs(load - self._provisioned_load) <=
                     self.transitions.hysteresis * ref).all())

    def _solve(self, load: np.ndarray) -> tuple[ProvisionResult, tuple]:
        table = EfficiencyTable(self.table.servers, self.table.workloads,
                                self.table.qps, self.table.power, self.avail)
        fn = POLICIES[self.policy]
        kwargs: dict = {"overprovision": self.overprovision}
        if self.policy == "nh":
            kwargs["seed"] = self.seed + self.t
        r = fn(table, load, **kwargs)
        if self.colocation is None or not r.feasible:
            return r, ()
        from repro.core.colocation import pack_colocated
        packed = pack_colocated(table, self.colocation, load, r,
                                overprovision=self.overprovision)
        if packed.merges == 0:
            return r, ()
        return ProvisionResult(packed.alloc, packed.provisioned_power_w,
                               packed.capacity, True), packed.co_machines

    def step(self, load: np.ndarray, tail_ok: bool = True) -> StatefulStep:
        load = np.asarray(load, dtype=np.float64)
        target = load * (1.0 + self.overprovision)
        cfg = self.transitions
        hold = (not self._force) and tail_ok and self._within_band(load) and \
            self._covers(target)
        if hold:
            self.n_holds += 1
            alloc_new, co_new, feasible = self.alloc, self.coalloc, True
        else:
            boost = 1.0 if tail_ok else 1.0 + cfg.feedback_boost
            r, co_new = self._solve(load * boost)
            self.n_resolves += 1
            if not tail_ok:
                self.n_tail_resolves += 1
                if not r.feasible and boost > 1.0:
                    # the extra headroom is not available on this pool, but
                    # the offered load itself may still be provisionable —
                    # serve that rather than freezing on a stale allocation
                    r, co_new = self._solve(load)
            feasible = r.feasible
            if r.feasible:
                alloc_new = r.alloc
                self._provisioned_load = load.copy()
            else:
                # best effort: keep serving on whatever survives
                alloc_new, co_new = self.alloc, self.coalloc
                if not tail_ok and self._covers(target):
                    # only the boosted target overshot the pool; the real
                    # one is still covered, so the day itself is not lost
                    feasible = True
            self._force = False
        added = np.maximum(alloc_new - self.alloc, 0)
        removed = np.maximum(self.alloc - alloc_new, 0)
        co_added, co_removed = _co_diff(co_new, self.coalloc)
        if self._warm:  # day starts with a warm fleet: no load transient
            added = np.zeros_like(added)
            co_added = ()
            self._warm = False
        drain_frac = min(cfg.drain_s / cfg.interval_s, 1.0)
        power = float((alloc_new * self.table.power).sum())
        power += sum(c.power_w for c in co_new)
        power += float((removed * self.table.power).sum()) * drain_frac
        power += sum(c.power_w for c in co_removed) * drain_frac
        self.alloc = alloc_new
        self.coalloc = co_new
        self.t += 1
        return StatefulStep(
            alloc=alloc_new.copy(), power_w=power,
            capacity=int(alloc_new.sum()) + len(co_new),
            feasible=feasible, resolved=not hold, added=added, removed=removed,
            coalloc=co_new, co_added=co_added, co_removed=co_removed,
        )


def provision_day(
    table: EfficiencyTable,
    traces: np.ndarray,            # [M, T] per-workload diurnal loads
    policy: str = "hercules",
    overprovision: float = 0.05,
    seed: int = 0,
) -> dict:
    """Run a policy across the day; returns power/capacity time series."""
    M, T = traces.shape
    fn = POLICIES[policy]
    power = np.zeros(T)
    capacity = np.zeros(T, np.int64)
    allocs = []
    feasible = True
    for t in range(T):
        kwargs = {"overprovision": overprovision}
        if policy == "nh":
            kwargs["seed"] = seed + t
        r = fn(table, traces[:, t], **kwargs)
        feasible &= r.feasible
        power[t] = r.provisioned_power_w
        capacity[t] = r.capacity
        allocs.append(r.alloc)
    return {
        "power_w": power,
        "capacity": capacity,
        "allocs": np.stack(allocs),
        "feasible": feasible,
        "peak_power_w": float(power.max()),
        "avg_power_w": float(power.mean()),
        "peak_capacity": int(capacity.max()),
        "avg_capacity": float(capacity.mean()),
    }
