"""Cluster-level scheduling (paper §IV-C, §VI-C).

Three provisioning policies over the workload-classification table
(efficiency tuples):

- ``nh``       : heterogeneity-oblivious — activates servers in a random
                 order until each workload's load is covered.
- ``greedy``   : Paragon/Quasar-style — per workload, activates the
                 best-ranked (QPS/W) available server type; contention
                 between workloads for the same type is resolved in
                 arbitrary (workload-index) order, which is exactly the
                 failure mode of Fig. 8.
- ``hercules`` : the paper's contribution — global LP (Eq. 1-3) minimizing
                 total provisioned power, then integer repair.

``provision_day`` runs a policy across a diurnal trace and reports the
capacity (activated servers) and provisioned-power time series.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.lp import round_and_repair, solve_relaxation


@dataclasses.dataclass(frozen=True)
class EfficiencyTable:
    """Workload classification (paper Fig. 9b): offline-profiled tuples."""

    servers: tuple[str, ...]       # H server-type names
    workloads: tuple[str, ...]     # M workload names
    qps: np.ndarray                # [H, M] latency-bounded throughput
    power: np.ndarray              # [H, M] provisioned power budget (W)
    avail: np.ndarray              # [H] available servers N_h

    def ranking(self, m: int, metric: str = "qps_per_watt") -> list[int]:
        """Server-type ranking for workload m (greedy scheduler input)."""
        if metric == "qps_per_watt":
            score = self.qps[:, m] / np.maximum(self.power[:, m], 1e-9)
        else:
            score = self.qps[:, m]
        return list(np.argsort(-score))


@dataclasses.dataclass
class ProvisionResult:
    alloc: np.ndarray              # [H, M] integer server counts
    provisioned_power_w: float
    capacity: int                  # total activated servers
    feasible: bool

    @staticmethod
    def infeasible(H: int, M: int) -> "ProvisionResult":
        return ProvisionResult(np.zeros((H, M), np.int64), 0.0, 0, False)


def _power_capacity(table: EfficiencyTable, alloc: np.ndarray) -> tuple[float, int]:
    return float((alloc * table.power).sum()), int(alloc.sum())


def provision_nh(table: EfficiencyTable, load: np.ndarray,
                 overprovision: float = 0.0, seed: int = 0) -> ProvisionResult:
    rng = np.random.default_rng(seed)
    H, M = table.qps.shape
    alloc = np.zeros((H, M), np.int64)
    remaining = table.avail.astype(np.int64).copy()
    target = load * (1.0 + overprovision)
    served = np.zeros(M)
    # random server activation order, round-robin over workloads needing load
    pool = np.repeat(np.arange(H), remaining)
    rng.shuffle(pool)
    for h in pool:
        deficit = target - served
        if (deficit <= 1e-9).all():
            break
        m = int(rng.choice(np.flatnonzero(deficit > 1e-9)))
        if table.qps[h, m] <= 0:
            continue
        alloc[h, m] += 1
        served[m] += table.qps[h, m]
    if ((target - served) > 1e-9).any():
        return ProvisionResult.infeasible(H, M)
    p, c = _power_capacity(table, alloc)
    return ProvisionResult(alloc, p, c, True)


def provision_greedy(table: EfficiencyTable, load: np.ndarray,
                     overprovision: float = 0.0,
                     metric: str = "qps_per_watt") -> ProvisionResult:
    H, M = table.qps.shape
    alloc = np.zeros((H, M), np.int64)
    remaining = table.avail.astype(np.int64).copy()
    target = load * (1.0 + overprovision)
    for m in range(M):  # arbitrary workload order: the Fig. 8 deficiency
        need = target[m]
        for h in table.ranking(m, metric):
            while need > 1e-9 and remaining[h] > 0 and table.qps[h, m] > 0:
                alloc[h, m] += 1
                remaining[h] -= 1
                need -= table.qps[h, m]
            if need <= 1e-9:
                break
        if need > 1e-9:
            return ProvisionResult.infeasible(H, M)
    p, c = _power_capacity(table, alloc)
    return ProvisionResult(alloc, p, c, True)


def provision_hercules(table: EfficiencyTable, load: np.ndarray,
                       overprovision: float = 0.0) -> ProvisionResult:
    """LP relaxation + integer repair; since rounding can regress past the
    greedy integer solution on small instances, return the cheaper of the
    two feasible allocations (the LP optimum is a lower bound on both)."""
    H, M = table.qps.shape
    candidates: list[ProvisionResult] = []
    x = solve_relaxation(table.qps, table.power, load, table.avail, overprovision)
    if x is not None:
        n = round_and_repair(x, table.qps, table.power, load, table.avail,
                             overprovision)
        if n is not None:
            p, c = _power_capacity(table, n)
            candidates.append(ProvisionResult(n, p, c, True))
    g = provision_greedy(table, load, overprovision)
    if g.feasible:
        candidates.append(g)
    if not candidates:
        return ProvisionResult.infeasible(H, M)
    return min(candidates, key=lambda r: r.provisioned_power_w)


POLICIES = {
    "nh": provision_nh,
    "greedy": provision_greedy,
    "hercules": provision_hercules,
}


def provision_day(
    table: EfficiencyTable,
    traces: np.ndarray,            # [M, T] per-workload diurnal loads
    policy: str = "hercules",
    overprovision: float = 0.05,
    seed: int = 0,
) -> dict:
    """Run a policy across the day; returns power/capacity time series."""
    M, T = traces.shape
    fn = POLICIES[policy]
    power = np.zeros(T)
    capacity = np.zeros(T, np.int64)
    allocs = []
    feasible = True
    for t in range(T):
        kwargs = {"overprovision": overprovision}
        if policy == "nh":
            kwargs["seed"] = seed + t
        r = fn(table, traces[:, t], **kwargs)
        feasible &= r.feasible
        power[t] = r.provisioned_power_w
        capacity[t] = r.capacity
        allocs.append(r.alloc)
    return {
        "power_w": power,
        "capacity": capacity,
        "allocs": np.stack(allocs),
        "feasible": feasible,
        "peak_power_w": float(power.max()),
        "avg_power_w": float(power.mean()),
        "peak_capacity": int(capacity.max()),
        "avg_capacity": float(capacity.mean()),
    }
