"""State-of-the-art baseline task schedulers (paper §III).

- DeepRecSys [ISCA'20]: CPU model-based scheduling with the full thread
  count (one core per inference thread) exploring only the batch dimension
  P(D); on accelerators, no model co-location and no query fusion.
- Baymax [ASPLOS'16]: accelerator model co-location (searches m) but no
  query fusion.

Both receive the same HW-aware partition Hercules uses (the paper runs all
Fig. 14 evaluations at production scale with the locality-aware partition),
so the deltas isolate the *scheduling-space* contribution.
"""
from __future__ import annotations

import numpy as np

from repro.core.devices import DeviceProfile
from repro.core.gradient_search import BATCH_GRID
from repro.core.partition import enumerate_placements
from repro.core.workload import ModelProfile
from repro.serving.simulator import SchedConfig, max_sustainable_qps


def _best_accel_placement(profile, device):
    pls = enumerate_placements(profile, device)
    for plan in ("accel_full", "accel_hot", "accel_sd"):
        for p in pls:
            if p.plan == plan:
                return p
    return None


def deeprecsys_qps(profile: ModelProfile, device: DeviceProfile,
                   query_sizes: np.ndarray, seed: int = 0):
    """DeepRecSys: CPU -> fixed cores x 1 threads, P(D) sweep;
    accel -> single thread, no fusion, P(D) sweep."""
    best = (0.0, None, None)
    if device.has_accel:
        pl = _best_accel_placement(profile, device)
        if pl is not None:
            for d in BATCH_GRID:
                sched = SchedConfig(batch=d, m=1, o=1, fuse=False)
                qps, res = max_sustainable_qps(pl, device, sched,
                                               profile.sla_ms, query_sizes,
                                               seed=seed)
                if qps > best[0]:
                    best = (qps, sched, pl)
    else:
        pl = enumerate_placements(profile, device)[0]  # cpu_model
        m = device.cpu.cores
        for d in BATCH_GRID:
            sched = SchedConfig(batch=d, m=m, o=1)
            qps, res = max_sustainable_qps(pl, device, sched, profile.sla_ms,
                                           query_sizes, seed=seed)
            if qps > best[0]:
                best = (qps, sched, pl)
    return best


def baymax_qps(profile: ModelProfile, device: DeviceProfile,
               query_sizes: np.ndarray, seed: int = 0):
    """Baymax: accelerator co-location (sweep m), no query fusion."""
    if not device.has_accel:
        return deeprecsys_qps(profile, device, query_sizes, seed)
    pl = _best_accel_placement(profile, device)
    if pl is None:
        return deeprecsys_qps(profile, device, query_sizes, seed)
    best = (0.0, None, None)
    for m in range(1, device.accel.max_colocate + 1):
        for d in (256, 1024):  # batch cap only bounds the split granularity
            sched = SchedConfig(batch=d, m=m, o=1, fuse=False)
            qps, res = max_sustainable_qps(pl, device, sched, profile.sla_ms,
                                           query_sizes, seed=seed)
            if qps > best[0]:
                best = (qps, sched, pl)
    return best
