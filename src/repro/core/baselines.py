"""State-of-the-art baseline task schedulers (paper §III).

- DeepRecSys [ISCA'20]: CPU model-based scheduling with the full thread
  count (one core per inference thread) exploring only the batch dimension
  P(D); on accelerators, no model co-location and no query fusion.
- Baymax [ASPLOS'16]: accelerator model co-location (searches m) but no
  query fusion.

Both receive the same HW-aware partition Hercules uses (the paper runs all
Fig. 14 evaluations at production scale with the locality-aware partition),
so the deltas isolate the *scheduling-space* contribution.

Each sweep shares one :class:`~repro.serving.simulator.SimCache` across all
its grid points (common random numbers + shared duration tables), and can
persist its result through :mod:`repro.core.profile_cache` so benchmarks
and cluster provisioning stop re-running identical baseline scans.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import profile_cache
from repro.core.devices import DeviceProfile
from repro.core.gradient_search import BATCH_GRID
from repro.core.partition import enumerate_placements
from repro.core.workload import ModelProfile
from repro.serving.simulator import SchedConfig, SimCache, max_sustainable_qps

BAYMAX_BATCH_CAPS = (256, 1024)  # batch cap only bounds the split granularity


def _best_accel_placement(profile, device):
    pls = enumerate_placements(profile, device)
    for plan in ("accel_full", "accel_hot", "accel_sd"):
        for p in pls:
            if p.plan == plan:
                return p
    return None


def _placement_by_plan(profile, device, plan):
    for p in enumerate_placements(profile, device):
        if p.plan == plan:
            return p
    return None


def _cached(kind, profile, device, query_sizes, seed, grid):
    key = profile_cache.pair_key(kind, profile, device, query_sizes,
                                 seed=seed, batch_grid=grid)
    rec = profile_cache.load(kind, profile.name, device.name, key)
    if rec is None:
        return key, None
    sched = SchedConfig(**rec["sched"]) if rec["sched"] else None
    pl = _placement_by_plan(profile, device, rec["plan"]) if rec["plan"] else None
    return key, (rec["qps"], sched, pl)


def _store(kind, profile, device, key, best):
    qps, sched, pl = best
    profile_cache.store(kind, profile.name, device.name, key, {
        "qps": qps,
        "sched": dataclasses.asdict(sched) if sched else None,
        "plan": pl.plan if pl else None,
    })


def deeprecsys_qps(profile: ModelProfile, device: DeviceProfile,
                   query_sizes: np.ndarray, seed: int = 0,
                   engine: str = "fast", use_cache: bool = False):
    """DeepRecSys: CPU -> fixed cores x 1 threads, P(D) sweep;
    accel -> single thread, no fusion, P(D) sweep."""
    if use_cache:
        key, hit = _cached("deeprecsys", profile, device, query_sizes, seed,
                           BATCH_GRID)
        if hit is not None:
            return hit
    cache = SimCache(query_sizes, seed)
    best = (0.0, None, None)
    if device.has_accel:
        pl = _best_accel_placement(profile, device)
        if pl is not None:
            for d in BATCH_GRID:
                sched = SchedConfig(batch=d, m=1, o=1, fuse=False)
                qps, res = max_sustainable_qps(pl, device, sched,
                                               profile.sla_ms, query_sizes,
                                               seed=seed, cache=cache,
                                               engine=engine)
                if qps > best[0]:
                    best = (qps, sched, pl)
    else:
        pl = enumerate_placements(profile, device)[0]  # cpu_model
        m = device.cpu.cores
        for d in BATCH_GRID:
            sched = SchedConfig(batch=d, m=m, o=1)
            qps, res = max_sustainable_qps(pl, device, sched, profile.sla_ms,
                                           query_sizes, seed=seed, cache=cache,
                                           engine=engine)
            if qps > best[0]:
                best = (qps, sched, pl)
    if use_cache:
        _store("deeprecsys", profile, device, key, best)
    return best


def baymax_qps(profile: ModelProfile, device: DeviceProfile,
               query_sizes: np.ndarray, seed: int = 0,
               engine: str = "fast", use_cache: bool = False):
    """Baymax: accelerator co-location (sweep m), no query fusion."""
    if not device.has_accel:
        return deeprecsys_qps(profile, device, query_sizes, seed,
                              engine=engine, use_cache=use_cache)
    pl = _best_accel_placement(profile, device)
    if pl is None:
        return deeprecsys_qps(profile, device, query_sizes, seed,
                              engine=engine, use_cache=use_cache)
    if use_cache:
        key, hit = _cached("baymax", profile, device, query_sizes, seed,
                           BAYMAX_BATCH_CAPS)
        if hit is not None:
            return hit
    cache = SimCache(query_sizes, seed)
    best = (0.0, None, None)
    for m in range(1, device.accel.max_colocate + 1):
        for d in BAYMAX_BATCH_CAPS:
            sched = SchedConfig(batch=d, m=m, o=1, fuse=False)
            qps, res = max_sustainable_qps(pl, device, sched, profile.sla_ms,
                                           query_sizes, seed=seed, cache=cache,
                                           engine=engine)
            if qps > best[0]:
                best = (qps, sched, pl)
    if use_cache:
        _store("baymax", profile, device, key, best)
    return best
