"""Offline profiling stage: build the workload-classification table.

For every (workload, server-type) pair, run the gradient-based task-
scheduling search and record the efficiency tuple (QPS_{m,h}, Power_{m,h})
— paper Fig. 9(b). The provisioned power budget recorded is the server's
peak power envelope (what the datacenter must budget when the server is
activated), while the measured average power at peak QPS is kept for the
energy-efficiency (QPS/W) rankings of Fig. 15.

Profiled pairs persist through :mod:`repro.core.profile_cache`
(``artifacts/profiles/*.json``, keyed by workload/server fingerprints,
seed, grids and the query-size sample), so cluster provisioning, examples
and benchmarks re-search a cell only when something that affects its
result changed; ``build_table(cache=False)`` forces recomputation and
``profile_cache.invalidate()`` clears the store.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib

import numpy as np

from repro.core import profile_cache
from repro.core.cluster import EfficiencyTable
from repro.core.devices import DEFAULT_AVAILABILITY, SERVER_TYPES, DeviceProfile
from repro.core.gradient_search import BATCH_GRID, SearchResult, gradient_search
from repro.core.workload import ModelProfile

ARTIFACTS = pathlib.Path(__file__).resolve().parents[3] / "artifacts"

# Relative throughput tolerance for the bisection early-stop in provisioning-
# table builds.  The cluster LP consumes each cell as one aggregate QPS
# number and the diurnal loads carry >= 5% over-provision headroom, so a 1%
# one-sided bracket error is noise there — and it saves the final bisection
# probes of every (workload, server) search.  Everywhere results are compared
# bit-exactly (engine equivalence tests, BENCH_search.json) the default
# stays ``qps_tol=0`` (see docs/cluster_serving.md).
TABLE_QPS_TOL = 0.01


def default_query_sizes(n: int = 600, seed: int = 0) -> np.ndarray:
    """Paper Fig. 2b query-size distribution."""
    r = np.random.default_rng(seed)
    return np.clip(r.lognormal(np.log(64), 1.1, n).astype(np.int64), 1, 1024)


@dataclasses.dataclass
class ProfiledPair:
    workload: str
    server: str
    qps: float
    avg_power_w: float
    provisioned_power_w: float
    plan: str
    m: int
    d: int
    o: int
    sd_sparse: int
    p95_ms: float
    evals: int
    space_size: int
    # Multi-tenant interference dilation baked into qps/p95_ms (1.0 = solo;
    # see perfmodel.colocation_dilation). Solo records omit/keep the default
    # so every pre-existing cached profile loads unchanged.
    dilation: float = 1.0


def profile_pair(profile: ModelProfile, device: DeviceProfile,
                 query_sizes: np.ndarray | None = None, seed: int = 0,
                 engine: str = "fast", use_cache: bool = True,
                 o_grid: tuple[int, ...] | None = None,
                 qps_tol: float = TABLE_QPS_TOL) -> ProfiledPair:
    qs = query_sizes if query_sizes is not None else default_query_sizes()
    key = None
    if use_cache:
        key = profile_cache.pair_key("hercules", profile, device, qs,
                                     seed=seed, o_grid=o_grid,
                                     batch_grid=BATCH_GRID, qps_tol=qps_tol,
                                     engine=engine)
        rec = profile_cache.load("hercules", profile.name, device.name, key)
        if rec is not None:
            return ProfiledPair(**rec)
    r: SearchResult = gradient_search(profile, device, qs, seed=seed,
                                      o_grid=o_grid, engine=engine,
                                      qps_tol=qps_tol)
    s = r.sched
    pair = ProfiledPair(
        workload=profile.name, server=device.name, qps=r.qps,
        avg_power_w=r.power_w, provisioned_power_w=device.peak_power_w,
        plan=r.placement.plan, m=s.m, d=s.batch, o=s.o, sd_sparse=s.sd_sparse,
        p95_ms=r.p95_ms, evals=r.evals, space_size=r.space_size,
    )
    if use_cache:
        profile_cache.store("hercules", profile.name, device.name, key,
                            dataclasses.asdict(pair))
    return pair


def derated_device(device: DeviceProfile, co_pressures) -> DeviceProfile:
    """The device as a co-located victim sees it: every shared resource's
    bandwidth/throughput scaled by ``1 - u`` where ``u`` is the co-resident
    tenants' aggregate pressure on that resource (capped at
    ``perfmodel.COLOC_UTIL_CAP``).  Per-core outstanding-miss limits are
    per-thread properties and are *not* derated — contention lives on the
    shared bus / engine / link."""
    from repro.core import perfmodel

    u = {r: min(sum(max(p.get(r, 0.0), 0.0) for p in co_pressures),
                perfmodel.COLOC_UTIL_CAP)
         for r in perfmodel.PRESSURE_RESOURCES}
    mem = device.mem
    # gather bandwidth is modeled as bw_gbs * gather_eff, so the gather
    # derate is applied on top of (divided by) the stream derate
    mem2 = dataclasses.replace(
        mem, bw_gbs=mem.bw_gbs * (1.0 - u["stream"]),
        gather_eff=mem.gather_eff * (1.0 - u["gather"])
        / max(1.0 - u["stream"], 1e-9))
    acc2 = device.accel
    if acc2 is not None:
        # MPS-style slot time-sharing slows kernels and HBM alike; the host
        # link is a separately contended resource
        acc2 = dataclasses.replace(
            acc2, peak_gflops=acc2.peak_gflops * (1.0 - u["engine"]),
            hbm_gbs=acc2.hbm_gbs * (1.0 - u["engine"]),
            link_gbs=acc2.link_gbs * (1.0 - u["link"]))
    return dataclasses.replace(device, mem=mem2, accel=acc2)


def profile_colocated(profile: ModelProfile, device: DeviceProfile,
                      co_profiles: tuple[ModelProfile, ...],
                      query_sizes: np.ndarray | None = None, seed: int = 0,
                      engine: str = "fast", use_cache: bool = True,
                      o_grid: tuple[int, ...] | None = None,
                      qps_tol: float = TABLE_QPS_TOL) -> ProfiledPair:
    """Profile `profile` on `device` with `co_profiles` co-resident.

    Each co-tenant's pressure on the shared resources is measured at its
    *fair-share* operating point (its solo peak QPS divided by the number
    of tenants sharing the machine,
    :func:`repro.core.perfmodel.tenant_pressure`); the victim is then
    re-searched on the contention-derated device (:func:`derated_device`),
    so the co-located record is a real latency-bounded operating point —
    its ``p95_ms`` meets the victim's SLA whenever the search is feasible,
    and ``qps == 0`` marks an inadmissible packing.  ``dilation`` is the
    resulting duration inflation ``solo_qps / coloc_qps`` (clamped >= 1 so
    adding a tenant never shortens durations).  Cached under a coloc-keyed
    entry (solo cache entries are untouched); an empty co-set returns the
    solo record bit-identically.
    """
    from repro.core import perfmodel
    from repro.core.gradient_search import gradient_search

    qs = query_sizes if query_sizes is not None else default_query_sizes()
    base = profile_pair(profile, device, qs, seed=seed, engine=engine,
                        use_cache=use_cache, o_grid=o_grid, qps_tol=qps_tol)
    if not co_profiles:
        return base
    co_fps = tuple(
        profile_cache._fingerprint((co.name, co.ops, co.table_gb,
                                    co.weight_gb, co.sla_ms, co.zipf_alpha))
        for co in co_profiles)
    key = None
    if use_cache:
        key = profile_cache.pair_key(
            "hercules", profile, device, qs, seed=seed, o_grid=o_grid,
            batch_grid=BATCH_GRID, qps_tol=qps_tol, engine=engine,
            coloc=co_fps)
        rec = profile_cache.load("hercules", profile.name, device.name, key)
        if rec is not None:
            return ProfiledPair(**rec)
    mean_items = float(np.mean(qs))
    share = 1.0 / (len(co_profiles) + 1)
    pressures = []
    for co in co_profiles:
        co_base = profile_pair(co, device, qs, seed=seed, engine=engine,
                               use_cache=use_cache, o_grid=o_grid,
                               qps_tol=qps_tol)
        pressures.append(perfmodel.tenant_pressure(
            co, device, co_base.qps * share, mean_items))
    r = gradient_search(profile, derated_device(device, pressures), qs,
                        seed=seed, o_grid=o_grid, engine=engine,
                        qps_tol=qps_tol)
    qps_c = min(r.qps, base.qps)
    dil = base.qps / qps_c if qps_c > 0.0 else float("inf")
    s = r.sched
    pair = dataclasses.replace(
        base, qps=qps_c, p95_ms=r.p95_ms, avg_power_w=r.power_w,
        plan=r.placement.plan, m=s.m, d=s.batch, o=s.o,
        sd_sparse=s.sd_sparse, evals=r.evals, space_size=r.space_size,
        dilation=dil)
    if use_cache:
        profile_cache.store("hercules", profile.name, device.name, key,
                            dataclasses.asdict(pair))
    return pair


def build_table(
    profiles: dict[str, ModelProfile],
    servers: dict[str, DeviceProfile] | None = None,
    availability: dict[str, int] | None = None,
    cache: bool | str = True,
    query_sizes: np.ndarray | None = None,
    verbose: bool = False,
    seed: int = 0,
    engine: str = "fast",
    qps_tol: float = TABLE_QPS_TOL,
) -> tuple[EfficiencyTable, dict]:
    """Profile all pairs (cached per pair); returns the table + raw records.

    ``cache``: truthy -> hit/update the persistent per-pair profile cache;
    a string additionally writes the aggregate records to
    ``artifacts/<cache>`` for inspection (legacy location).

    Table builds run the throughput bisection with ``qps_tol`` early-stop
    (default 1% — tolerable for provisioning, ROADMAP item); pass
    ``qps_tol=0.0`` for bit-exact cells.
    """
    servers = servers or SERVER_TYPES
    availability = availability or DEFAULT_AVAILABILITY
    qs = query_sizes if query_sizes is not None else default_query_sizes()
    records: dict[str, dict] = {}
    for wname, prof in profiles.items():
        for sname, dev in servers.items():
            pair = profile_pair(prof, dev, qs, seed=seed, engine=engine,
                                use_cache=bool(cache), qps_tol=qps_tol)
            records[f"{wname}|{sname}"] = dataclasses.asdict(pair)
            if verbose:
                print(f"profiled {wname}|{sname}: qps={pair.qps:.0f} "
                      f"plan={pair.plan}", flush=True)
    if isinstance(cache, str):
        agg = ARTIFACTS / cache
        agg.parent.mkdir(parents=True, exist_ok=True)
        agg.write_text(json.dumps(records, indent=1))

    snames = list(servers)
    wnames = list(profiles)
    qps = np.zeros((len(snames), len(wnames)))
    power = np.zeros_like(qps)
    for i, s in enumerate(snames):
        for j, w in enumerate(wnames):
            rec = records[f"{w}|{s}"]
            qps[i, j] = rec["qps"]
            power[i, j] = rec["provisioned_power_w"]
    table = EfficiencyTable(
        servers=tuple(snames), workloads=tuple(wnames), qps=qps, power=power,
        avail=np.array([availability[s] for s in snames], np.int64),
    )
    return table, records
