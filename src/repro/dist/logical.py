"""Logical-axis sharding annotations (MaxText/Flax-linen style, pared down).

Model code never names mesh axes directly — it annotates arrays with
*logical* axes ("batch", "seq", "heads", "vocab", "expert", "nodes", ...)
via :func:`constrain`.  The launcher binds logical names to mesh axes with
:func:`axis_rules`; the same model code runs un-annotated on a single
device (every helper here is a no-op outside a binding context), which is
what keeps the smoke tests and the 512-chip dry-run on one code path.

The binding is tracked per-thread at *trace* time: ``axis_rules`` is
entered around ``jax.jit``/tracing, not captured inside the jaxpr, so a
cell can be lowered under different meshes without retouching model code.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_STATE = threading.local()


def _context():
    """The innermost (mesh, rules) binding, or None."""
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def axis_rules(mesh, rules: dict):
    """Bind logical axis names to mesh axes for the enclosed trace.

    ``rules`` maps logical name -> mesh axis name, tuple of mesh axis names
    (e.g. ``("pod", "data")`` for multi-pod data parallelism), or None
    (replicate).  Nesting is allowed; the innermost binding wins.
    """
    prev = _context()
    _STATE.ctx = (mesh, dict(rules))
    try:
        yield
    finally:
        _STATE.ctx = prev


def current_mesh():
    """The mesh of the active binding, or None."""
    ctx = _context()
    return None if ctx is None else ctx[0]


def current_rules() -> dict | None:
    """The logical->mesh rules of the active binding, or None."""
    ctx = _context()
    return None if ctx is None else ctx[1]


def resolve(axes) -> P:
    """Resolve a tuple of logical names (or None) to a mesh PartitionSpec.

    Unbound logical names resolve to None (replicated) so model code can
    annotate axes that only some meshes shard.
    """
    ctx = _context()
    rules = {} if ctx is None else ctx[1]
    return P(*(None if a is None else rules.get(a) for a in axes))


def constrain(x, axes):
    """``with_sharding_constraint(x, axes)`` under a binding; identity without.

    ``axes``: one logical name (or None) per array dimension.
    """
    ctx = _context()
    if ctx is None:
        return x
    mesh, _ = ctx
    if len(axes) != x.ndim:
        raise ValueError(
            f"constrain: {len(axes)} logical axes for rank-{x.ndim} array"
        )
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, resolve(axes))
    )


def bound_axes(name: str) -> tuple:
    """Mesh axes bound to one logical name, normalized to a tuple.

    () when there is no binding context, the name is unbound, or it is
    bound to None — callers can treat "replicated" uniformly. This is how
    repro.dist.decode discovers the "kv_seq" axes of a sequence-sharded
    KV cache.
    """
    ctx = _context()
    if ctx is None:
        return ()
    axes = ctx[1].get(name)
    if axes is None:
        return ()
    if isinstance(axes, str):
        return (axes,)
    return tuple(axes)


def model_axis_name():
    """Mesh axis bound to the logical "model" axis, or None.

    This is the switch the embedding/MoE/loss layers use to pick between
    single-device semantics and the sharded dataflow.
    """
    ctx = _context()
    if ctx is None:
        return None
    return ctx[1].get("model")
