"""Vocab-parallel cross-entropy and mixed-precision gradient casting.

With the lm_head column-sharded (logical "vocab" axis), the naive CE
recipe would all-gather the [B, T, V] logits onto every shard.  Writing
the gold-logit selection as a one-hot contraction keeps everything local:
each shard reduces its vocab slice (partial logsumexp terms, partial gold
dot product) and GSPMD inserts scalar-sized psums — the Megatron
vocab-parallel loss, recovered at the XLA level.  The math is exact, so
the same function doubles as the single-device reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.dist import logical


def ce_loss(logits, targets):
    """Token-mean cross entropy.  logits: [..., V] (any leading dims),
    targets: matching integer array.  Stable f32 internals regardless of
    the logits dtype; vocab-sharded logits stay sharded throughout."""
    x = logits.astype(jnp.float32)
    if x.ndim >= 2:
        x = logical.constrain(
            x, ("batch",) + (None,) * (x.ndim - 2) + ("vocab",)
        )
    vocab = x.shape[-1]
    # stable logsumexp: the max reduces locally then psums (scalar per token)
    m = jax.lax.stop_gradient(jnp.max(x, axis=-1, keepdims=True))
    logz = jnp.squeeze(m, -1) + jnp.log(jnp.sum(jnp.exp(x - m), axis=-1))
    # gold logit via one-hot contraction: local partial dot + psum, never a
    # cross-shard gather on the sharded vocab dim
    onehot = jax.nn.one_hot(targets, vocab, dtype=x.dtype)
    gold = jnp.sum(x * onehot, axis=-1)
    return jnp.mean(logz - gold)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _cast(x, dtype):
    return x.astype(jnp.float32)


def _cast_fwd(x, dtype):
    return x.astype(jnp.float32), None


def _cast_bwd(dtype, _res, g):
    return (g.astype(dtype),)


_cast.defvjp(_cast_fwd, _cast_bwd)


def cast_grad(x):
    """Cast to f32 for the loss while keeping the backward pass in the
    original activation dtype (bf16 grads flow back through the model;
    the f32 cast never becomes a stored f32 activation)."""
    return _cast(x, x.dtype)
