"""Model-axis-sharded embedding lookup (the distributed SparseNet).

The combined embedding table is row-sharded over the "model" mesh axis
(:func:`repro.dist.sharding.param_spec_tree`).  A row gather against a
row-sharded operand lowers, under GSPMD, to exactly the paper's Psum
dataflow: every shard gathers the requested rows it owns (masked local
gather) and the partial results are all-reduced — no shard ever
materializes the full table.  This module pins that layout with sharding
constraints so the partitioner cannot fall back to an all-gather of the
multi-GB table.

Single-device semantics are identical (the constraints are no-ops outside
an ``axis_rules`` binding), which is what the numerical-equivalence tests
in ``tests/test_distributed.py`` exercise.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import logical
from repro.models import embedding as emb_lib


def sharded_row_gather(table, ids, axis_name=None):
    """Row gather from a (possibly) row-sharded table.

    table: [rows, dim] annotated sharded over the model axis; ids: any int
    shape.  ``axis_name`` pins the table to an explicit mesh axis instead
    of the bound logical "model" axis (None = use the active binding; no
    binding = plain local gather).  Returns ``ids.shape + (dim,)``.
    """
    if axis_name is not None:
        mesh = logical.current_mesh()
        if mesh is not None:
            table = jax.lax.with_sharding_constraint(
                table, NamedSharding(mesh, P(axis_name, None))
            )
        return jnp.take(table, ids, axis=0)
    if logical.model_axis_name() is None:
        return jnp.take(table, ids, axis=0)
    table = logical.constrain(table, ("model", None))
    return jnp.take(table, ids, axis=0)


def embedding_bag_sharded(params, ids, cfg):
    """Multi-hot gather + pool against the row-sharded combined table.

    Delegates to :func:`repro.models.embedding.embedding_bag_local` (same
    QR handling, same masked pooling — one body to maintain) with the
    table pinned row-sharded and the pooled output pinned batch-sharded.
    ids: [B, F, P] int32, -1-padded -> [B, F, dim].
    """
    table = logical.constrain(params["table"], ("model", None))
    pooled = emb_lib.embedding_bag_local({"table": table}, ids, cfg)
    return logical.constrain(pooled, ("batch", None, None))
