"""Expert-parallel MoE dispatch (Switch-style capacity buffers).

The dense reference (``repro.models.layers.apply_moe_dense``) runs every
expert on every token — O(E·N) compute.  The production path here routes
each token's top-k assignments into fixed-size per-expert capacity buffers
(grouped-GEMM layout ``[E, capacity, d]``) so expert compute is O(N·k) and
the stacked expert weights shard over the model axis (logical "expert"
axis).  Under a mesh binding the buffers are annotated expert-sharded and
GSPMD lowers the gather/scatter to the all-to-all + psum dataflow; without
a binding the same code is the single-device grouped dispatch.

``e_start``/``e_count`` expose the per-shard expert window so a caller
(or a shard_map'd kernel) can compute one expert slice's partial output;
partial outputs over disjoint windows sum to the full result, which is the
invariant ``tests/test_models.py::test_expert_partials_sum_to_full`` pins.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.dist import logical
from repro.models.layers import MoEConfig, apply_swiglu, moe_router


def expert_capacity(n_tokens: int, cfg: MoEConfig) -> int:
    """Per-expert buffer slots for ``n_tokens``: the uniform-routing share
    ``n·k/E`` scaled by the capacity factor, rounded up to a multiple of 8
    (TPU sublane alignment).  With capacity_factor >= 1 this always admits
    every assignment in aggregate: capacity · E >= n · k."""
    want = math.ceil(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return -(-want // 8) * 8


def dispatch_indices(topk, n_experts: int, capacity: int,
                     e_start: int = 0, e_count: int | None = None):
    """Slot assignment for the capacity buffers of experts
    ``[e_start, e_start + e_count)``.

    topk: [n, k] int32 expert ids (position-priority: earlier tokens win
    slots when an expert oversubscribes its capacity).

    Returns:
      buf_token: [e_count * capacity] int32 — token feeding each slot
                 (slot layout: ``(e - e_start) * capacity + rank``)
      buf_valid: [e_count * capacity] bool — slot occupied
      slot_of:   [n, k] int32 — slot of each assignment, -1 if dropped
                 (over capacity or outside the expert window)
    """
    if e_count is None:
        e_count = n_experts
    n, k = topk.shape
    flat = topk.reshape(-1)                                   # [n*k]
    token_of = (jnp.arange(n * k, dtype=jnp.int32) // k)      # [n*k]
    # rank of each assignment within its expert, in flat (position) order —
    # computed over ALL experts so a window sees the same ranks as the full
    # dispatch (windows must tile consistently)
    onehot = (flat[:, None] == jnp.arange(n_experts, dtype=jnp.int32)[None, :])
    rank = jnp.sum((jnp.cumsum(onehot, axis=0) - 1) * onehot, axis=1)  # [n*k]
    rank = rank.astype(jnp.int32)

    keep = (rank < capacity) & (flat >= e_start) & (flat < e_start + e_count)
    slot = (flat - e_start) * capacity + rank
    slot_of = jnp.where(keep, slot, -1).reshape(n, k)

    n_slots = e_count * capacity
    scatter_to = jnp.where(keep, slot, n_slots)               # drops -> spill row
    buf_token = (
        jnp.zeros((n_slots + 1,), jnp.int32).at[scatter_to].set(token_of)[:n_slots]
    )
    buf_valid = (
        jnp.zeros((n_slots + 1,), bool).at[scatter_to].set(keep)[:n_slots]
    )
    return buf_token, buf_valid, slot_of


def moe_apply_grouped(params, x, cfg: MoEConfig, *, e_start: int = 0,
                      e_count: int | None = None, capacity: int | None = None):
    """Routed-expert output via capacity-buffer grouped dispatch.

    x: [N, d].  Computes only experts ``[e_start, e_start + e_count)`` —
    the full (padded) expert range by default — and does NOT add the shared
    expert (see :func:`moe_apply`).  Returns ([N, d], aux_loss); dropped
    assignments contribute zero (damped output, never NaN).
    """
    e_pad = cfg.n_experts_padded
    if e_count is None:
        e_count = e_pad
    n, d = x.shape
    if capacity is None:
        capacity = expert_capacity(n, cfg)

    topk_idx, topk_w, aux = moe_router(params, x, cfg)
    buf_token, buf_valid, slot_of = dispatch_indices(
        topk_idx, e_pad, capacity, e_start, e_count
    )

    # gather tokens into the [e, capacity, d] buffers (zero for empty slots)
    xb = jnp.take(x, buf_token, axis=0) * buf_valid[:, None].astype(x.dtype)
    xb = logical.constrain(
        xb.reshape(e_count, capacity, d), ("expert", None, None)
    )

    ex = params["experts"]
    wg = jax.lax.dynamic_slice_in_dim(ex["w_gate"], e_start, e_count, axis=0)
    wu = jax.lax.dynamic_slice_in_dim(ex["w_up"], e_start, e_count, axis=0)
    wd = jax.lax.dynamic_slice_in_dim(ex["w_down"], e_start, e_count, axis=0)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xb, wg)) * jnp.einsum(
        "ecd,edf->ecf", xb, wu
    )
    y = jnp.einsum("ecf,efd->ecd", h, wd)
    y = logical.constrain(y, ("expert", None, None)).reshape(
        e_count * capacity, d
    )

    # combine: out[t] = sum_j w[t,j] * y[slot_of[t,j]] over kept assignments
    kept = (slot_of >= 0)
    rows = jnp.take(y, jnp.maximum(slot_of, 0).reshape(-1), axis=0)
    rows = rows.reshape(n, cfg.top_k, d)
    w = topk_w * kept.astype(topk_w.dtype)
    out = jnp.einsum("nk,nkd->nd", w, rows)
    return logical.constrain(out, ("batch", None)), aux


def moe_apply(params, x, cfg: MoEConfig):
    """Full MoE layer: routed experts (grouped dispatch over the whole
    padded expert range, expert-parallel under a mesh binding) plus the
    always-on shared expert.  x: [N, d] -> ([N, d], aux_loss)."""
    out, aux = moe_apply_grouped(params, x, cfg)
    if cfg.n_shared:
        out = out + apply_swiglu(params["shared"], x)
    return logical.constrain(out, ("batch", None)), aux
