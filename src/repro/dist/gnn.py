"""Sharded GNN execution via ``shard_map`` (vertex/edge partition).

Full-graph GraphSAGE distributes by sharding the EDGE LIST: each device
gathers messages for its edge shard, segment-sums a partial [N, d]
aggregation, and a psum over the mesh reconstructs the exact full-graph
aggregate (sum and mean are linear in the edge set; max uses pmax).  The
dense SAGE combine then runs replicated outside the shard_map — parameters
never enter the mapped region, so this composes with jit/grad without
per-leaf spec plumbing.

Batched small graphs (molecule cells) are embarrassingly parallel instead:
the packed [G·n] node / [G·e] edge arrays shard on their graph-major axis,
and each device runs the whole forward on its own block of graphs after
rebasing the global node/graph ids to its shard.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.models import gnn as gnn_lib


def _sharded_aggregate(h, edges, mesh, n_nodes, aggregator):
    """Exact full-graph aggregation with edges sharded over every mesh axis.

    h: [N, d] (replicated into the map), edges: [2, E] -> ([N, d], [N, 1])
    aggregate and in-degree, both replicated (psum'd) on the way out.
    """
    axes = tuple(mesh.axis_names)

    def body(h_full, edges_local):
        src, dst = edges_local[0], edges_local[1]
        msg = jnp.take(h_full, src, axis=0)                   # [E_local, d]
        if aggregator == "max":
            agg = jax.ops.segment_max(msg, dst, num_segments=n_nodes)
            agg = jnp.where(jnp.isfinite(agg), agg, -jnp.inf)
            agg = jax.lax.pmax(agg, axes)
            agg = jnp.where(jnp.isfinite(agg), agg, 0.0)
            deg = jnp.ones((n_nodes, 1), h_full.dtype)        # unused for max
            return agg, deg
        agg = jax.ops.segment_sum(msg, dst, num_segments=n_nodes)
        deg = jax.ops.segment_sum(
            jnp.ones_like(dst, h_full.dtype), dst, num_segments=n_nodes
        )[:, None]
        return jax.lax.psum(agg, axes), jax.lax.psum(deg, axes)

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(None, None), P(None, axes)),
        out_specs=(P(None, None), P(None, None)),
    )(h, edges)


def apply_full_sharded(params, feats, edges, labels, label_mask, cfg, mesh,
                       n_nodes):
    """Full-graph GraphSAGE forward + masked softmax CE under edge sharding.

    Numerically identical to ``gnn.apply_full`` -> ``gnn.softmax_ce`` on one
    device; returns the scalar loss.
    """
    h = feats.astype(cfg.dtype)
    for layer in params["layers"]:
        agg, deg = _sharded_aggregate(h, edges, mesh, n_nodes, cfg.aggregator)
        if cfg.aggregator == "mean":
            agg = agg / jnp.maximum(deg, 1.0)
        h = gnn_lib._sage_combine(layer, h, agg, activate=True)
    logits = h @ params["cls"]
    return gnn_lib.softmax_ce(logits, labels, label_mask)


def apply_batched_sharded(params, batch, cfg, mesh, dp, n_graphs, n_nodes,
                          n_edges):
    """Packed-small-graph forward with graphs sharded over the ``dp`` axes.

    batch: feats [G·n, d] / edges [2, G·e] (global node ids) / node_mask
    [G·n] / graph_ids [G·n] (global graph ids) / labels [G], uniformly
    packed (graph g owns nodes [g·n, (g+1)·n)).  Each shard rebases ids to
    its local block and runs the plain batched forward.  Returns
    (logits [G, C], labels [G]) for the caller's loss.
    """
    dp = (dp,) if isinstance(dp, str) else tuple(dp)
    n_shards = 1
    for a in dp:
        n_shards *= mesh.shape[a]
    if n_graphs % n_shards:
        raise ValueError(f"{n_graphs} graphs do not tile {n_shards} shards")
    g_local = n_graphs // n_shards

    p_specs = jax.tree.map(lambda l: P(*([None] * jnp.ndim(l))), params)

    def body(p, feats, edges, node_mask, graph_ids, labels):
        idx = jnp.zeros((), jnp.int32)
        for a in dp:  # flattened shard index over the dp axes, major-first
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        edges_l = edges - idx * (g_local * n_nodes)
        gids_l = graph_ids - idx * g_local
        logits = gnn_lib.apply_batched(
            p, feats, edges_l, node_mask, gids_l, g_local, cfg
        )
        return logits, labels

    return shard_map(
        body, mesh=mesh,
        in_specs=(p_specs, P(dp, None), P(None, dp), P(dp), P(dp), P(dp)),
        out_specs=(P(dp, None), P(dp)),
    )(params, batch["feats"], batch["edges"], batch["node_mask"],
      batch["graph_ids"], batch["labels"])
