"""Sharding policy: logical-axis rules and parameter/optimizer spec trees.

One place encodes how each :class:`~repro.common.types.ArchKind` maps onto
the production meshes (``("data", "model")`` single pod, ``("pod", "data",
"model")`` multi-pod):

- LMs run 2D data x tensor parallelism (Megatron layout): attention heads
  and FFN width column-sharded, output projections row-sharded, the
  vocabulary dimension (embed table rows / lm_head columns) sharded for the
  vocab-parallel CE loss, and MoE expert stacks sharded over the model axis
  (expert parallelism).
- RecSys shards only the combined embedding table row-wise over the model
  axis (the multi-GB SparseNet); the small dense MLPs replicate.
- GNNs replicate parameters and shard the graph (nodes/edges) over every
  mesh axis — vertex-partition data parallelism.

Parameter specs name only the "model" axis, so the same spec tree is valid
on both mesh shapes; data/pod axes shard activations, never weights.
"""
from __future__ import annotations

import warnings

import jax
from jax.sharding import PartitionSpec as P

from repro.common.types import ArchKind


class ShardingFallbackWarning(UserWarning):
    """An optimizer sub-tree diverged from the parameter structure and its
    accumulators were conservatively replicated.

    Replication is correct but silently forfeits memory scaling — a
    replicated Adam state for a model-sharded multi-GB embedding table puts
    the whole accumulator on every chip.  The warning names the diverging
    sub-tree and leaf paths so the spec logic can be extended; pass
    ``strict=True`` to turn it into an error.
    """


def logical_rules(kind: ArchKind, multi_pod: bool = False) -> dict:
    """Logical axis name -> mesh axis binding for one architecture family."""
    dp = ("pod", "data") if multi_pod else ("data",)
    rules = {
        "batch": dp,
        "model": "model",
    }
    if kind in (ArchKind.LM_DENSE, ArchKind.LM_MOE):
        rules.update(
            seq=None,            # sequence replicated (residual_seq opts in)
            residual_seq=None,   # bound to "model" by seq_shard configs
            embed=None,
            heads="model",
            kv_heads="model",
            ffn="model",
            vocab="model",
            expert="model",
            kv_seq=None,         # decode cells bind this (kv_seq_axes)
        )
    elif kind == ArchKind.GNN:
        # vertex/edge partition spreads the graph over the whole mesh
        rules["nodes"] = dp + ("model",)
    return rules


def kv_seq_axes(batch: int, multi_pod: bool = False) -> tuple[str, ...]:
    """Mesh axes the decode KV cache's sequence dimension shards over.

    Large-batch decode (decode_32k) shards batch over the data axes and
    sequence over "model" only; batch == 1 (long_500k) has no batch
    parallelism to exploit, so the sequence takes every mesh axis.  The
    "kv_seq" logical rule binds to this, and repro.dist.decode reads it to
    pick the cross-shard merge axes.
    """
    dp = ("pod", "data") if multi_pod else ("data",)
    return dp + ("model",) if batch < 16 else ("model",)


def kv_cache_spec(batch: int, multi_pod: bool = False) -> P:
    """PartitionSpec for one stacked KV-cache leaf [L, B, S, KVH, hd].

    Sequence shards over :func:`kv_seq_axes`; batch over the data axes
    when it is large enough to split.  Rank-5 int8-scale leaves
    ([L, B, S, KVH, 1]) take the same spec — only S is sharded.
    """
    dp = ("pod", "data") if multi_pod else ("data",)
    if batch >= 16:
        return P(None, dp, kv_seq_axes(batch, multi_pod), None, None)
    return P(None, None, kv_seq_axes(batch, multi_pod), None, None)


def _path_names(path) -> list[str]:
    return [k.key for k in path if hasattr(k, "key")]


def _spec(lead: int, ndim: int, shard_dim: int) -> P:
    """P with ``lead`` stacked-layer Nones, "model" at ``shard_dim`` of the
    per-layer shape, None elsewhere."""
    axes = [None] * ndim
    axes[lead + shard_dim] = "model"
    return P(*axes)


def _replicated(ndim: int) -> P:
    return P(*([None] * ndim))


def _lm_leaf_spec(names: list[str], ndim: int) -> P:
    last = names[-1] if names else ""
    # per-layer params are stacked on a leading L axis under "blocks"
    lead = 1 if "blocks" in names else 0
    if last == "embed":
        return P("model", None)           # vocab-row sharded
    if last == "lm_head":
        return P(None, "model")           # vocab-column sharded
    if "experts" in names:
        return _spec(lead, ndim, 0)       # [L, E, ...]: expert parallel
    if last == "router":
        return _replicated(ndim)          # tiny; replicate for exact routing
    if last in ("wq", "wk", "wv", "bq", "bk", "bv"):
        return _spec(lead, ndim, ndim - lead - 1)  # heads column-sharded
    if last == "wo":
        return _spec(lead, ndim, 0)       # row-sharded (psum on output)
    if last in ("w_gate", "w_up"):
        return _spec(lead, ndim, ndim - lead - 1)  # ffn column-sharded
    if last == "w_down":
        return _spec(lead, ndim, 0)       # ffn row-sharded
    return _replicated(ndim)              # norms, biases


def _recsys_leaf_spec(names: list[str], ndim: int) -> P:
    # the combined embedding table (and its hot/cold split) row-shards over
    # the model axis; everything dense replicates
    if names and names[-1] in ("table", "hot", "cold") and ndim == 2:
        return P("model", None)
    return _replicated(ndim)


def param_spec_tree(kind: ArchKind, params):
    """PartitionSpec pytree matching ``params`` (arrays or ShapeDtypeStructs)."""

    def leaf_spec(path, leaf):
        names = _path_names(path)
        ndim = len(leaf.shape)
        if kind in (ArchKind.LM_DENSE, ArchKind.LM_MOE):
            return _lm_leaf_spec(names, ndim)
        if kind == ArchKind.RECSYS:
            return _recsys_leaf_spec(names, ndim)
        return _replicated(ndim)          # GNN: pure data parallel

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def opt_spec_tree(kind: ArchKind, opt_state, param_specs, strict: bool = False):
    """PartitionSpec pytree for an optimizer state.

    Optimizer accumulators mirror the parameter tree ("m"/"v"/"mu"/"acc"
    sub-trees) and inherit each parameter's spec; row-wise accumulators
    ([rows, 1] for a [rows, dim] table) keep the row sharding because the
    spec is positional.  Scalar counters ("step") replicate.

    A sub-tree whose structure diverges from the parameter tree falls back
    to replicated specs with a :class:`ShardingFallbackWarning` naming the
    diverging paths; ``strict=True`` raises ``ValueError`` instead (use in
    tests and launch validation, where a silent memory-scaling regression
    is worse than a crash).
    """
    spec_leaves = jax.tree_util.tree_leaves(
        param_specs, is_leaf=lambda x: isinstance(x, P)
    )

    def mirrored(name, sub):
        flat, treedef = jax.tree_util.tree_flatten_with_path(sub)
        leaves = [l for _, l in flat]
        if len(leaves) != len(spec_leaves):
            msg = (
                f'optimizer sub-tree "{name}" has {len(leaves)} leaves but '
                f"params have {len(spec_leaves)}; replicating "
                f"{[jax.tree_util.keystr(p) for p, _ in flat]}"
            )
            if strict:
                raise ValueError(f"opt_spec_tree: {msg}")
            warnings.warn(msg, ShardingFallbackWarning, stacklevel=3)
            # structure diverged from params: replicate conservatively
            fitted = [_replicated(len(l.shape)) for l in leaves]
        else:
            fitted = [
                s if len(s) == len(l.shape) else _replicated(len(l.shape))
                for l, s in zip(leaves, spec_leaves)
            ]
        return jax.tree_util.tree_unflatten(treedef, fitted)

    out = {}
    for name, sub in opt_state.items():
        sub_leaves = jax.tree_util.tree_leaves(sub)
        if not sub_leaves:
            out[name] = sub                      # e.g. momentum-less sgd {}
        elif len(sub_leaves) == 1 and not len(sub_leaves[0].shape):
            out[name] = P()                      # scalar step counter
        else:
            out[name] = mirrored(name, sub)
    return out
