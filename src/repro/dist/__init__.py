"""Distributed execution layer: logical-axis sharding, spec trees, and the
sharded dataflows (embedding Psum, expert-parallel MoE, vocab-parallel CE,
vertex-partition GNN, seq-sharded flash decode) that back the mesh/dry-run
paths.

Submodules import lazily where they touch model code so that
``repro.dist.logical`` / ``repro.dist.sharding`` stay importable from
pure-config contexts.
"""
from repro.dist import logical  # noqa: F401  (the universal entry point)
