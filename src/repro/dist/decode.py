"""Distributed flash decode over a sequence-sharded KV cache.

The long-context decode cells (``decode_32k`` / ``long_500k``) keep the KV
cache sequence-sharded: [B, S, KVH, hd] with the S dimension split over the
"model" axis (and over the data axes too when batch == 1 — long_500k's only
option, see ``repro.dist.sharding.kv_seq_axes``).  A naive attention over
that layout forces GSPMD to all-gather the whole cache onto every chip —
exactly the transfer the layout exists to avoid.

This module runs the split-KV schedule across chips instead: under a
``shard_map`` each shard runs the on-chip Pallas kernel
(:func:`~repro.kernels.flash_attention.flash_decode.flash_decode_partials`)
on its *local* KV slice — passing its global base offset so a ragged
``kv_len`` that ends mid-shard masks correctly — producing per-shard
softmax partials ``(m, l, o)``.  A single all-gather of the partials
(tiny: [group, hd] per kv head, independent of S) followed by the same
``lse_combine`` primitive the kernel uses for its on-chip chunk merge
combines them, so the cross-chip merge and the on-chip merge share one
correctness oracle.  The merge is permutation-invariant (max + weighted
sums), so gather order across a multi-axis shard never matters.

``decode_attention`` is the model-facing entry: it reads the active logical
binding (``repro.dist.logical``) and picks the distributed path iff a mesh
is bound with a non-trivial "kv_seq" rule; otherwise it runs the local
kernel — the same code path serves single-device smoke tests and the
sharded cells.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.dist import logical
from repro.kernels.flash_attention.flash_decode import (
    flash_decode_partials,
    flash_decode_pallas,
    lse_combine,
)
from repro.kernels.flash_attention.ops import _on_tpu


def _as_axes(axes) -> tuple[str, ...]:
    """Normalize a rule binding (name | tuple | None) to a tuple of names."""
    if axes is None:
        return ()
    if isinstance(axes, str):
        return (axes,)
    return tuple(axes)


def seq_shard_index(mesh, seq_axes: tuple[str, ...]):
    """Flat shard index along a dimension sharded over ``seq_axes``.

    PartitionSpec orders multi-axis sharding major-to-minor, so the shard
    holding global rows [i * S_local, (i+1) * S_local) has
    i = axis_index(major) * size(minor) + axis_index(minor).
    """
    idx = jnp.zeros((), jnp.int32)
    for a in seq_axes:
        idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
    return idx


def flash_decode_sharded(q, k, v, *, kv_len, mesh, seq_axes, batch_axes=(),
                         bk=512, interpret=False):
    """Flash decode with k/v sequence-sharded over ``seq_axes``.

    q [B, 1, H, hd] (replicated over ``seq_axes``; optionally sharded on
    batch over ``batch_axes``); k/v [B, S, KVH, hd] with S sharded over
    ``seq_axes``.  kv_len is the GLOBAL live cache length — it may land
    anywhere inside any shard; shards entirely past it contribute empty
    partials.  Returns [B, 1, H, hd] with q's sharding.
    """
    seq_axes = _as_axes(seq_axes)
    batch_axes = _as_axes(batch_axes)
    if not seq_axes:
        return flash_decode_pallas(q, k, v, kv_len=kv_len, bk=bk,
                                   interpret=interpret)
    n_shards = 1
    for a in seq_axes:
        n_shards *= mesh.shape[a]
    S = k.shape[1]
    if S % n_shards:
        raise ValueError(f"S {S} not divisible by {n_shards} seq shards "
                         f"({seq_axes})")
    s_local = S // n_shards

    b_ax = batch_axes or None
    q_spec = P(b_ax, None, None, None)
    kv_spec = P(b_ax, seq_axes, None, None)

    def local_decode(q_l, k_l, v_l):
        offset = seq_shard_index(mesh, seq_axes) * s_local
        m, l, o = flash_decode_partials(
            q_l, k_l, v_l, kv_len=kv_len, kv_offset=offset, bk=bk,
            interpret=interpret,
        )
        # partials are [B_l, KVH, group, {1, hd}] — gathering them moves
        # O(B * H * hd) bytes per chip, independent of S
        m_all, l_all, o_all = jax.lax.all_gather(
            (m, l, o), seq_axes, axis=0)
        _, l_c, o_c = lse_combine(m_all, l_all, o_all, axis=0)
        out = (o_c / jnp.maximum(l_c, 1e-30)).astype(q_l.dtype)
        b_l, kvh, group, hd = o_c.shape
        return out.reshape(b_l, kvh * group, hd).reshape(b_l, 1, kvh * group, hd)

    return shard_map(
        local_decode, mesh=mesh,
        in_specs=(q_spec, kv_spec, kv_spec),
        out_specs=q_spec,
        check_rep=False,
    )(q, k, v)


def decode_attention(q, k, v, *, kv_len, bk=512, interpret=None):
    """Model-facing decode attention: distributed iff "kv_seq" is bound.

    Reads the active logical binding at trace time: with a mesh and a
    non-empty "kv_seq" rule the KV cache is sequence-sharded and the
    shard_map path runs; otherwise the local split-KV kernel does.  The
    "batch" rule (if bound) carries through as the batch sharding.
    """
    if interpret is None:
        interpret = not _on_tpu()
    mesh = logical.current_mesh()
    seq_axes = logical.bound_axes("kv_seq")
    if mesh is None or not seq_axes:
        return flash_decode_pallas(q, k, v, kv_len=kv_len, bk=bk,
                                   interpret=interpret)
    return flash_decode_sharded(
        q, k, v, kv_len=kv_len, mesh=mesh, seq_axes=seq_axes,
        batch_axes=logical.bound_axes("batch"), bk=bk, interpret=interpret,
    )
