"""Sharding-consistency pass.

A typo'd axis name in a ``constrain`` annotation, an ``axis_rules`` dict or
a ``PartitionSpec`` does not error — ``logical.resolve`` maps unknown names
to None and GSPMD happily replicates, so a multi-GB table silently lands
whole on every chip.  This pass checks every literal axis name against the
vocabulary declared in ``repro/dist/sharding.py``'s rule tables
(:class:`repro.analysis.core.RepoFacts`):

- logical names (``constrain`` axes, ``axis_rules`` dict keys,
  ``rules[...] = ...`` writes) must be declared logical axes;
- mesh names (``PartitionSpec`` entries, ``axis_rules`` dict values,
  string axis arguments of collectives like ``psum``/``all_gather``/
  ``axis_index``) must be declared mesh axes;
- a spec-tree fallback that replicates on structural divergence without
  warning or raising (the historical ``opt_spec_tree`` behaviour) is a
  finding — silent replication is exactly the failure mode above.

Only literal strings are checked; names computed at run time (e.g.
``tuple(mesh.axis_names)``) are out of static reach and pass through.
"""
from __future__ import annotations

import ast

from repro.analysis.core import (
    FileContext,
    Finding,
    dotted_name,
    string_constants,
)

RULES = {
    "sharding-unknown-logical-axis": (
        "logical axis name not declared in repro/dist/sharding.py's rule "
        "tables (it would silently resolve to replicated)"
    ),
    "sharding-unknown-mesh-axis": (
        "mesh axis name not used by any declared mesh "
        "(PartitionSpec/collective would fail or silently replicate)"
    ),
    "sharding-silent-fallback": (
        "spec-tree structural-divergence fallback replicates without "
        "warning or raising"
    ),
}

# collectives whose string arguments name mesh axes
_COLLECTIVES = {
    "psum", "pmax", "pmin", "pmean", "all_gather", "all_to_all",
    "axis_index", "axis_size", "ppermute", "psum_scatter",
}


def _is_logical_api(ctx: FileContext, func: ast.AST, name: str) -> bool:
    """Does ``func`` refer to repro.dist.logical.<name>?"""
    resolved = ctx.resolve(func)
    if resolved is not None:
        return resolved == f"repro.dist.logical.{name}"
    # fallback: `logical.<name>` via a relative/unresolved import
    dotted = dotted_name(func)
    return dotted is not None and dotted.endswith(f"logical.{name}")


def _check_axis_strings(
    ctx: FileContext, node: ast.AST, vocab: frozenset, rule: str, what: str
):
    for s, line in string_constants(node):
        if s not in vocab:
            yield Finding(
                ctx.rel, line, rule,
                f'unknown {what} "{s}" (declared: '
                f"{', '.join(sorted(vocab))})",
            )


def _check_constrain(ctx: FileContext, call: ast.Call):
    if len(call.args) < 2:
        return
    yield from _check_axis_strings(
        ctx, call.args[1], ctx.facts.logical_axes,
        "sharding-unknown-logical-axis", "logical axis",
    )


def _check_axis_rules(ctx: FileContext, call: ast.Call):
    if len(call.args) < 2 or not isinstance(call.args[1], ast.Dict):
        return
    rules_dict = call.args[1]
    for k, v in zip(rules_dict.keys, rules_dict.values):
        if isinstance(k, ast.Constant) and isinstance(k.value, str):
            if k.value not in ctx.facts.logical_axes:
                yield Finding(
                    ctx.rel, k.lineno, "sharding-unknown-logical-axis",
                    f'axis_rules key "{k.value}" is not a declared logical '
                    "axis",
                )
        yield from _check_axis_strings(
            ctx, v, ctx.facts.mesh_axes,
            "sharding-unknown-mesh-axis", "mesh axis",
        )


def _check_rules_write(ctx: FileContext, node: ast.Assign):
    """``rules["kv_seq"] = ...`` — the launch-layer idiom for extending a
    logical_rules dict; the key must be a declared logical axis."""
    t = node.targets[0]
    if not (
        isinstance(t, ast.Subscript)
        and isinstance(t.value, ast.Name)
        and t.value.id == "rules"
        and isinstance(t.slice, ast.Constant)
        and isinstance(t.slice.value, str)
    ):
        return
    if t.slice.value not in ctx.facts.logical_axes:
        yield Finding(
            ctx.rel, node.lineno, "sharding-unknown-logical-axis",
            f'rules["{t.slice.value}"] writes an undeclared logical axis',
        )


def _is_partition_spec(ctx: FileContext, func: ast.AST) -> bool:
    resolved = ctx.resolve(func)
    return resolved in (
        "jax.sharding.PartitionSpec",
        "jax.experimental.pjit.PartitionSpec",
    )


def _check_silent_fallback(ctx: FileContext, node: ast.If):
    """``if len(a) != len(b): <build replicated specs>`` with no warn/raise
    in the branch — the opt_spec_tree bug class."""
    test = node.test
    if not (
        isinstance(test, ast.Compare)
        and len(test.ops) == 1
        and isinstance(test.ops[0], ast.NotEq)
    ):
        return
    sides = [test.left, *test.comparators]
    if not all(
        isinstance(s, ast.Call)
        and isinstance(s.func, ast.Name)
        and s.func.id == "len"
        for s in sides
    ):
        return
    body_calls = [
        n for stmt in node.body for n in ast.walk(stmt)
        if isinstance(n, ast.Call)
    ]
    replicates = any(
        "replicated" in (dotted_name(c.func) or "").lower()
        for c in body_calls
    )
    if not replicates:
        return
    warns = any(
        (dotted_name(c.func) or "").split(".")[-1] in ("warn", "warning")
        for c in body_calls
    )
    raises = any(
        isinstance(n, ast.Raise)
        for stmt in node.body
        for n in ast.walk(stmt)
    )
    if not warns and not raises:
        yield Finding(
            ctx.rel, node.lineno, "sharding-silent-fallback",
            "structure-mismatch branch falls back to replicated specs "
            "without a warning or raise — add a structured warning and a "
            "strict= escape hatch",
        )


def run(ctx: FileContext):
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call):
            if _is_logical_api(ctx, node.func, "constrain"):
                yield from _check_constrain(ctx, node)
            elif _is_logical_api(ctx, node.func, "axis_rules"):
                yield from _check_axis_rules(ctx, node)
            elif _is_logical_api(ctx, node.func, "bound_axes"):
                if node.args:
                    yield from _check_axis_strings(
                        ctx, node.args[0], ctx.facts.logical_axes,
                        "sharding-unknown-logical-axis", "logical axis",
                    )
            elif _is_partition_spec(ctx, node.func):
                yield from _check_axis_strings(
                    ctx, node, ctx.facts.mesh_axes,
                    "sharding-unknown-mesh-axis", "mesh axis",
                )
            else:
                dotted = dotted_name(node.func) or ""
                leaf = dotted.split(".")[-1]
                if leaf in _COLLECTIVES:
                    # axis_index/axis_size take the axis name first; the
                    # rest take (value, axis_name, ...)
                    positional = (
                        node.args
                        if leaf in ("axis_index", "axis_size")
                        else node.args[1:]
                    )
                    for arg in [*positional, *(
                        kw.value for kw in node.keywords
                        if kw.arg in ("axis_name", "axes")
                    )]:
                        yield from _check_axis_strings(
                            ctx, arg, ctx.facts.mesh_axes,
                            "sharding-unknown-mesh-axis", "mesh axis",
                        )
        elif isinstance(node, ast.Assign):
            yield from _check_rules_write(ctx, node)
        elif isinstance(node, ast.If):
            yield from _check_silent_fallback(ctx, node)
