"""Jit-purity pass.

Host-side operations on traced values inside ``jax.jit`` / ``shard_map``
functions either fail at trace time (``float(tracer)``), silently run once
at trace time (``print``), or force a blocking device sync (``.item()``)
that wrecks the async dispatch pipeline the serving engine depends on.

Scope detection is static: functions decorated ``@jax.jit`` or
``@functools.partial(jax.jit, ...)`` (with ``static_argnames`` /
``static_argnums`` excluded from the traced parameter set), plus functions
passed as the first argument to ``shard_map``.  Host ``numpy`` calls are
only flagged when a traced parameter is passed *directly* — ``np.sqrt(hd)``
on a Python int extracted from a static shape is fine and common in this
repo's kernels.
"""
from __future__ import annotations

import ast

from repro.analysis.core import FileContext, Finding, dotted_name

RULES = {
    "jit-purity-print": (
        "print inside a jitted/shard_map function runs at trace time only "
        "— use jax.debug.print"
    ),
    "jit-purity-host-sync": (
        ".item()/.tolist()/float()/int() on a traced value forces a "
        "blocking host sync inside jit"
    ),
    "jit-purity-host-numpy": (
        "host numpy op applied to a traced value inside jit — use "
        "jax.numpy"
    ),
}


def _resolves_to_jit(ctx: FileContext, node: ast.AST) -> bool:
    resolved = ctx.resolve(node)
    if resolved == "jax.jit":
        return True
    dotted = dotted_name(node)
    return dotted in ("jax.jit",)


def _static_names(fn: ast.FunctionDef, call: ast.Call | None) -> set[str]:
    """Parameter names excluded from tracing by static_argnames/argnums."""
    if call is None:
        return set()
    out: set[str] = set()
    params = [
        a.arg
        for a in [*fn.args.posonlyargs, *fn.args.args, *fn.args.kwonlyargs]
    ]
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, str):
                    out.add(n.value)
        elif kw.arg == "static_argnums":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, int):
                    if 0 <= n.value < len(params):
                        out.add(params[n.value])
    return out


def _jitted_functions(ctx: FileContext):
    """Yield (FunctionDef, traced-param-name set) for every statically
    detectable jit/shard_map scope in the file."""
    by_name = {
        n.name: n
        for n in ast.walk(ctx.tree)
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
    }

    def params_of(fn):
        return {
            a.arg
            for a in [*fn.args.posonlyargs, *fn.args.args,
                      *fn.args.kwonlyargs]
        }

    for fn in by_name.values():
        for dec in fn.decorator_list:
            if _resolves_to_jit(ctx, dec):
                yield fn, params_of(fn)
            elif isinstance(dec, ast.Call):
                if _resolves_to_jit(ctx, dec.func):
                    yield fn, params_of(fn) - _static_names(fn, dec)
                elif (
                    (dotted_name(dec.func) or "").endswith("partial")
                    and dec.args
                    and _resolves_to_jit(ctx, dec.args[0])
                ):
                    yield fn, params_of(fn) - _static_names(fn, dec)

    # functions handed to shard_map: every parameter is traced
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        dotted = dotted_name(node.func) or ""
        if not dotted.endswith("shard_map"):
            continue
        target = node.args[0] if node.args else None
        if (
            isinstance(target, ast.Call)
            and (dotted_name(target.func) or "").endswith("partial")
            and target.args
        ):
            target = target.args[0]
        if isinstance(target, ast.Name) and target.id in by_name:
            fn = by_name[target.id]
            yield fn, params_of(fn)


def run(ctx: FileContext):
    seen: set[tuple[int, str]] = set()
    for fn, traced in _jitted_functions(ctx):
        if (fn.lineno, fn.name) in seen:
            continue
        seen.add((fn.lineno, fn.name))
        # nested jitted defs are their own scope; don't double-report
        inner = {
            n
            for d in ast.walk(fn)
            if isinstance(d, (ast.FunctionDef, ast.AsyncFunctionDef))
            and d is not fn
            for n in ast.walk(d)
        }
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call) or node in inner:
                continue
            if isinstance(node.func, ast.Name) and node.func.id == "print":
                yield Finding(
                    ctx.rel, node.lineno, "jit-purity-print",
                    f"print() inside jitted function {fn.name}",
                )
            elif isinstance(node.func, ast.Attribute) and node.func.attr in (
                "item", "tolist"
            ):
                yield Finding(
                    ctx.rel, node.lineno, "jit-purity-host-sync",
                    f".{node.func.attr}() inside jitted function {fn.name} "
                    "blocks on the device",
                )
            elif (
                isinstance(node.func, ast.Name)
                and node.func.id in ("float", "int", "bool")
                and node.args
                and isinstance(node.args[0], ast.Name)
                and node.args[0].id in traced
            ):
                yield Finding(
                    ctx.rel, node.lineno, "jit-purity-host-sync",
                    f"{node.func.id}() on traced argument "
                    f"{node.args[0].id} fails/syncs at trace time",
                )
            else:
                resolved = ctx.resolve(node.func)
                if (
                    resolved
                    and resolved.startswith("numpy.")
                    and any(
                        isinstance(a, ast.Name) and a.id in traced
                        for a in node.args
                    )
                ):
                    yield Finding(
                        ctx.rel, node.lineno, "jit-purity-host-numpy",
                        f"{resolved} applied to a traced argument of "
                        f"{fn.name}",
                    )
