"""Repo-aware static analysis for the Hercules reproduction.

Four AST passes enforce whole-repo invariants the test suite can only
sample: sharding axis-name consistency against ``dist/sharding.py``'s rule
tables, Pallas BlockSpec/grid/index-map discipline, simulated-path
determinism (seeded-Generator-only RNG, virtual clocks, no set-order
leaks), and jit purity.  Run with ``python -m repro.analysis`` — see
``docs/static_analysis.md`` for the rule catalog and suppression syntax
(``# repro: ignore[rule]``).

The package imports no jax: it must load (and run in CI) in any Python.
"""
from repro.analysis.core import (
    Finding,
    RepoFacts,
    Report,
    analyze_file,
    analyze_paths,
    rule_catalog,
)

__all__ = [
    "Finding",
    "RepoFacts",
    "Report",
    "analyze_file",
    "analyze_paths",
    "rule_catalog",
]
