"""Simulation-determinism pass.

The serving results (PR-2 "identical argmax", PR-4 SLA curves, every
latency-bounded throughput comparison) assume bitwise-reproducible
simulation: common random numbers threaded as seeded ``np.random.Generator``
objects, virtual time from the event loop, and ordered containers feeding
ordered results.  One unseeded draw or set-iteration in a hot path silently
turns "A beats B" into noise.

Scope is the simulated paths only — ``serving/engine.py``,
``serving/event_core.py``, ``serving/simulator.py``,
``serving/cluster_runtime.py``, ``serving/scenarios.py`` (scenario
builders must thread every seed through the spec) and ``core/*`` (plus
the lint fixture corpus); benchmarks and tests may use wall clocks and
ad-hoc RNG freely.

- ``determinism-global-rng``: ``np.random.<draw>`` module-level RNG calls
  (seeded constructor entry points like ``default_rng``/``SeedSequence``
  are fine);
- ``determinism-stdlib-random``: any call on the stdlib ``random`` module
  (its global Mersenne state is process-wide and unseedable per-component);
- ``determinism-wall-clock``: ``time.time``/``monotonic``/``perf_counter``
  (and ``_ns`` variants) — simulated paths must take time from the event
  loop's virtual clock;
- ``determinism-set-order``: iterating a ``set`` (for-loop, comprehension,
  ``sum``/``join`` reduction) where the result order matters — wrap in
  ``sorted(...)`` or keep a list/dict.
"""
from __future__ import annotations

import ast

from repro.analysis.core import FileContext, Finding, dotted_name

RULES = {
    "determinism-global-rng": (
        "unseeded module-level numpy RNG in a simulated path — thread a "
        "seeded np.random.Generator instead"
    ),
    "determinism-stdlib-random": (
        "stdlib random (global Mersenne state) in a simulated path — "
        "thread a seeded np.random.Generator instead"
    ),
    "determinism-wall-clock": (
        "wall-clock read in a simulated path — use the event loop's "
        "virtual clock"
    ),
    "determinism-set-order": (
        "iteration over a set feeds an ordered result — sort it or use an "
        "ordered container"
    ),
}

# determinism scope: the simulated hot paths named in the issue, plus the
# lint fixture corpus (so known-bad fixtures are in scope by construction)
_SCOPE_MARKERS = (
    "repro/serving/engine.py",
    "repro/serving/event_core.py",
    "repro/serving/simulator.py",
    "repro/serving/cluster_runtime.py",
    "repro/serving/scenarios.py",
    "repro/serving/geo.py",
    # repro/core/ below already covers the packing module; named so the
    # co-location hot path stays in scope even if the package-wide marker
    # is ever narrowed
    "repro/core/colocation.py",
    "repro/core/",
    "analysis_fixtures",
)

# numpy.random entry points that construct/derive seeded state rather than
# drawing from the hidden global stream
_SEEDED_CONSTRUCTORS = {
    "default_rng", "Generator", "SeedSequence", "PCG64", "PCG64DXSM",
    "Philox", "SFC64", "MT19937", "BitGenerator", "RandomState",
}

_WALL_CLOCK = {
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns", "time.process_time",
    "time.process_time_ns",
}


def _in_scope(rel: str) -> bool:
    return any(m in rel for m in _SCOPE_MARKERS)


def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    return (
        isinstance(node, ast.Call)
        and isinstance(node.func, ast.Name)
        and node.func.id in ("set", "frozenset")
    )


def _check_call(ctx: FileContext, node: ast.Call):
    resolved = ctx.resolve(node.func)
    if resolved is None:
        return
    if resolved.startswith("numpy.random."):
        leaf = resolved.rsplit(".", 1)[1]
        if leaf not in _SEEDED_CONSTRUCTORS:
            yield Finding(
                ctx.rel, node.lineno, "determinism-global-rng",
                f"np.random.{leaf}() draws from the global stream — use a "
                "seeded Generator",
            )
    elif resolved.startswith("random."):
        leaf = resolved.rsplit(".", 1)[1]
        if leaf not in ("Random", "SystemRandom"):
            yield Finding(
                ctx.rel, node.lineno, "determinism-stdlib-random",
                f"random.{leaf}() uses the process-global Mersenne state",
            )
    elif resolved in _WALL_CLOCK:
        yield Finding(
            ctx.rel, node.lineno, "determinism-wall-clock",
            f"{resolved}() reads the wall clock inside a simulated path",
        )


def _check_set_iteration(ctx: FileContext, node: ast.AST):
    if isinstance(node, ast.For) and _is_set_expr(node.iter):
        yield Finding(
            ctx.rel, node.iter.lineno, "determinism-set-order",
            "for-loop iterates a set in an order-sensitive path",
        )
    elif isinstance(
        node, (ast.ListComp, ast.GeneratorExp, ast.DictComp)
    ):
        for gen in node.generators:
            if _is_set_expr(gen.iter):
                yield Finding(
                    ctx.rel, gen.iter.lineno, "determinism-set-order",
                    "comprehension iterates a set into an ordered result",
                )
    elif isinstance(node, ast.Call):
        # sum(set)/"".join(set): order-dependent float accumulation / text
        dotted = dotted_name(node.func) or ""
        leaf = dotted.split(".")[-1]
        if leaf in ("sum", "join") and node.args and _is_set_expr(
            node.args[0]
        ):
            yield Finding(
                ctx.rel, node.lineno, "determinism-set-order",
                f"{leaf}() over a set accumulates in hash order",
            )


def run(ctx: FileContext):
    if not _in_scope(ctx.rel):
        return
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call):
            yield from _check_call(ctx, node)
        yield from _check_set_iteration(ctx, node)
