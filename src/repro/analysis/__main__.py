"""CLI: ``python -m repro.analysis [paths...]``.

Exits 1 on any unsuppressed finding (or unparseable file), 0 otherwise.
``--json`` writes the full machine-readable report (findings, suppressions,
rule catalog, extracted axis facts) for CI artifacts and baseline diffing
via ``tools/check_analysis.py``.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis.core import analyze_paths, rule_catalog


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-aware static analysis (sharding / pallas / "
        "determinism / jit-purity)",
    )
    parser.add_argument(
        "paths", nargs="*",
        help="files or directories to analyze (default: src tests "
        "benchmarks, whichever exist)",
    )
    parser.add_argument(
        "--json", metavar="FILE", dest="json_out",
        help="write the full report as JSON ('-' for stdout)",
    )
    parser.add_argument(
        "--include-fixtures", action="store_true",
        help="also analyze tests/analysis_fixtures (the known-bad corpus)",
    )
    parser.add_argument(
        "--list-rules", action="store_true",
        help="print the rule catalog and exit",
    )
    parser.add_argument(
        "--exit-zero", action="store_true",
        help="always exit 0 (report-only mode)",
    )
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule, desc in sorted(rule_catalog().items()):
            print(f"{rule}: {desc}")
        return 0

    paths = args.paths or [
        p for p in ("src", "tests", "benchmarks") if Path(p).exists()
    ]
    if not paths:
        print("no paths to analyze", file=sys.stderr)
        return 1

    report = analyze_paths(paths, include_fixtures=args.include_fixtures)

    for f in [*report.errors, *report.findings]:
        print(f.format())

    if args.json_out:
        payload = json.dumps(report.to_dict(), indent=2, sort_keys=True)
        if args.json_out == "-":
            print(payload)
        else:
            Path(args.json_out).write_text(payload + "\n")

    n_bad = len(report.findings) + len(report.errors)
    print(
        f"repro.analysis: {report.n_files} files, {n_bad} finding(s), "
        f"{len(report.suppressed)} suppressed "
        f"[axes from {report.facts.source or 'builtin defaults'}]",
        file=sys.stderr,
    )
    if args.exit_zero:
        return 0
    return 1 if n_bad else 0


if __name__ == "__main__":
    sys.exit(main())
