"""Lint framework: findings, suppressions, repo facts, and the runner.

The analyzer is a plain-``ast`` walk — no jax import, no code execution —
so it runs in any environment (including the no-jax import guard in
``tests/test_imports.py``) and costs milliseconds per file.  Each pass is
a module exposing ``RULES`` (rule name -> one-line description) and
``run(ctx)`` yielding :class:`Finding`s; the runner parses each file once,
hands the shared :class:`FileContext` to every pass, and filters findings
whose line carries a ``# repro: ignore[rule]`` suppression.

Repo-specific knowledge (which logical/mesh axis names exist) is read
from ``repro/dist/sharding.py``'s rule tables at analysis time — see
:class:`RepoFacts` — so the sharding pass tracks the source of truth
instead of a hardcoded copy.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path

SUPPRESS_RE = re.compile(r"#\s*repro:\s*ignore(?:\[([^\]]+)\])?")

# directories never descended into; "analysis_fixtures" additionally gated
# by include_fixtures (the known-bad lint corpus must not fail the repo)
SKIP_DIRS = {".git", "__pycache__", ".pytest_cache", "artifacts", ".github"}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analyzer hit, anchored to a file/line for suppression + diffing."""

    file: str  # posix path as given on the command line (repo-relative in CI)
    line: int
    rule: str
    message: str

    def format(self) -> str:
        return f"{self.file}:{self.line}: {self.rule}: {self.message}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def dotted_name(node: ast.AST) -> str | None:
    """``a.b.c`` for a Name/Attribute chain rooted at a Name, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


def import_map(tree: ast.Module) -> dict[str, str]:
    """Local binding name -> fully qualified module/object path.

    ``import numpy as np`` -> {"np": "numpy"}; ``from jax.sharding import
    PartitionSpec as P`` -> {"P": "jax.sharding.PartitionSpec"}.  Collected
    from every import statement in the file (not just module level) so
    function-local imports resolve too.
    """
    out: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0]
                )
        elif isinstance(node, ast.ImportFrom) and node.module and not node.level:
            for a in node.names:
                if a.name == "*":
                    continue
                out[a.asname or a.name] = f"{node.module}.{a.name}"
    return out


def resolve_call(node: ast.AST, imports: dict[str, str]) -> str | None:
    """Fully qualified dotted path of a call target, through import aliases.

    ``np.random.rand`` with ``import numpy as np`` -> "numpy.random.rand";
    ``P(...)`` with ``from jax.sharding import PartitionSpec as P`` ->
    "jax.sharding.PartitionSpec".  None when the chain is not rooted at an
    imported name (locals, attributes of call results, ...).
    """
    dotted = dotted_name(node)
    if dotted is None:
        return None
    head, _, rest = dotted.partition(".")
    base = imports.get(head)
    if base is None:
        return None
    return f"{base}.{rest}" if rest else base


def parent_map(tree: ast.Module) -> dict[ast.AST, ast.AST]:
    return {
        child: parent
        for parent in ast.walk(tree)
        for child in ast.iter_child_nodes(parent)
    }


def enclosing_function(node: ast.AST, parents: dict) -> ast.AST | None:
    cur = parents.get(node)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return cur
        cur = parents.get(cur)
    return None


def literal_tuple(node: ast.AST, scope: ast.AST | None) -> ast.Tuple | None:
    """Resolve ``node`` to a literal Tuple, following one level of simple
    ``name = (…)`` assignment inside ``scope``.  None when ambiguous."""
    if isinstance(node, ast.Tuple):
        return node
    if isinstance(node, ast.Name) and scope is not None:
        hits = [
            n.value
            for n in ast.walk(scope)
            if isinstance(n, ast.Assign)
            and len(n.targets) == 1
            and isinstance(n.targets[0], ast.Name)
            and n.targets[0].id == node.id
        ]
        if len(hits) == 1 and isinstance(hits[0], ast.Tuple):
            return hits[0]
    return None


def string_constants(node: ast.AST) -> list[tuple[str, int]]:
    """Every string literal under ``node`` with its line number."""
    return [
        (n.value, n.lineno)
        for n in ast.walk(node)
        if isinstance(n, ast.Constant) and isinstance(n.value, str)
    ]


# ---------------------------------------------------------------------------
# repo facts: the declared logical / mesh axis vocabulary
# ---------------------------------------------------------------------------


# fallback vocabulary when repro/dist/sharding.py is not under the scanned
# roots (e.g. linting a single test file from elsewhere) — a snapshot of the
# rule tables, used only as a last resort
DEFAULT_LOGICAL_AXES = frozenset(
    {
        "batch", "model", "seq", "residual_seq", "embed", "heads", "kv_heads",
        "ffn", "vocab", "expert", "kv_seq", "nodes",
    }
)
DEFAULT_MESH_AXES = frozenset({"data", "model", "pod"})


@dataclasses.dataclass
class RepoFacts:
    """Axis vocabulary extracted from ``repro/dist/sharding.py``.

    ``logical_axes``: names model code may use in ``constrain``/rule dicts
    (the keys of ``logical_rules``'s tables).  ``mesh_axes``: physical mesh
    axis names logical names may bind to (the values, plus every axis named
    in the module's PartitionSpecs).
    """

    logical_axes: frozenset[str] = DEFAULT_LOGICAL_AXES
    mesh_axes: frozenset[str] = DEFAULT_MESH_AXES
    source: str | None = None  # path the tables were read from

    @classmethod
    def discover(cls, roots: list[Path]) -> "RepoFacts":
        for root in roots:
            base = root if root.is_dir() else root.parent
            for cand in [base, *base.parents]:
                hit = cand / "src" / "repro" / "dist" / "sharding.py"
                if hit.is_file():
                    return cls.from_sharding_module(hit)
            if root.is_dir():
                hits = sorted(root.rglob("repro/dist/sharding.py"))
                if hits:
                    return cls.from_sharding_module(hits[0])
        return cls()

    @classmethod
    def from_sharding_module(cls, path: Path) -> "RepoFacts":
        tree = ast.parse(path.read_text(), filename=str(path))
        logical: set[str] = set()
        mesh: set[str] = set()
        for node in ast.walk(tree):
            if not isinstance(node, ast.FunctionDef):
                continue
            if node.name == "logical_rules":
                for n in ast.walk(node):
                    # rules = {"batch": dp, "model": "model", ...}
                    if isinstance(n, ast.Dict):
                        for k, v in zip(n.keys, n.values):
                            if isinstance(k, ast.Constant) and isinstance(
                                k.value, str
                            ):
                                logical.add(k.value)
                                mesh.update(s for s, _ in string_constants(v))
                    # rules.update(seq=None, heads="model", ...)
                    elif (
                        isinstance(n, ast.Call)
                        and isinstance(n.func, ast.Attribute)
                        and n.func.attr == "update"
                    ):
                        for kw in n.keywords:
                            if kw.arg:
                                logical.add(kw.arg)
                                mesh.update(
                                    s for s, _ in string_constants(kw.value)
                                )
                    # rules["nodes"] = dp + ("model",)
                    elif isinstance(n, ast.Assign) and isinstance(
                        n.targets[0], ast.Subscript
                    ):
                        key = n.targets[0].slice
                        if isinstance(key, ast.Constant) and isinstance(
                            key.value, str
                        ):
                            logical.add(key.value)
                            mesh.update(s for s, _ in string_constants(n.value))
                    # dp = ("pod", "data") if multi_pod else ("data",)
                    elif (
                        isinstance(n, ast.Assign)
                        and isinstance(n.targets[0], ast.Name)
                        and not isinstance(n.value, ast.Dict)
                    ):
                        mesh.update(s for s, _ in string_constants(n.value))
            elif node.name == "kv_seq_axes":
                # returned tuples only (the docstring is prose, not axes)
                for n in ast.walk(node):
                    if isinstance(n, (ast.Return, ast.Assign)) and n.value:
                        mesh.update(s for s, _ in string_constants(n.value))
        if not logical or not mesh:
            return cls(source=str(path))
        return cls(frozenset(logical), frozenset(mesh), str(path))


# ---------------------------------------------------------------------------
# file context + runner
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FileContext:
    """Everything a pass needs about one parsed file (parse once, share)."""

    path: Path
    rel: str                       # path as reported in findings
    tree: ast.Module
    lines: list[str]
    facts: RepoFacts
    imports: dict[str, str]
    _parents: dict | None = None

    @property
    def parents(self) -> dict:
        if self._parents is None:
            self._parents = parent_map(self.tree)
        return self._parents

    def resolve(self, node: ast.AST) -> str | None:
        return resolve_call(node, self.imports)


def all_passes():
    from repro.analysis import (
        rules_determinism,
        rules_jit,
        rules_pallas,
        rules_sharding,
    )

    return [rules_sharding, rules_pallas, rules_determinism, rules_jit]


def rule_catalog() -> dict[str, str]:
    out: dict[str, str] = {}
    for p in all_passes():
        out.update(p.RULES)
    return out


def suppressed_rules(line_text: str) -> set[str] | None:
    """Rules suppressed on this line: a set of names, the universal set
    (returned as ``{"*"}``) for a bare ``# repro: ignore``, or None."""
    m = SUPPRESS_RE.search(line_text)
    if not m:
        return None
    if m.group(1) is None:
        return {"*"}
    return {r.strip() for r in m.group(1).split(",") if r.strip()}


@dataclasses.dataclass
class Report:
    findings: list[Finding]
    suppressed: list[Finding]
    n_files: int
    facts: RepoFacts
    errors: list[Finding]  # unparseable files (reported, non-fatal)

    def to_dict(self) -> dict:
        return {
            "findings": [f.to_dict() for f in self.findings],
            "suppressed": [f.to_dict() for f in self.suppressed],
            "errors": [f.to_dict() for f in self.errors],
            "n_files": self.n_files,
            "rules": rule_catalog(),
            "facts": {
                "logical_axes": sorted(self.facts.logical_axes),
                "mesh_axes": sorted(self.facts.mesh_axes),
                "source": self.facts.source,
            },
        }


def iter_py_files(paths: list[Path], include_fixtures: bool = False):
    for p in paths:
        if p.is_file() and p.suffix == ".py":
            yield p
            continue
        if not p.is_dir():
            continue
        for f in sorted(p.rglob("*.py")):
            parts = set(f.parts)
            if parts & SKIP_DIRS:
                continue
            if not include_fixtures and "analysis_fixtures" in parts:
                continue
            yield f


def analyze_file(
    path: Path, facts: RepoFacts, rel: str | None = None
) -> tuple[list[Finding], list[Finding]]:
    """(active findings, suppressed findings) for one file."""
    rel = rel or path.as_posix()
    src = path.read_text()
    tree = ast.parse(src, filename=rel)
    lines = src.splitlines()
    ctx = FileContext(
        path=path, rel=rel, tree=tree, lines=lines, facts=facts,
        imports=import_map(tree),
    )
    active: list[Finding] = []
    suppressed: list[Finding] = []
    for p in all_passes():
        for f in p.run(ctx):
            text = lines[f.line - 1] if 0 < f.line <= len(lines) else ""
            sup = suppressed_rules(text)
            if sup is not None and ("*" in sup or f.rule in sup):
                suppressed.append(f)
            else:
                active.append(f)
    key = lambda f: (f.file, f.line, f.rule)  # noqa: E731
    return sorted(active, key=key), sorted(suppressed, key=key)


def analyze_paths(
    paths: list[str | Path], include_fixtures: bool = False,
    facts: RepoFacts | None = None,
) -> Report:
    roots = [Path(p) for p in paths]
    facts = facts or RepoFacts.discover(roots)
    findings: list[Finding] = []
    suppressed: list[Finding] = []
    errors: list[Finding] = []
    n = 0
    for f in iter_py_files(roots, include_fixtures):
        n += 1
        rel = f.as_posix()
        try:
            a, s = analyze_file(f, facts, rel)
        except SyntaxError as e:
            errors.append(
                Finding(rel, e.lineno or 0, "parse-error", str(e.msg))
            )
            continue
        findings.extend(a)
        suppressed.extend(s)
    return Report(findings, suppressed, n, facts, errors)
