"""Pallas-kernel discipline pass.

The repo's kernels are validated in ``interpret=True`` mode (this container
has no TPU), so every ``pl.pallas_call`` site must stay interpret-equivalent:

- the grid rank must match every index map's arity and every index map's
  returned tuple must match its BlockSpec block-shape rank (a mismatch
  compiles on TPU into silent wrong indexing or fails only at lowering);
- index maps must be pure lambdas over their grid arguments — closing over
  a global or tracer captures a value at trace time and diverges between
  interpret and compiled runs (the sanctioned capture idiom is a lambda
  default, ``lambda h, i, j, g=group: ...``, which binds at definition);
- Python ``if``/``while`` on a Ref value inside a kernel body is a trace
  error on TPU but may silently "work" in interpret mode — use ``pl.when``
  / ``jnp.where``;
- every ``pallas_call`` must expose an ``interpret=`` kwarg path so CI's
  kernels-interpret lane can reach it.

The checks are intentionally literal: a grid/BlockSpec that can't be
resolved to a tuple literal (through one simple local assignment) is
skipped, not guessed at.
"""
from __future__ import annotations

import ast
import builtins

from repro.analysis.core import (
    FileContext,
    Finding,
    dotted_name,
    enclosing_function,
    literal_tuple,
)

RULES = {
    "pallas-grid-blockspec-rank": (
        "BlockSpec index-map arity / block-shape rank disagrees with the "
        "pallas_call grid"
    ),
    "pallas-index-map-closure": (
        "BlockSpec index map closes over a non-parameter name (capture it "
        "as a lambda default instead)"
    ),
    "pallas-ref-branch": (
        "Python if/while branches on a kernel Ref value — use pl.when or "
        "jnp.where"
    ),
    "pallas-no-interpret": (
        "pallas_call has no interpret=-reachable path for CI's interpret "
        "lane"
    ),
}

_BUILTINS = frozenset(dir(builtins))


def _is_pallas_call(ctx: FileContext, node: ast.Call) -> bool:
    resolved = ctx.resolve(node.func)
    if resolved in (
        "jax.experimental.pallas.pallas_call",
        "jax.experimental.pallas.triton.pallas_call",
    ):
        return True
    dotted = dotted_name(node.func)
    return dotted is not None and dotted.endswith("pl.pallas_call")


def _kwarg(call: ast.Call, name: str) -> ast.AST | None:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _block_specs(node: ast.AST | None) -> list[ast.Call]:
    """BlockSpec constructor calls under an in_specs/out_specs expression
    (a single BlockSpec, or a list/tuple of them)."""
    if node is None:
        return []
    return [
        n
        for n in ast.walk(node)
        if isinstance(n, ast.Call)
        and (dotted_name(n.func) or "").split(".")[-1] == "BlockSpec"
    ]


def _lambda_params(fn: ast.Lambda) -> tuple[list[str], int]:
    """(all parameter names, count of non-default positional params)."""
    a = fn.args
    pos = [p.arg for p in [*a.posonlyargs, *a.args]]
    names = pos + [p.arg for p in a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names, len(pos) - len(a.defaults)


def _check_index_map(
    ctx: FileContext, spec: ast.Call, grid_rank: int | None, scope
):
    # BlockSpec(block_shape, index_map) — both positional in this repo
    shape_node = spec.args[0] if spec.args else _kwarg(spec, "block_shape")
    fn = spec.args[1] if len(spec.args) > 1 else _kwarg(spec, "index_map")
    if not isinstance(fn, ast.Lambda):
        return
    params, n_positional = _lambda_params(fn)

    if grid_rank is not None and n_positional != grid_rank:
        yield Finding(
            ctx.rel, fn.lineno, "pallas-grid-blockspec-rank",
            f"index map takes {n_positional} grid indices but the grid has "
            f"rank {grid_rank}",
        )

    shape_tuple = literal_tuple(shape_node, scope) if shape_node else None
    if shape_tuple is not None and isinstance(fn.body, ast.Tuple):
        if len(fn.body.elts) != len(shape_tuple.elts):
            yield Finding(
                ctx.rel, fn.lineno, "pallas-grid-blockspec-rank",
                f"index map returns {len(fn.body.elts)} coordinates for a "
                f"rank-{len(shape_tuple.elts)} block shape",
            )

    for n in ast.walk(fn.body):
        if (
            isinstance(n, ast.Name)
            and isinstance(n.ctx, ast.Load)
            and n.id not in params
            and n.id not in _BUILTINS
        ):
            yield Finding(
                ctx.rel, n.lineno, "pallas-index-map-closure",
                f'index map closes over "{n.id}" — bind it as a lambda '
                f"default ({n.id}={n.id})",
            )


def _kernel_function(
    ctx: FileContext, call: ast.Call, scope
) -> ast.FunctionDef | None:
    """Resolve pallas_call's first argument to its kernel FunctionDef,
    through the ``kernel = functools.partial(_fn, ...)`` idiom."""
    if not call.args:
        return None
    target = call.args[0]
    name: str | None = None
    if isinstance(target, ast.Name):
        name = target.id
        # one level of `kernel = functools.partial(_fn, ...)`
        if scope is not None:
            for n in ast.walk(scope):
                if (
                    isinstance(n, ast.Assign)
                    and len(n.targets) == 1
                    and isinstance(n.targets[0], ast.Name)
                    and n.targets[0].id == name
                    and isinstance(n.value, ast.Call)
                    and (dotted_name(n.value.func) or "").endswith("partial")
                    and n.value.args
                    and isinstance(n.value.args[0], ast.Name)
                ):
                    name = n.value.args[0].id
                    break
    elif isinstance(target, ast.Call) and (
        dotted_name(target.func) or ""
    ).endswith("partial"):
        if target.args and isinstance(target.args[0], ast.Name):
            name = target.args[0].id
    if name is None:
        return None
    for n in ast.walk(ctx.tree):
        if isinstance(n, ast.FunctionDef) and n.name == name:
            return n
    return None


def _check_ref_branches(ctx: FileContext, kernel: ast.FunctionDef):
    refs = {
        a.arg
        for a in [*kernel.args.posonlyargs, *kernel.args.args,
                  *kernel.args.kwonlyargs]
        if a.arg.endswith(("_ref", "_scr"))
    }
    if not refs:
        return
    for n in ast.walk(kernel):
        if isinstance(n, (ast.If, ast.While, ast.IfExp)):
            touched = sorted(
                m.id
                for m in ast.walk(n.test)
                if isinstance(m, ast.Name) and m.id in refs
            )
            if touched:
                yield Finding(
                    ctx.rel, n.test.lineno, "pallas-ref-branch",
                    f"Python branch on Ref value(s) {', '.join(touched)} — "
                    "this traces on data, use pl.when/jnp.where",
                )


def run(ctx: FileContext):
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Call) and _is_pallas_call(ctx, node)):
            continue
        scope = enclosing_function(node, ctx.parents)

        interp = _kwarg(node, "interpret")
        if interp is None or (
            isinstance(interp, ast.Constant) and interp.value is False
        ):
            yield Finding(
                ctx.rel, node.lineno, "pallas-no-interpret",
                "pallas_call never enables interpret mode — plumb an "
                "interpret= kwarg through to it",
            )

        grid_node = _kwarg(node, "grid")
        grid_tuple = literal_tuple(grid_node, scope) if grid_node else None
        grid_rank = len(grid_tuple.elts) if grid_tuple is not None else None

        for spec in [
            *_block_specs(_kwarg(node, "in_specs")),
            *_block_specs(_kwarg(node, "out_specs")),
        ]:
            yield from _check_index_map(ctx, spec, grid_rank, scope)

        kernel = _kernel_function(ctx, node, scope)
        if kernel is not None:
            yield from _check_ref_branches(ctx, kernel)
