"""Jitted wrapper: Pallas on TPU, interpret-mode Pallas elsewhere."""
from __future__ import annotations

import jax

from repro.kernels.embedding_bag.embedding_bag import hot_embedding_bag_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def hot_embedding_bag(table, ids, *, tile_b: int = 128):
    """Fused hot-table SLS: table [H, D], ids [B, P] -> [B, D].

    Pads the batch up to tile_b internally."""
    B = ids.shape[0]
    pad = (-B) % tile_b
    if pad:
        import jax.numpy as jnp

        ids = jnp.pad(ids, ((0, pad), (0, 0)), constant_values=-1)
    out = hot_embedding_bag_pallas(
        table, ids, tile_b=tile_b, interpret=not _on_tpu()
    )
    return out[:B] if pad else out
