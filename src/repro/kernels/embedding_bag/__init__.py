from repro.kernels.embedding_bag.ops import hot_embedding_bag
from repro.kernels.embedding_bag.ref import hot_embedding_bag_ref

__all__ = ["hot_embedding_bag", "hot_embedding_bag_ref"]
