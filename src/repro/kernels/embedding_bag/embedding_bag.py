"""Fused hot-embedding SparseLengthsSum Pallas kernel.

TPU adaptation of the paper's locality-aware hot-table partition: the hot
table (sized by repro.core.partition to the fast-memory budget) is pinned
whole in VMEM; each grid step streams one batch tile of ids into VMEM and
performs the gather + pool on-chip, writing only the pooled [tile, D] rows
back. This replaces the NMP DIMM's rank-parallel Gather-Reduce with a
VMEM-resident gather: HBM sees ids in and pooled vectors out — never the
P individual rows.

Grid: (B // tile_b,). BlockSpecs:
    table [H, D]    — constant block (index_map -> (0, 0)), lives in VMEM
                      across grid steps; H*D*dtype must fit the ~16 MB
                      twin-buffer budget (the partitioner guarantees it).
    ids   [tile_b, P] int32 — per-step tile.
    out   [tile_b, D]       — per-step tile.

The inner gather uses jnp.take on the VMEM-resident block (vector gather
on current TPU gens; exact in interpret mode, which is how this container
validates it).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(table_ref, ids_ref, out_ref):
    ids = ids_ref[...]                       # [tile_b, P] int32
    table = table_ref[...]                   # [H, D]
    tile_b, P = ids.shape
    mask = (ids >= 0).astype(table.dtype)    # [tile_b, P]
    safe = jnp.maximum(ids, 0)
    rows = jnp.take(table, safe.reshape(-1), axis=0)
    rows = rows.reshape(tile_b, P, -1)
    out_ref[...] = (rows * mask[..., None]).sum(axis=1)


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def hot_embedding_bag_pallas(table: jax.Array, ids: jax.Array, *,
                             tile_b: int = 128, interpret: bool = False):
    """table [H, D]; ids [B, P] (-1 padded) -> pooled [B, D]."""
    B, P = ids.shape
    H, D = table.shape
    if B % tile_b:
        raise ValueError(f"batch {B} must be a multiple of tile_b {tile_b}")
    grid = (B // tile_b,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((H, D), lambda i: (0, 0)),       # table resident
            pl.BlockSpec((tile_b, P), lambda i: (i, 0)),  # ids tile
        ],
        out_specs=pl.BlockSpec((tile_b, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, D), table.dtype),
        interpret=interpret,
    )(table, ids)
