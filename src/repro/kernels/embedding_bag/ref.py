"""Pure-jnp oracle for the fused hot-embedding SparseLengthsSum kernel."""
from __future__ import annotations

import jax.numpy as jnp


def hot_embedding_bag_ref(table, ids, weights=None):
    """table [H, D]; ids [B, P] int32 (-1 padded); optional per-sample
    weights [B, P] -> pooled [B, D] (sum of table rows per bag)."""
    mask = (ids >= 0)
    safe = jnp.maximum(ids, 0)
    rows = jnp.take(table, safe, axis=0)             # [B, P, D]
    w = mask.astype(table.dtype)
    if weights is not None:
        w = w * weights.astype(table.dtype)
    return (rows * w[..., None]).sum(axis=1)
