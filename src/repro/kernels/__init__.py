"""Pallas TPU kernels for the perf-critical compute layers.

- embedding_bag: fused SparseLengthsSum over a VMEM-resident hot table —
  the TPU-native adaptation of the paper's hot-embedding partition (the
  NMP Gather-Reduce insight mapped to the HBM->VMEM hierarchy).
- flash_attention: blocked causal GQA attention (prefill) + split-KV decode
  for the LM serving cells.
- dot_interaction: DLRM pairwise-dot feature interaction fused with the
  triu extraction.

Each kernel ships <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper with interpret=True fallback off-TPU) and ref.py (pure-jnp oracle);
tests sweep shapes/dtypes against the oracle.
"""
