from repro.kernels.flash_attention.flash_decode import (
    flash_decode_partials,
    lse_combine,
)
from repro.kernels.flash_attention.ops import flash_attention, flash_decode
from repro.kernels.flash_attention.ref import attention_ref

__all__ = ["flash_attention", "flash_decode", "flash_decode_partials",
           "lse_combine", "attention_ref"]
