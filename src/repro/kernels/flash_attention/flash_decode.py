"""Split-KV flash decode (FlashDecoding-style) Pallas kernel.

Decode attends one query token against a long KV cache; a single-block
kernel leaves the chip idle (one query row). The split-KV schedule carves
the cache into S // bk chunks, computes per-chunk partial
(max, denom, weighted-sum) — embarrassingly parallel across chunks — and
combines with a log-sum-exp merge. The same merge (exposed as
``lse_combine``) is what the DISTRIBUTED flash decode in repro.dist.decode
uses to combine per-shard partials across the model axis for the long_500k
cell, so the on-chip and cross-chip schedules share one correctness oracle.

Positions are GLOBAL: ``kv_offset`` is the base position of k/v's first row
(a traced scalar — each shard of a sequence-sharded cache passes its own
base), and ``kv_len`` masks against global position, so a shard whose slice
starts past ``kv_len`` contributes an empty partial rather than requiring
the caller to pre-truncate.

Grid (B*KVH, n_chunks): per (batch x kv-head), each chunk produces
partials; group query heads for that kv head are processed together as a
[group, hd] tile (GQA: the MXU sees a [group, bk] x [bk, hd] matmul).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(off_ref, q_ref, k_ref, v_ref, m_ref, l_ref, o_ref, *,
                   scale, kv_len, bk):
    """One KV chunk: q [group, hd]; k/v [bk, hd] -> partial m/l/o.

    off_ref holds the global position of k/v row 0 (shard base offset);
    chunk c covers global positions off + [c*bk, (c+1)*bk).
    """
    c = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)              # [group, hd]
    k = k_ref[0].astype(jnp.float32)              # [bk, hd]
    v = v_ref[0].astype(jnp.float32)
    s = (q @ k.T) * scale                         # [group, bk]
    kpos = off_ref[0, 0] + c * bk + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)
    s = jnp.where(kpos < kv_len, s, NEG_INF)
    m = s.max(axis=1, keepdims=True)              # [group, 1]
    p = jnp.exp(s - m)
    # fully-masked chunk: m == NEG_INF and p == 1 everywhere; zero the
    # weights so the partial is exactly empty (l = 0, o = 0) instead of
    # relying on a downstream merge to suppress it — the partial itself is
    # part of the distributed-decode contract.
    p = jnp.where(kpos < kv_len, p, 0.0)
    l = p.sum(axis=1, keepdims=True)
    o = p @ v                                     # [group, hd]
    m_ref[0, 0] = m
    l_ref[0, 0] = l
    o_ref[0, 0] = o.astype(o_ref.dtype)


def lse_combine(m, l, o, axis: int):
    """Merge split-softmax partials along `axis`.

    m/l: [..., n, group, 1]; o: [..., n, group, hd] -> combined [..., group, hd]
    plus the combined (m, l) for further hierarchical merging."""
    m_max = m.max(axis=axis, keepdims=True)
    alpha = jnp.exp(m - m_max)
    l_comb = (l * alpha).sum(axis=axis)
    o_comb = (o * alpha).sum(axis=axis)
    return m_max.squeeze(axis), l_comb, o_comb


@functools.partial(jax.jit, static_argnames=("kv_len", "bk", "interpret"))
def flash_decode_partials(q, k, v, *, kv_len, kv_offset=0, bk=512,
                          interpret=False):
    """Per-(batch, kv-head, group) softmax partials over a KV slice.

    q [B, 1, H, hd]; k/v [B, S, KVH, hd] holding global positions
    [kv_offset, kv_offset + S); kv_len masks against global position.
    Returns (m, l, o) float32 of shapes [B, KVH, group, 1] x2 and
    [B, KVH, group, hd], already merged over the local chunks — the
    caller (repro.dist.decode) merges across shards with ``lse_combine``.
    """
    B, _, H, hd = q.shape
    S, KVH = k.shape[1], k.shape[2]
    group = H // KVH
    bk = min(bk, S)
    if S % bk:
        raise ValueError(f"S {S} % bk {bk} != 0")
    n_chunks = S // bk

    qf = q.reshape(B, KVH, group, hd).reshape(B * KVH, group, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KVH, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KVH, S, hd)
    off = jnp.asarray(kv_offset, jnp.int32).reshape(1, 1)

    grid = (B * KVH, n_chunks)
    kernel = functools.partial(
        _decode_kernel, scale=1.0 / np.sqrt(hd), kv_len=kv_len, bk=bk
    )
    m, l, o = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda h, c: (0, 0)),
            pl.BlockSpec((1, group, hd), lambda h, c: (h, 0, 0)),
            pl.BlockSpec((1, bk, hd), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, bk, hd), lambda h, c: (h, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, group, 1), lambda h, c: (h, c, 0, 0)),
            pl.BlockSpec((1, 1, group, 1), lambda h, c: (h, c, 0, 0)),
            pl.BlockSpec((1, 1, group, hd), lambda h, c: (h, c, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * KVH, n_chunks, group, 1), jnp.float32),
            jax.ShapeDtypeStruct((B * KVH, n_chunks, group, 1), jnp.float32),
            jax.ShapeDtypeStruct((B * KVH, n_chunks, group, hd), jnp.float32),
        ],
        interpret=interpret,
    )(off, qf, kf, vf)
    m_c, l_c, o_c = lse_combine(m, l, o, axis=1)  # over local chunks
    return (m_c.reshape(B, KVH, group, 1),
            l_c.reshape(B, KVH, group, 1),
            o_c.reshape(B, KVH, group, hd))


@functools.partial(jax.jit, static_argnames=("kv_len", "bk", "interpret"))
def flash_decode_pallas(q, k, v, *, kv_len, kv_offset=0, bk=512,
                        interpret=False):
    """q [B, 1, H, hd]; k/v [B, S, KVH, hd]; kv_len: live cache length.

    Returns [B, 1, H, hd]."""
    B, _, H, hd = q.shape
    _, l_c, o_c = flash_decode_partials(
        q, k, v, kv_len=kv_len, kv_offset=kv_offset, bk=bk,
        interpret=interpret,
    )
    out = (o_c / jnp.maximum(l_c, 1e-30)).astype(q.dtype)
    return out.reshape(B, H, hd).reshape(B, 1, H, hd)
