"""Blocked causal GQA flash attention (Pallas TPU).

Grid (B*H, Tq // bq, Tk // bk): the KV-block axis is innermost and
sequential, carrying the running (max, denom, accum) in VMEM scratch — the
standard IO-aware schedule: Q tiles stay resident, KV streams once through
VMEM, O is written once. GQA is folded by indexing the KV head as
``h // group`` in the KV BlockSpec index map, so no KV duplication is ever
materialized.

Block sizes default to (bq, bk) = (128, 128): MXU-aligned on the lane dim
(head_dim is the minor dim of every matmul) and the working set
(q + k + v + acc tiles, ~4 x 128 x 128 x 4 B) sits far under the ~16 MB
VMEM budget, leaving room for the pipeline emitter's double buffering.

Causal handling: whole-tile skip for blocks strictly above the diagonal
(predicated on grid coordinates via pl.when) and an element mask on
diagonal blocks; ``q_offset`` aligns decode/cache positions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale, causal, q_offset, bq, bk, n_kblocks):
    qb = pl.program_id(1)
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = q_offset + qb * bq
    k_start = kb * bk
    # whole-tile causal skip: live unless every query precedes every key
    live = jnp.asarray(True) if not causal else (q_start + bq - 1 >= k_start)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # [bq, hd]
        k = k_ref[0].astype(jnp.float32)          # [bk, hd]
        v = v_ref[0].astype(jnp.float32)          # [bk, hd]
        s = (q @ k.T) * scale                     # [bq, bk]
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_scr[...]                       # [bq, 1]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                    # [bq, bk]
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + p @ v
        m_scr[...] = m_new

    @pl.when(kb == n_kblocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "q_offset", "bq", "bk", "interpret"),
)
def flash_attention_pallas(q, k, v, *, causal=True, q_offset=0,
                           bq=128, bk=128, interpret=False):
    """q [B, Tq, H, hd]; k/v [B, Tk, KVH, hd] -> [B, Tq, H, hd]."""
    B, Tq, H, hd = q.shape
    Tk, KVH = k.shape[1], k.shape[2]
    group = H // KVH
    bq = min(bq, Tq)
    bk = min(bk, Tk)
    if Tq % bq or Tk % bk:
        raise ValueError(f"Tq {Tq} % bq {bq} or Tk {Tk} % bk {bk} != 0")

    # head-major layouts: q [B*H, Tq, hd]; kv [B*KVH, Tk, hd]
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Tq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KVH, Tk, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KVH, Tk, hd)

    grid = (B * H, Tq // bq, Tk // bk)
    kernel = functools.partial(
        _fa_kernel, scale=1.0 / np.sqrt(hd), causal=causal,
        q_offset=q_offset, bq=bq, bk=bk, n_kblocks=Tk // bk,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda h, i, j, g=group: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Tq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # running denom
            pltpu.VMEM((bq, hd), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Tq, hd).transpose(0, 2, 1, 3)
