"""Pure-jnp oracle for the flash attention kernels (GQA, causal offset)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal=True, q_offset=0, kv_len=None):
    """q [B, Tq, H, hd]; k/v [B, Tk, KVH, hd] -> [B, Tq, H, hd].

    Query i's absolute position is q_offset + i; with causal it attends to
    kv j <= q_offset + i. kv_len (scalar or [B]) masks the cache tail.
    """
    B, Tq, H, hd = q.shape
    Tk, KVH = k.shape[1], k.shape[2]
    group = H // KVH
    qg = q.reshape(B, Tq, KVH, group, hd)
    logits = jnp.einsum("btkgh,bskh->bkgts", qg, k) / np.sqrt(hd)
    logits = logits.astype(jnp.float32)
    jpos = jnp.arange(Tk)[None, :]
    mask = jnp.ones((Tq, Tk), bool)
    if causal:
        mask = jpos <= (jnp.arange(Tq)[:, None] + q_offset)
    if kv_len is not None:
        mask = mask & (jpos < jnp.asarray(kv_len).reshape(-1)[0])
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgts,bskh->btkgh", probs, v)
    return out.reshape(B, Tq, H, hd)
