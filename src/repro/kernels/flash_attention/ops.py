"""Jitted wrappers: Pallas on TPU, interpret mode elsewhere."""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.flash_decode import flash_decode_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal=True, q_offset=0, bq=128, bk=128):
    """Blocked GQA attention: q [B, Tq, H, hd], k/v [B, Tk, KVH, hd]."""
    return flash_attention_pallas(
        q, k, v, causal=causal, q_offset=q_offset, bq=bq, bk=bk,
        interpret=not _on_tpu(),
    )


def flash_decode(q, k, v, *, kv_len, kv_offset=0, bk=512):
    """Split-KV decode: q [B, 1, H, hd] against cache k/v [B, S, KVH, hd].

    kv_offset: global position of k/v row 0 (non-zero for a shard of a
    sequence-sharded cache); kv_len masks against global position.
    """
    return flash_decode_pallas(q, k, v, kv_len=kv_len, kv_offset=kv_offset,
                               bk=bk, interpret=not _on_tpu())
