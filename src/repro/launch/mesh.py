"""Production mesh construction.

Defined as functions (not module-level constants) so importing this module
never touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to build these meshes on the CPU container.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 4):
    """Small mesh for CPU multi-device tests (8 fake devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def all_axes(mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)
