"""Per-(architecture x shape) program builders.

``build_cell(arch_id, shape_name, mesh=None, multi_pod=False)`` returns a
CellProgram bundling the jittable step function, abstract input/parameter
specs (ShapeDtypeStruct — no allocation), and in/out shardings. The same
builder serves the smoke tests (mesh=None, SMOKE config, real arrays) and
the 512-chip dry-run (FULL config, abstract lowering only).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.common.types import ArchKind, ShapeSpec
from repro.configs.registry import get_arch
from repro.dist import logical
from repro.dist.sharding import (
    kv_cache_spec,
    kv_seq_axes,
    logical_rules,
    opt_spec_tree,
    param_spec_tree,
)
from repro.models import din as din_lib
from repro.models import dlrm as dlrm_lib
from repro.models import gnn as gnn_lib
from repro.models import mind as mind_lib
from repro.models import transformer as tf_lib
from repro.models import widedeep as wnd_lib
from repro.models.recsys_base import RecsysConfig, binary_ce
from repro.models.recsys_base import input_specs as recsys_input_specs
from repro.train import optimizer as opt_lib

RECSYS_APPLY = {
    "dot": dlrm_lib.apply,
    "concat": wnd_lib.apply,
    "target-attn": din_lib.apply,
    "multi-interest": mind_lib.apply,
}
RECSYS_INIT = {
    "dot": dlrm_lib.init,
    "concat": wnd_lib.init,
    "target-attn": din_lib.init,
    "multi-interest": mind_lib.init,
}


@dataclasses.dataclass
class CellProgram:
    arch_id: str
    shape: ShapeSpec
    kind: ArchKind
    cfg: Any
    step_fn: Callable                  # step(state, batch) -> outputs
    state_specs: Any                   # ShapeDtypeStruct pytree
    batch_specs: Any                   # ShapeDtypeStruct pytree
    state_shardings: Any = None        # NamedSharding pytree (mesh runs)
    batch_shardings: Any = None
    mesh: Any = None
    multi_pod: bool = False
    donate_state: bool = True
    donate_batch: bool = False         # decode: donate the KV cache
    init_state: Callable | None = None  # real init for smoke runs
    rules: dict | None = None          # logical axis bindings

    def _ctx(self):
        if self.mesh is None:
            import contextlib

            return contextlib.nullcontext()
        rules = self.rules or logical_rules(self.kind, self.multi_pod)
        return logical.axis_rules(self.mesh, rules)

    def jitted(self):
        kwargs = {}
        if self.mesh is not None:
            kwargs["in_shardings"] = (self.state_shardings, self.batch_shardings)
        donate = []
        if self.donate_state:
            donate.append(0)
        if self.donate_batch:
            donate.append(1)
        if donate:
            kwargs["donate_argnums"] = tuple(donate)
        return jax.jit(self.step_fn, **kwargs)

    def lower(self):
        with self._ctx():
            return self.jitted().lower(self.state_specs, self.batch_specs)

    def run(self, state, batch):
        with self._ctx():
            return self.jitted()(state, batch)


def _shardings_from_specs(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _dp_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------


def _lm_cell(arch, shape: ShapeSpec, mesh, multi_pod: bool) -> CellProgram:
    cfg = arch.FULL if mesh is not None else arch.SMOKE
    kind = arch.KIND
    dp = _dp_axes(multi_pod)
    B = shape["global_batch"]
    S = shape["seq_len"]
    if mesh is None:  # smoke: shrink the cell
        B, S = 4, 32

    params_shape = jax.eval_shape(lambda: tf_lib.init(jax.random.PRNGKey(0), cfg))
    p_specs = param_spec_tree(kind, params_shape)

    if shape.step == "train":
        opt = opt_lib.adamw(lr=3e-4)
        opt_shape = jax.eval_shape(opt.init, params_shape)
        o_specs = opt_spec_tree(kind, opt_shape, p_specs)
        state_specs = {"params": params_shape, "opt": opt_shape}
        state_spec_tree = {"params": p_specs, "opt": o_specs}
        batch_specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        batch_spec_tree = {"tokens": P(dp, None)}

        def step(state, batch):
            loss, grads = jax.value_and_grad(tf_lib.lm_loss)(
                state["params"], batch, cfg
            )
            params, opt_state = opt.update(state["params"], grads, state["opt"])
            return {"params": params, "opt": opt_state}, {"loss": loss}

        def init_state(key):
            params = tf_lib.init(key, cfg)
            return {"params": params, "opt": opt.init(params)}

    elif shape.step == "prefill":
        state_specs = params_shape
        state_spec_tree = p_specs
        batch_specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        batch_spec_tree = {"tokens": P(dp, None)}

        def step(params, batch):
            cache = tf_lib.init_kv_cache(cfg, B, S)
            last, new_cache = tf_lib.prefill(params, batch["tokens"], cache, cfg)
            return {"logits": last, "cache": new_cache}

        def init_state(key):
            return tf_lib.init(key, cfg)

    else:  # decode (decode_32k / long_500k): one token against an S cache
        if mesh is not None:
            # the seq-sharded cache is served by the distributed flash
            # decode (repro.dist.decode) instead of falling back to a
            # local single-block attention over a gathered cache
            cfg = dataclasses.replace(cfg, decode_impl="flash")
        state_specs = params_shape
        state_spec_tree = p_specs
        cache_specs = tf_lib.kv_cache_specs(cfg, B, S)
        # KV sharding: batch over dp when it divides; sequence over "model"
        # (and over dp too when batch == 1 — long_500k's only option).
        kv_spec = kv_cache_spec(B, multi_pod)
        batch_specs = {
            "token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
            "cache": cache_specs,
        }
        batch_spec_tree = {
            "token": P(dp, None) if B >= 16 else P(None, None),
            "cache": {key: kv_spec for key in cache_specs},
        }
        pos = S - 1

        def step(params, batch):
            logits, new_cache = tf_lib.decode_step(
                params, batch["token"], batch["cache"], pos, cfg
            )
            return {"logits": logits, "cache": new_cache}

        def init_state(key):
            return tf_lib.init(key, cfg)

    rules = logical_rules(kind, multi_pod)
    if getattr(cfg, "seq_shard", False):
        rules = dict(rules)
        rules["residual_seq"] = "model"
    if shape.step == "decode":
        rules = dict(rules)
        rules["kv_seq"] = kv_seq_axes(B, multi_pod)
        if B < 16:
            rules["batch"] = None  # batch=1: token replicated, KV seq-sharded
    return CellProgram(
        arch_id=arch.ARCH_ID, shape=shape, kind=kind, cfg=cfg, step_fn=step,
        state_specs=state_specs, batch_specs=batch_specs,
        state_shardings=_shardings_from_specs(mesh, state_spec_tree) if mesh else None,
        batch_shardings=_shardings_from_specs(mesh, batch_spec_tree) if mesh else None,
        mesh=mesh, multi_pod=multi_pod,
        donate_state=(shape.step == "train"),
        donate_batch=(shape.step == "decode"),
        init_state=init_state,
        rules=rules,
    )


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------


def _recsys_batch_spec_tree(specs: dict, dp) -> dict:
    out = {}
    for k, v in specs.items():
        if v.ndim >= 1 and v.shape[0] > 1:
            out[k] = P(dp, *([None] * (v.ndim - 1)))
        else:
            out[k] = P(*([None] * v.ndim))
    return out


def _recsys_cell(arch, shape: ShapeSpec, mesh, multi_pod: bool) -> CellProgram:
    cfg: RecsysConfig = arch.FULL if mesh is not None else arch.SMOKE
    kind = arch.KIND
    dp = _dp_axes(multi_pod)
    B = shape["batch"]
    n_cand = shape.get("n_candidates", 0)
    if mesh is None:
        B = 16
        n_cand = 128 if n_cand else 0

    apply_fn = RECSYS_APPLY[cfg.interaction]
    init_fn = RECSYS_INIT[cfg.interaction]
    params_shape = jax.eval_shape(lambda: init_fn(jax.random.PRNGKey(0), cfg))
    p_specs = param_spec_tree(kind, params_shape)

    if shape.step == "train":
        opt = opt_lib.rowwise_adagrad(lr=0.01)
        opt_shape = jax.eval_shape(opt.init, params_shape)
        o_specs = opt_spec_tree(kind, opt_shape, p_specs)
        state_specs = {"params": params_shape, "opt": opt_shape}
        state_spec_tree = {"params": p_specs, "opt": o_specs}
        specs = recsys_input_specs(cfg, B, with_labels=True)
        batch_specs = specs
        batch_spec_tree = _recsys_batch_spec_tree(specs, dp)

        def step(state, batch):
            def loss_fn(params):
                logits = apply_fn(params, batch, cfg)
                return binary_ce(logits, batch["label"])

            loss, grads = jax.value_and_grad(loss_fn)(state["params"])
            params, opt_state = opt.update(state["params"], grads, state["opt"])
            return {"params": params, "opt": opt_state}, {"loss": loss}

        def init_state(key):
            params = init_fn(key, cfg)
            return {"params": params, "opt": opt.init(params)}

    elif n_cand:  # retrieval_cand: one user against n_cand items
        B = shape["batch"]  # always 1 (the retrieval query)
        state_specs = params_shape
        state_spec_tree = p_specs
        if cfg.interaction == "multi-interest":
            specs = recsys_input_specs(cfg, B, n_candidates=n_cand)
            batch_spec_tree = _recsys_batch_spec_tree(specs, dp)

            def step(params, batch):
                return {"scores": mind_lib.retrieval_scores(
                    params, batch, batch["candidate_ids"], cfg)}
        elif cfg.interaction == "target-attn":
            specs = recsys_input_specs(cfg, B, n_candidates=n_cand)
            batch_spec_tree = _recsys_batch_spec_tree(specs, dp)

            def step(params, batch):
                return {"scores": din_lib.retrieval_scores(
                    params, batch, batch["candidate_ids"], cfg)}
        else:
            # CTR rankers score the 1M candidates as a bulk batch
            specs = recsys_input_specs(cfg, n_cand)
            batch_spec_tree = _recsys_batch_spec_tree(specs, dp)

            def step(params, batch):
                return {"scores": apply_fn(params, batch, cfg)}
        batch_specs = specs

        def init_state(key):
            return init_fn(key, cfg)

    else:  # serve
        state_specs = params_shape
        state_spec_tree = p_specs
        specs = recsys_input_specs(cfg, B)
        batch_specs = specs
        batch_spec_tree = _recsys_batch_spec_tree(specs, dp)

        def step(params, batch):
            return {"scores": apply_fn(params, batch, cfg)}

        def init_state(key):
            return init_fn(key, cfg)

    return CellProgram(
        arch_id=arch.ARCH_ID, shape=shape, kind=kind, cfg=cfg, step_fn=step,
        state_specs=state_specs, batch_specs=batch_specs,
        state_shardings=_shardings_from_specs(mesh, state_spec_tree) if mesh else None,
        batch_shardings=_shardings_from_specs(mesh, batch_spec_tree) if mesh else None,
        mesh=mesh, multi_pod=multi_pod,
        donate_state=(shape.step == "train"),
        init_state=init_state,
    )


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------


def _pad_to(n: int, mult: int) -> int:
    return -(-n // mult) * mult


def _gnn_cell(arch, shape: ShapeSpec, mesh, multi_pod: bool) -> CellProgram:
    kind = arch.KIND
    dp = _dp_axes(multi_pod)
    if mesh is None:
        cfg = arch.SMOKE
    else:
        cfg = arch.SHAPE_CONFIGS[shape.name]

    n_dev = 1
    if mesh is not None:
        for a in mesh.axis_names:
            n_dev *= mesh.shape[a]

    opt = opt_lib.adamw(lr=1e-3)

    if cfg.mode == "full":
        N = _pad_to(shape["n_nodes"], max(n_dev, 1)) if mesh else 64
        E = _pad_to(shape["n_edges"], max(n_dev, 1)) if mesh else 256
        params_shape = jax.eval_shape(lambda: gnn_lib.init(jax.random.PRNGKey(0), cfg))
        p_specs = param_spec_tree(kind, params_shape)
        opt_shape = jax.eval_shape(opt.init, params_shape)
        state_specs = {"params": params_shape, "opt": opt_shape}
        state_spec_tree = {"params": p_specs,
                           "opt": opt_spec_tree(kind, opt_shape, p_specs)}
        all_ax = tuple(mesh.axis_names) if mesh else ()
        batch_specs = {
            "feats": jax.ShapeDtypeStruct((N, cfg.d_feat), cfg.dtype),
            "edges": jax.ShapeDtypeStruct((2, E), jnp.int32),
            "labels": jax.ShapeDtypeStruct((N,), jnp.int32),
            "label_mask": jax.ShapeDtypeStruct((N,), jnp.bool_),
        }
        batch_spec_tree = {
            "feats": P(all_ax, None),
            "edges": P(None, all_ax),
            "labels": P(all_ax),
            "label_mask": P(all_ax),
        }

        the_mesh = mesh

        def step(state, batch):
            def loss_fn(params):
                if the_mesh is not None:
                    from repro.dist.gnn import apply_full_sharded

                    return apply_full_sharded(
                        params, batch["feats"], batch["edges"], batch["labels"],
                        batch["label_mask"], cfg, the_mesh, N,
                    )
                logits = gnn_lib.apply_full(params, batch["feats"], batch["edges"], cfg)
                return gnn_lib.softmax_ce(logits, batch["labels"], batch["label_mask"])

            loss, grads = jax.value_and_grad(loss_fn)(state["params"])
            params, opt_state = opt.update(state["params"], grads, state["opt"])
            return {"params": params, "opt": opt_state}, {"loss": loss}

    elif cfg.mode == "mini":
        B = shape.get("batch_nodes", 1024) if mesh else 8
        fan = shape.get("fanout", cfg.fanout)
        specs = gnn_lib.input_specs(cfg, {"batch_nodes": B, "fanout": fan})
        params_shape = jax.eval_shape(lambda: gnn_lib.init(jax.random.PRNGKey(0), cfg))
        p_specs = param_spec_tree(kind, params_shape)
        opt_shape = jax.eval_shape(opt.init, params_shape)
        state_specs = {"params": params_shape, "opt": opt_shape}
        state_spec_tree = {"params": p_specs,
                           "opt": opt_spec_tree(kind, opt_shape, p_specs)}
        batch_specs = specs
        batch_spec_tree = {
            k: P(dp, *([None] * (v.ndim - 1))) for k, v in specs.items()
        }
        L = cfg.n_layers

        def step(state, batch):
            def loss_fn(params):
                hop_feats = [batch[f"hop{j}_feats"] for j in range(L + 1)]
                hop_masks = [None] + [batch[f"hop{j}_mask"] for j in range(1, L + 1)]
                logits = gnn_lib.apply_minibatch(params, hop_feats, hop_masks, cfg)
                return gnn_lib.softmax_ce(logits, batch["labels"])

            loss, grads = jax.value_and_grad(loss_fn)(state["params"])
            params, opt_state = opt.update(state["params"], grads, state["opt"])
            return {"params": params, "opt": opt_state}, {"loss": loss}

    else:  # batched small graphs (molecule)
        G = shape.get("batch", 128) if mesh else 8
        n, e = shape["n_nodes"], shape["n_edges"]
        if mesh is None:
            n, e = 6, 10
        specs = gnn_lib.input_specs(cfg, {"batch": G, "n_nodes": n, "n_edges": e})
        params_shape = jax.eval_shape(lambda: gnn_lib.init(jax.random.PRNGKey(0), cfg))
        p_specs = param_spec_tree(kind, params_shape)
        opt_shape = jax.eval_shape(opt.init, params_shape)
        state_specs = {"params": params_shape, "opt": opt_shape}
        state_spec_tree = {"params": p_specs,
                           "opt": opt_spec_tree(kind, opt_shape, p_specs)}
        batch_specs = specs
        # graphs are independent: shard every packed array on its graph-major
        # leading dim; the per-graph shard_map keeps segment ids local.
        batch_spec_tree = {
            "feats": P(dp, None),
            "edges": P(None, dp),
            "node_mask": P(dp),
            "graph_ids": P(dp),
            "labels": P(dp),
        }
        the_mesh = mesh

        def step(state, batch):
            def loss_fn(params):
                if the_mesh is not None:
                    from repro.dist.gnn import apply_batched_sharded

                    logits, labels = apply_batched_sharded(
                        params, batch, cfg, the_mesh, dp, G, n, e,
                    )
                    return gnn_lib.softmax_ce(logits, labels)
                logits = gnn_lib.apply_batched(
                    params, batch["feats"], batch["edges"], batch["node_mask"],
                    batch["graph_ids"], G, cfg,
                )
                return gnn_lib.softmax_ce(logits, batch["labels"])

            loss, grads = jax.value_and_grad(loss_fn)(state["params"])
            params, opt_state = opt.update(state["params"], grads, state["opt"])
            return {"params": params, "opt": opt_state}, {"loss": loss}

    def init_state(key):
        params = gnn_lib.init(key, cfg)
        return {"params": params, "opt": opt.init(params)}

    return CellProgram(
        arch_id=arch.ARCH_ID, shape=shape, kind=kind, cfg=cfg, step_fn=step,
        state_specs=state_specs, batch_specs=batch_specs,
        state_shardings=_shardings_from_specs(mesh, state_spec_tree) if mesh else None,
        batch_shardings=_shardings_from_specs(mesh, batch_spec_tree) if mesh else None,
        mesh=mesh, multi_pod=multi_pod, donate_state=True,
        init_state=init_state,
    )


# ---------------------------------------------------------------------------


def build_cell(arch_id: str, shape_name: str, mesh=None,
               multi_pod: bool = False, cfg_override=None) -> CellProgram:
    arch = get_arch(arch_id)
    shape = next(s for s in arch.SHAPES if s.name == shape_name)
    if cfg_override is not None:
        # used by the roofline scan-correction (n_layers=1/2 lowering)
        import types

        arch = types.SimpleNamespace(
            ARCH_ID=arch.ARCH_ID, KIND=arch.KIND, SHAPES=arch.SHAPES,
            FULL=cfg_override, SMOKE=getattr(arch, "SMOKE", None),
            SHAPE_CONFIGS=getattr(arch, "SHAPE_CONFIGS", None),
        )
    if arch.KIND in (ArchKind.LM_DENSE, ArchKind.LM_MOE):
        return _lm_cell(arch, shape, mesh, multi_pod)
    if arch.KIND == ArchKind.RECSYS:
        return _recsys_cell(arch, shape, mesh, multi_pod)
    return _gnn_cell(arch, shape, mesh, multi_pod)


def run_cell(cell: CellProgram, fn):
    """Run `fn` under the cell's mesh + logical axis rules (no-op without)."""
    if cell.mesh is None:
        return fn()
    rules = logical_rules(cell.kind, cell.multi_pod)
    with logical.axis_rules(cell.mesh, rules):
        return fn()
