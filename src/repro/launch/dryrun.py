"""Multi-pod dry-run: lower + compile every (architecture x shape) cell on
the production meshes and extract the roofline terms.

MUST be the process entry point (or imported before any other jax-touching
module) — the XLA_FLAGS line below runs before any jax import and pins 512
host devices. Usage:

    PYTHONPATH=src python -m repro.launch.dryrun --arch dlrm-rm2 \
        --shape train_batch --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Artifacts: artifacts/dryrun/<arch>__<shape>__<mesh>.json with
memory_analysis, cost_analysis, and per-collective byte counts parsed from
the partitioned HLO (cost_analysis has no collective term — DESIGN.md §6).
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import json  # noqa: E402
import pathlib  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

ARTIFACTS = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

# TPU v5e hardware constants (per chip) for the roofline terms.
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(bf16|f64|f32|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:%\S+\s*=\s*)?"
    r"(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
    re.MULTILINE,
)

# effective bytes-on-wire multiplier per collective (ring algorithms),
# relative to the RESULT shape bytes.
_WIRE_FACTOR = {
    "all-gather": 1.0,        # each device receives ~result bytes
    "all-reduce": 2.0,        # reduce-scatter + all-gather
    "reduce-scatter": 1.0,    # sends ~operand, receives result; operand ~ result*n
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-kind result bytes (per device) from partitioned HLO."""
    out: dict[str, float] = {}
    counts: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        out[kind] = out.get(kind, 0.0) + b * _WIRE_FACTOR[kind]
        counts[kind + "_count"] = counts.get(kind + "_count", 0) + 1
    out.update(counts)
    return out


def run_cell_dryrun(arch_id: str, shape_name: str, mesh_kind: str,
                    save: bool = True, verbose: bool = True) -> dict:
    import jax

    from repro.launch.mesh import make_debug_mesh, make_production_mesh
    from repro.launch.steps import build_cell

    multi_pod = mesh_kind == "multi"
    if mesh_kind == "debug":
        mesh = make_debug_mesh()
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size

    t0 = time.time()
    cell = build_cell(arch_id, shape_name, mesh=mesh, multi_pod=multi_pod)
    lowered = cell.lower()
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    coll_total = sum(v for k, v in coll.items() if not k.endswith("_count"))

    rec = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": mesh_kind,
        "n_devices": n_dev,
        "time_lower_s": round(t_lower, 2),
        "time_compile_s": round(t_compile, 2),
        # cost_analysis is PER-DEVICE (the partitioned module)
        "flops_per_device": flops,
        "bytes_per_device": bytes_accessed,
        "collective_bytes_per_device": coll_total,
        "collectives": coll,
        "memory": {
            "argument_size_bytes": mem.argument_size_in_bytes,
            "output_size_bytes": mem.output_size_in_bytes,
            "temp_size_bytes": mem.temp_size_in_bytes,
            "alias_size_bytes": mem.alias_size_in_bytes,
            # absent on the CPU backend's CompiledMemoryStats
            "peak_memory_bytes": getattr(mem, "peak_memory_in_bytes", None),
            "generated_code_size_bytes": mem.generated_code_size_in_bytes,
        },
        # roofline terms (seconds) per §Roofline
        "t_compute_s": flops / PEAK_FLOPS,
        "t_memory_s": bytes_accessed / HBM_BW,
        "t_collective_s": coll_total / ICI_BW,
    }
    terms = {"compute": rec["t_compute_s"], "memory": rec["t_memory_s"],
             "collective": rec["t_collective_s"]}
    rec["bottleneck"] = max(terms, key=terms.get)

    if verbose:
        live = ((rec["memory"]["argument_size_bytes"] or 0)
                + (rec["memory"]["temp_size_bytes"] or 0)) / max(n_dev, 1)
        print(f"[{arch_id} x {shape_name} x {mesh_kind}({n_dev})] "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s | "
              f"flops/dev {flops:.3e} bytes/dev {bytes_accessed:.3e} "
              f"coll/dev {coll_total:.3e} | args+temp {live/1e9:.2f} GB | "
              f"bottleneck {rec['bottleneck']}", flush=True)
    if save:
        ARTIFACTS.mkdir(parents=True, exist_ok=True)
        p = ARTIFACTS / f"{arch_id}__{shape_name}__{mesh_kind}.json"
        p.write_text(json.dumps(rec, indent=1))
    return rec


def all_cells():
    from repro.configs.registry import get_arch, list_archs

    for arch_id in list_archs():
        for shape in get_arch(arch_id).SHAPES:
            yield arch_id, shape.name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both", "debug"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = list(all_cells()) if args.all else [(args.arch, args.shape)]
    failures = []
    for arch_id, shape_name in cells:
        for mk in meshes:
            out = ARTIFACTS / f"{arch_id}__{shape_name}__{mk}.json"
            if args.skip_existing and out.exists():
                print(f"skip {out.name}")
                continue
            try:
                run_cell_dryrun(arch_id, shape_name, mk)
            except Exception as e:
                failures.append((arch_id, shape_name, mk, repr(e)[:200]))
                print(f"FAIL [{arch_id} x {shape_name} x {mk}]: {e}", flush=True)
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nAll dry-run cells passed.")


if __name__ == "__main__":
    main()
