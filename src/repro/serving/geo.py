"""Geo-distributed multi-region serving with follow-the-sun provisioning.

Hercules provisions one datacenter against one diurnal curve; its saving
argument compounds when regions peak out of phase.  This module puts a
region layer on top of the scenario zoo (the result the paper never had):

- :func:`compile_geo_scenario` expands a :class:`ScenarioSpec` with
  ``regions`` into one *single-DC* compiled day per region — each region
  re-uses the spec's workload curves on its local clock
  (``RegionSpec.phase_hours`` shifts ``peak_hour``/``shoulder_hour``),
  with its own topology, load scale and decorrelated trace seeds — plus a
  :class:`GeoNetwork` resolved from the spec's ``links`` (per-direction
  capacity and RTT);
- :func:`plan_spill` decides, per interval, how much of each workload's
  offered load each region ships to its neighbours: a Helix-style joint
  LP (:func:`repro.core.lp.solve_geo_spill`) over per-region fractional
  server counts and directed spill rates, minimizing global provisioned
  power under per-region pool limits, per-link capacity, and an
  RTT-vs-SLA budget (a workload may only spill over a link whose RTT fits
  inside ``rtt_budget_frac`` of its SLA — Hera's SLA-aware spill rather
  than greedy offload); a deterministic water-fill fallback covers
  ``placement="greedy"`` and missing scipy;
- :func:`simulate_geo_day` serves each region's *post-spill* day through
  the unchanged query-granular :func:`simulate_cluster_day` — so each
  region's :class:`StatefulProvisioner` re-solves against the flattened
  load (follow-the-sun: the global fleet peak de-synchronizes) — then
  attributes every served query back to its origin region
  (:func:`repro.serving.router.split_stream_by_share` over the interval's
  origin shares) and adds the link RTT to spilled queries' latency
  exactly once.  ``mode="isolated"`` is the per-region-isolated Hercules
  baseline the bench's ``geo_day`` record compares against.

Region-scale incidents arrive as scenario events: ``region_partition``
severs every link touching a region for an interval window (local-only
serving), ``region_drain`` evacuates a whole DC — its keepable load ramps
to zero and the remainder force-spills over surviving links, with
make-before-break power accounting on both sides (the receiving regions
provision *before* the source stops serving; the source's removed servers
pay their drain power through each region's ``StatefulProvisioner``).

Everything is deterministic: spill plans depend only on compiled traces,
static capacities and the event timeline; attribution uses the router's
golden-ratio interleave with a ``(region, workload, interval)``-derived
sequence offset.  This file is in ``repro.analysis``'s determinism-lint
scope.  See ``docs/geo_serving.md``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.lp import solve_geo_spill
from repro.serving.cluster_runtime import DayResult, simulate_cluster_day
from repro.serving.router import split_stream_by_share
from repro.serving.scenarios import (
    EVENT_TYPES,
    GEO_EVENT_KINDS,
    CompiledScenario,
    ScenarioError,
    ScenarioSpec,
    compile_scenario,
)

GEO_MODES = ("follow_sun", "isolated")


@dataclasses.dataclass(frozen=True)
class GeoConfig:
    """Knobs of the geo spill planner."""

    #: "lp" = Helix-style joint LP (scipy HiGHS) with the water-fill as a
    #: fallback; "greedy" = the deterministic water-fill directly
    placement: str = "lp"
    #: a workload may spill over a link only if the link RTT fits inside
    #: this fraction of its SLA (spilled latency = remote service + RTT
    #: must still meet the SLA with headroom for the serving tail)
    rtt_budget_frac: float = 0.5
    #: tiny RTT-weighted cost on spill in the LP objective: breaks power
    #: ties toward local serving / the shortest feasible link
    spill_penalty: float = 1e-6
    #: plan entries below this rate (QPS) are zeroed (LP solver noise)
    min_spill_qps: float = 0.1


@dataclasses.dataclass
class GeoNetwork:
    """The inter-region network resolved to directed-pair capacities.

    ``LinkSpec.capacity_frac`` is declared relative to the *smaller*
    endpoint's total best-case fleet capacity (summed over workloads), so
    the resolved ``cap_qps`` scales with the topology.  Links are
    bidirectional: each :class:`LinkSpec` yields two directed pairs with
    the same RTT and per-direction capacity.
    """

    regions: tuple[str, ...]
    rtt_ms: dict[tuple[int, int], float]     # directed (origin, dest)
    cap_qps: dict[tuple[int, int], float]

    @staticmethod
    def build(spec: ScenarioSpec,
              days: dict[str, CompiledScenario]) -> "GeoNetwork":
        names = tuple(r.name for r in spec.regions)
        total = {n: float(days[n].table.fleet_capacity().sum())
                 for n in names}
        rtt: dict[tuple[int, int], float] = {}
        cap: dict[tuple[int, int], float] = {}
        for li in spec.links or ():
            i, j = names.index(li.a), names.index(li.b)
            c = li.capacity_frac * min(total[li.a], total[li.b])
            for p in ((i, j), (j, i)):
                rtt[p] = li.rtt_ms
                cap[p] = c
        return GeoNetwork(regions=names, rtt_ms=rtt, cap_qps=cap)

    def pairs(self) -> list[tuple[int, int]]:
        return sorted(self.rtt_ms)

    def active_pairs(self, severed: list[int],
                     inbound_blocked: list[int]) -> list[tuple[int, int]]:
        """Directed pairs usable this interval: neither endpoint under a
        partition, destination not mid-evacuation."""
        return [p for p in self.pairs()
                if p[0] not in severed and p[1] not in severed
                and p[1] not in inbound_blocked]


@dataclasses.dataclass
class CompiledGeoScenario:
    """A geo spec resolved to one compiled single-DC day per region plus
    the network; ``run`` plans the spill and serves the post-spill days."""

    spec: ScenarioSpec
    days: dict[str, CompiledScenario]       # region name -> base day
    network: GeoNetwork
    partitions: list[tuple[str, int, int]]  # (region, start, end)
    drains: list[tuple[str, int, int]]      # (region, at, ramp)

    @property
    def region_names(self) -> tuple[str, ...]:
        return tuple(r.name for r in self.spec.regions)

    def run(self, policy: str | None = None, mode: str = "follow_sun",
            geo: GeoConfig | None = None) -> "GeoDayResult":
        return simulate_geo_day(self, policy=policy or self.spec.policy,
                                mode=mode, geo=geo)


def compile_geo_scenario(spec: ScenarioSpec,
                         verbose: bool = False) -> CompiledGeoScenario:
    """Expand a spec with ``regions`` into per-region compiled days.

    Each region gets the spec's workloads on its local clock
    (``peak_hour``/``shoulder_hour`` shifted by ``phase_hours`` mod 24),
    its load scale, decorrelated trace seeds, and its topology overrides;
    non-geo events apply to every region's local day, geo events
    (``region_partition``/``region_drain``) are consumed here.
    """
    if spec.regions is None:
        raise ScenarioError(
            f"scenario {spec.name!r}: compile_geo_scenario needs regions")
    local_events = tuple(ev for ev in spec.events
                         if ev.kind not in GEO_EVENT_KINDS)
    days: dict[str, CompiledScenario] = {}
    for r in spec.regions:
        workloads = tuple(dataclasses.replace(
            w,
            peak_hour=(w.peak_hour + r.phase_hours) % 24.0,
            shoulder_hour=(w.shoulder_hour + r.phase_hours) % 24.0,
            load_frac=w.load_frac * r.load_scale,
            trace_seed=w.trace_seed + r.trace_seed_offset,
        ) for w in spec.workloads)
        rspec = dataclasses.replace(
            spec, name=f"{spec.name}/{r.name}", workloads=workloads,
            servers=r.servers if r.servers is not None else spec.servers,
            availability=r.availability if r.availability is not None
            else spec.availability,
            events=local_events, regions=None, links=None)
        days[r.name] = compile_scenario(rspec, verbose=verbose)
    comp = CompiledGeoScenario(
        spec=spec, days=days, network=GeoNetwork.build(spec, days),
        partitions=[], drains=[])
    runtime: dict = {}
    for ev in spec.events:
        if ev.kind in GEO_EVENT_KINDS:
            EVENT_TYPES[ev.kind].apply(comp, runtime, ev.params)
    return comp


# ---------------------------------------------------------------------------
# spill planning
# ---------------------------------------------------------------------------


def _drain_gates(comp: CompiledGeoScenario) -> np.ndarray:
    """[R, T] keepable-load gates from ``region_drain`` events (1 = keep
    everything, ramping linearly to 0 over the drain window)."""
    names = comp.region_names
    T = comp.spec.n_steps
    gate = np.ones((len(names), T))
    for (rname, at, ramp) in comp.drains:
        g = np.ones(T)
        end = min(at + ramp, T)
        g[at:end] = 1.0 - (np.arange(end - at) + 1) / ramp
        g[end:] = 0.0
        gate[names.index(rname)] *= g
    return gate


def _severed_at(comp: CompiledGeoScenario, t: int) -> list[int]:
    names = comp.region_names
    out = []
    for (rname, start, end) in comp.partitions:
        if start <= t < end:
            i = names.index(rname)
            if i not in out:
                out.append(i)
    return out


def _greedy_spill(loads: np.ndarray, must: np.ndarray,
                  active: list[tuple[int, int]],
                  allowed: dict[tuple[int, int], np.ndarray],
                  net: GeoNetwork, caps: list[np.ndarray],
                  ) -> tuple[dict[tuple[int, int], np.ndarray], bool]:
    """Deterministic water-fill spill for one interval.

    Forced evacuation first (lowest-RTT surviving link wins), then a few
    bounded sweeps that move load from the highest-utilization region to
    its least-utilized allowed neighbour until utilizations are within a
    band.  Utilization is the sum of per-workload load fractions against
    the region's best-case fleet capacity — a proxy that errs toward
    under-filling the receiver.  Returns ``(spill, ok)``; ``ok=False``
    when a forced evacuation could not be placed.
    """
    R, M = loads.shape
    spill = {p: np.zeros(M) for p in active}
    link_left = {p: net.cap_qps[p] for p in active}
    served = loads.copy()
    order = sorted(active, key=lambda p: (net.rtt_ms[p], p))

    def util(r: int) -> float:
        return float((served[r] / np.maximum(caps[r], 1e-9)).sum())

    ok = True
    for r in range(R):
        for m in range(M):
            need = float(must[r, m])
            for p in order:
                if need <= 1e-9:
                    break
                if p[0] != r or not allowed[p][m]:
                    continue
                j = p[1]
                head = max(0.0, (1.0 - util(j)) * float(caps[j][m]))
                move = min(need, link_left[p], head)
                if move <= 0.0:
                    continue
                spill[p][m] += move
                link_left[p] -= move
                served[r, m] -= move
                served[j, m] += move
                need -= move
            if need > 1e-6:
                ok = False
    for _ in range(8):  # bounded equalization sweeps
        us = [util(r) for r in range(R)]
        donor = int(np.argmax(us))
        cands = [p for p in order if p[0] == donor and link_left[p] > 0.0]
        if not cands or us[donor] <= 0.0:
            break
        recip = min(cands, key=lambda p: (us[p[1]], p))
        j = recip[1]
        du = (us[donor] - us[j]) / 2.0
        if du < 0.02:
            break
        frac = min(du / us[donor], 1.0)
        for m in range(M):
            if not allowed[recip][m]:
                continue
            move = min(frac * float(served[donor, m]), link_left[recip])
            if move <= 0.0:
                continue
            spill[recip][m] += move
            link_left[recip] -= move
            served[donor, m] -= move
            served[j, m] += move
    return spill, ok


def plan_spill(comp: CompiledGeoScenario, geo: GeoConfig | None = None,
               ) -> tuple[list[dict[tuple[int, int], np.ndarray]],
                          list[str], bool]:
    """Per-interval spill plan for the whole day.

    Returns ``(plan, events, ok)``: ``plan[t]`` maps directed region pairs
    to per-workload spill rates (QPS), ``events`` narrates fallbacks and
    failed evacuations, ``ok`` is False when some forced evacuation could
    not be placed.  The plan depends only on compiled traces, static
    capacities and the event timeline — not on which policy serves it —
    so follow-the-sun and any policy comparison share one plan (CRN).
    """
    geo = geo or GeoConfig()
    if geo.placement not in ("lp", "greedy"):
        raise ValueError(f"unknown placement {geo.placement!r}; "
                         "expected 'lp' or 'greedy'")
    names = comp.region_names
    days = [comp.days[n] for n in names]
    R = len(names)
    M, T = days[0].traces.shape
    loads = np.stack([np.asarray(d.traces, dtype=float) for d in days])
    gate = _drain_gates(comp)
    slas = np.array([days[0].profiles[w].sla_ms
                     for w in days[0].table.workloads])
    qps_r = [d.table.qps for d in days]
    power_r = [d.table.power for d in days]
    avail_r = [d.table.avail for d in days]
    caps = [d.table.fleet_capacity() for d in days]
    # plan under one shared over-provision rate (the most conservative
    # region's): per-region R differences are curve-jitter artifacts the
    # LP would otherwise arbitrage into massive no-win spill
    over = float(np.max([d.overprovision for d in days]))
    budget_ok = {p: comp.network.rtt_ms[p] <= geo.rtt_budget_frac * slas
                 for p in comp.network.pairs()}

    plan: list[dict[tuple[int, int], np.ndarray]] = []
    events: list[str] = []
    ok = True
    for t in range(T):
        lt = loads[:, :, t]
        must = lt * (1.0 - gate[:, t])[:, None]
        severed = _severed_at(comp, t)
        inbound_blocked = [r for r in range(R) if gate[r, t] < 1.0]
        active = comp.network.active_pairs(severed, inbound_blocked)
        allowed = {p: budget_ok[p] for p in active}
        if not active:
            if float(must.sum()) > 1e-6:
                ok = False
                events.append(f"t={t}: evacuation ordered but no usable "
                              "links (partitioned or isolated)")
            plan.append({})
            continue
        spill = None
        if geo.placement == "lp":
            sol = solve_geo_spill(
                lt, qps_r, power_r, avail_r, allowed,
                {p: comp.network.cap_qps[p] for p in active},
                {p: comp.network.rtt_ms[p] for p in active},
                must_spill=must, overprovision=over,
                spill_penalty=geo.spill_penalty)
            if sol is not None:
                spill = sol[0]
        if spill is None:
            if geo.placement == "lp":
                events.append(f"t={t}: spill LP unavailable/infeasible -> "
                              "greedy water-fill")
            spill, gok = _greedy_spill(lt, must, active, allowed,
                                       comp.network, caps)
            if not gok:
                ok = False
                events.append(f"t={t}: forced evacuation could not be "
                              "fully placed")
        clean: dict[tuple[int, int], np.ndarray] = {}
        for p in active:
            s = np.asarray(spill.get(p, np.zeros(M)), dtype=float)
            s = np.where(s >= geo.min_spill_qps, s, 0.0)
            if float(s.sum()) > 0.0:
                clean[p] = s
        plan.append(clean)
    return plan, events, ok


# ---------------------------------------------------------------------------
# the geo day
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class GeoDayResult:
    """Typed result of :func:`simulate_geo_day`.

    ``regions`` holds each region's full (serving-side) :class:`DayResult`
    on its post-spill load; ``origin`` re-attributes every served query to
    the region whose users issued it — spilled queries carry their link
    RTT — which is where SLA attainment is judged.  ``power`` is the
    global fleet series (sum over regions, transition drain included).
    """

    scenario: str
    policy: str
    mode: str
    region_names: tuple[str, ...]
    regions: dict[str, DayResult]
    origin: dict[str, dict]
    power: np.ndarray
    peak_power_w: float
    avg_power_w: float
    feasible: bool
    all_meet_sla: bool
    all_intervals_meet_sla: bool
    n_spilled: int           # spilled queries among the simulated streams
    spilled_qps_mean: float  # day-mean total planned spill rate
    lost_qps_mean: float     # day-mean evacuated-but-unplaceable rate
    events: list[str]

    def to_dict(self) -> dict:
        """JSON-safe summary (region day series flattened to scalars plus
        the global power series)."""
        return {
            "scenario": self.scenario,
            "policy": self.policy,
            "mode": self.mode,
            "region_names": list(self.region_names),
            "regions": {
                name: {
                    "peak_power_w": r.peak_power_w,
                    "avg_power_w": r.avg_power_w,
                    "peak_capacity": r.peak_capacity,
                    "feasible": r.feasible,
                    "all_meet_sla": r.all_meet_sla,
                    "total_churn": r.total_churn,
                } for name, r in self.regions.items()},
            "origin": self.origin,
            "power_w": [float(p) for p in self.power],
            "peak_power_w": self.peak_power_w,
            "avg_power_w": self.avg_power_w,
            "feasible": self.feasible,
            "all_meet_sla": self.all_meet_sla,
            "all_intervals_meet_sla": self.all_intervals_meet_sla,
            "n_spilled": self.n_spilled,
            "spilled_qps_mean": self.spilled_qps_mean,
            "lost_qps_mean": self.lost_qps_mean,
            "events": list(self.events),
        }


def simulate_geo_day(comp: CompiledGeoScenario, policy: str = "hercules",
                     mode: str = "follow_sun",
                     geo: GeoConfig | None = None) -> GeoDayResult:
    """Serve the geo day: plan the spill, serve each region's post-spill
    load at query granularity, attribute queries back to their origins.

    ``mode="follow_sun"`` runs the spill planner; ``mode="isolated"`` is
    the per-region-isolated baseline — no links, every region serves its
    own offered load (a ``region_drain``'s evacuated load then has nowhere
    to go and is reported lost).  Both modes provision each region with
    its base-curve over-provision rate, so the comparison isolates the
    effect of the spill itself.
    """
    geo = geo or GeoConfig()
    if mode not in GEO_MODES:
        raise ValueError(f"unknown mode {mode!r}; expected one of "
                         f"{'/'.join(GEO_MODES)}")
    names = comp.region_names
    days = [comp.days[n] for n in names]
    R = len(names)
    M, T = days[0].traces.shape
    wl = days[0].table.workloads
    events: list[str] = []
    if mode == "follow_sun":
        plan, plan_events, plan_ok = plan_spill(comp, geo)
        events.extend(plan_events)
    else:
        plan, plan_ok = [{} for _ in range(T)], True

    loads = np.stack([np.asarray(d.traces, dtype=float) for d in days])
    gate = _drain_gates(comp)
    evac = loads * (1.0 - gate[:, None, :])
    out = np.zeros((R, M, T))
    inc = np.zeros((R, M, T))
    for t, sp in enumerate(plan):
        for (i, j), s in sorted(sp.items()):
            out[i, :, t] += s
            inc[j, :, t] += s
    # an evacuated DC cannot serve what it failed to ship: the shortfall
    # is lost load, not locally served load
    lost = np.maximum(evac - out, 0.0)
    lost[lost < 1e-6] = 0.0
    served = loads - np.maximum(out, evac) + inc
    # a fully-spilled cell leaves float residue behind; a sub-micro-QPS
    # trace is an idle interval, not a provisioning target
    served[served < 1e-6] = 0.0
    if float(lost.sum()) > 1e-6:
        plan_ok = False
        events.append("evacuated load could not be placed: "
                      f"{float(lost.sum()):.0f} qps-intervals lost")

    # serve each region's post-spill day (make-before-break transitions and
    # drain power are the region provisioner's own accounting).  Each region
    # keeps the over-provision rate derived from its *base* curves — spill
    # and drains are disruptions the provisioner absorbs, not forecasts
    # (re-deriving R from a post-spill trace would read a drain landing as
    # a load-growth rate and inflate the provisioning target)
    results: list[DayResult] = []
    for r in range(R):
        din = dataclasses.replace(days[r].inputs, traces=served[r])
        cfg = dataclasses.replace(days[r].config, collect_latencies=True)
        results.append(simulate_cluster_day(din, policy=policy, config=cfg))
        for ev in results[-1].events:
            events.append(f"{names[r]}: {ev}")

    # origin attribution: split each destination's measured stream by the
    # interval's origin shares (golden-ratio interleave, deterministic in
    # (dest, workload, interval)); spilled queries pay the link RTT once
    origin_lat: list[list[list[np.ndarray]]] = \
        [[[] for _ in range(M)] for _ in range(R)]
    origin_lat_t: list[list[list[np.ndarray | None]]] = \
        [[[None] * T for _ in range(M)] for _ in range(R)]
    n_spilled = np.zeros((R, M), np.int64)
    for j in range(R):
        lats = results[j].latencies
        for m in range(M):
            for t in range(T):
                lat = None if lats is None else lats[m][t]
                if lat is None or len(lat) == 0:
                    continue
                shares = np.zeros(R)
                shares[j] = max(served[j, m, t] - inc[j, m, t], 0.0)
                for (i, j2), s in plan[t].items():
                    if j2 == j:
                        shares[i] += s[m]
                if shares.sum() <= 0.0:
                    shares[j] = 1.0
                seq = (j * M + m) * T + t
                assign = split_stream_by_share(len(lat), shares, seq=seq)
                for i in range(R):
                    sel = lat[assign == i]
                    if len(sel) == 0:
                        continue
                    if i != j:
                        sel = sel + comp.network.rtt_ms[(i, j)] / 1e3
                        n_spilled[i, m] += len(sel)
                    origin_lat[i][m].append(sel)
                    prev = origin_lat_t[i][m][t]
                    origin_lat_t[i][m][t] = sel if prev is None \
                        else np.concatenate([prev, sel])

    # origin-view SLA attainment (the numbers the geo gate judges)
    origin: dict[str, dict] = {}
    all_meet = True
    all_intervals = True
    for r in range(R):
        sq = days[r].config.sla_quantile
        per_wl: dict[str, dict] = {}
        for m, name in enumerate(wl):
            sla = days[r].profiles[name].sla_ms
            parts = origin_lat[r][m]
            if parts:
                lat_ms = np.concatenate(parts) * 1e3
            else:
                lat_ms = np.array([np.inf]) if float(loads[r, m].sum()) > 0 \
                    and float(lost[r, m].sum()) > 1e-6 else np.array([0.0])
            p50, p95, p99 = (float(v) for v in
                             np.percentile(lat_ms, (50, 95, 99)))
            q = float(np.quantile(lat_ms, sq))
            meets = bool(q <= sla)
            met_t, n_meas = 0, 0
            for t in range(T):
                lt = origin_lat_t[r][m][t]
                if lt is None:
                    continue
                n_meas += 1
                met_t += bool(float(np.quantile(lt * 1e3, sq)) <= sla)
            every = bool(n_meas == met_t)
            all_meet &= meets
            all_intervals &= every
            per_wl[name] = {
                "sla_ms": sla, "p50_ms": p50, "p95_ms": p95, "p99_ms": p99,
                "sla_attainment": float(np.mean(lat_ms <= sla)),
                "meets_sla": meets,
                "interval_sla_met_frac":
                    float(met_t / n_meas) if n_meas else 0.0,
                "meets_every_interval": every,
                "n_queries": int(sum(len(p) for p in parts)),
                "n_spilled": int(n_spilled[r, m]),
            }
        origin[names[r]] = per_wl

    power = np.sum([res.power for res in results], axis=0)
    feasible = plan_ok and all(res.feasible for res in results)
    return GeoDayResult(
        scenario=comp.spec.name,
        policy=policy,
        mode=mode,
        region_names=names,
        regions={names[r]: results[r] for r in range(R)},
        origin=origin,
        power=power,
        peak_power_w=float(power.max()),
        avg_power_w=float(power.mean()),
        feasible=bool(feasible),
        all_meet_sla=bool(all_meet and feasible),
        all_intervals_meet_sla=bool(all_intervals and feasible),
        n_spilled=int(n_spilled.sum()),
        spilled_qps_mean=float(out.sum(axis=(0, 1)).mean()),
        lost_qps_mean=float(lost.sum(axis=(0, 1)).mean()),
        events=events,
    )
