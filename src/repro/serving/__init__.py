"""Serving substrate: query generation, batching/fusion, the discrete-event
server simulator (vectorized engine + reference path), diurnal load traces,
and the serve driver."""
from repro.serving.simulator import (  # noqa: F401
    SchedConfig,
    SimCache,
    SimResult,
    max_sustainable_qps,
    simulate,
    simulate_rates,
)
