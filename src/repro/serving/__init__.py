"""Serving substrate: query generation, batching/fusion, the discrete-event
server simulator (vectorized engine + reference path), diurnal load traces,
the query router, the fleet-scale cluster serving runtime, and the
declarative scenario zoo (`repro.serving.scenarios`)."""
from repro.serving.cluster_runtime import (  # noqa: F401
    PairService,
    RuntimeConfig,
    failure_schedule,
    simulate_cluster_day,
)
from repro.serving.scenarios import (  # noqa: F401
    Event,
    ScenarioError,
    ScenarioSpec,
    WorkloadSpec,
    compile_scenario,
    full_scale,
    get_scenario,
    register,
    registry,
    run_scenario,
)
from repro.serving.simulator import (  # noqa: F401
    SchedConfig,
    SimCache,
    SimResult,
    max_sustainable_qps,
    simulate,
    simulate_rates,
)
