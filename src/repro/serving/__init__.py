"""Serving substrate: query generation, batching/fusion, the discrete-event
server simulator, diurnal load traces, and the serve driver."""
