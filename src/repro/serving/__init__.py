"""Serving substrate: query generation, batching/fusion, the discrete-event
server simulator (vectorized engine + reference path), diurnal load traces,
the query router, the fleet-scale cluster serving runtime with its typed
day API (:class:`DayInputs` in, :class:`DayResult` out), the declarative
scenario zoo (`repro.serving.scenarios`), and geo-distributed multi-region
serving with follow-the-sun spill (`repro.serving.geo` — region topologies
declared as :class:`RegionSpec`/:class:`LinkSpec` on a scenario spec)."""
from repro.serving.cluster_runtime import (  # noqa: F401
    DayInputs,
    DayResult,
    PairService,
    RuntimeConfig,
    failure_schedule,
    simulate_cluster_day,
)
from repro.serving.geo import (  # noqa: F401
    CompiledGeoScenario,
    GeoConfig,
    GeoDayResult,
    GeoNetwork,
    compile_geo_scenario,
    plan_spill,
    simulate_geo_day,
)
from repro.serving.scenarios import (  # noqa: F401
    Event,
    LinkSpec,
    RegionSpec,
    ScenarioError,
    ScenarioSpec,
    WorkloadSpec,
    compile_scenario,
    full_scale,
    get_scenario,
    register,
    registry,
    run_scenario,
)
from repro.serving.simulator import (  # noqa: F401
    SchedConfig,
    SimCache,
    SimResult,
    max_sustainable_qps,
    simulate,
    simulate_rates,
)

__all__ = [
    "CompiledGeoScenario",
    "DayInputs",
    "DayResult",
    "Event",
    "GeoConfig",
    "GeoDayResult",
    "GeoNetwork",
    "LinkSpec",
    "PairService",
    "RegionSpec",
    "RuntimeConfig",
    "ScenarioError",
    "ScenarioSpec",
    "SchedConfig",
    "SimCache",
    "SimResult",
    "WorkloadSpec",
    "compile_geo_scenario",
    "compile_scenario",
    "failure_schedule",
    "full_scale",
    "get_scenario",
    "max_sustainable_qps",
    "plan_spill",
    "register",
    "registry",
    "run_scenario",
    "simulate",
    "simulate_cluster_day",
    "simulate_geo_day",
    "simulate_rates",
]
