"""Fleet-scale, query-granular online serving (closing the paper's loop).

``provision_day`` (stage 2, `repro.core.cluster`) trusts the efficiency
table's QPS column: an interval is "served" if the LP covers the load with
profiled throughput numbers.  This module validates that claim the way
DeepRecSys and Hera do — by actually serving queries: it consumes the
allocations of a :class:`~repro.core.cluster.StatefulProvisioner` and
drives Poisson query streams through one
:class:`~repro.serving.router.QueryRouter` per workload, with per-server
behaviour reproduced from the PR-2 vectorized engine:

- each allocated server instance is a router slot backed by a
  :class:`PairService` — the (workload, server-type) pair's profiled
  optimal placement + scheduling config, whose sub-query splits and
  duration tables come from the shared :class:`~repro.serving.simulator.
  SimCache` (common random numbers across intervals, slots and policies);
- routing is the router's deterministic low-discrepancy weighted
  assignment; newly provisioned servers join the pool only after their
  model load completes, drained servers stop taking queries but finish
  in-flight work (make-before-break when ``drain_s >= model_load_s``);
- mid-day failures land *inside* the measured window: the victim's
  unfinished queries re-dispatch to healthy slots at the detection time,
  and the provisioner re-solves on the shrunken pool at the next interval;
- stragglers hedge once the router's p99-based threshold trips, modelled
  as a duplicate issued at ``arrival + threshold`` completing after the
  best alternative slot's unloaded service time.

Per interval the runtime measures a window of up to
``queries_per_interval`` queries per workload starting at the interval
boundary — where re-provisioning transitions bite — at the *true* arrival
rate, so per-slot utilization matches the fleet's.  Pools start idle at
each window (no backlog carry-over between intervals), which slightly
flatters tails at very high utilization; the day-level p99 / SLA
attainment aggregates every window.  See ``docs/cluster_serving.md``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.cluster import (
    EfficiencyTable,
    StatefulProvisioner,
    TransitionConfig,
)
from repro.core.devices import SERVER_TYPES, DeviceProfile
from repro.core.partition import enumerate_placements
from repro.core.perfmodel import (
    accel_engine_time,
    accel_link_time,
    cpu_stage_time,
)
from repro.core.workload import ModelProfile
from repro.serving.engine import fifo_finish
from repro.serving.router import QueryRouter, ServerSlot
from repro.serving.simulator import (
    _PROBE_CAP,
    SchedConfig,
    SimCache,
    _accel_pipeline,
    _fusion_groups,
)


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    """Knobs of the query-granular day simulation."""

    queries_per_interval: int = 1500  # window cap per workload (CRN prefix)
    hedge_quantile: float = 0.99
    hedge_factor: float = 2.0
    sla_quantile: float = 0.95        # "meets SLA" = this quantile <= sla_ms


# ---------------------------------------------------------------------------
# per-(workload, server-type) service model
# ---------------------------------------------------------------------------


class PairService:
    """Query-granular service model of one (workload, server-type) pair.

    Reproduces the single-server simulator's fast path on an arbitrary
    subset of the CRN query stream: the profiled optimal placement and
    scheduling config define the pool structure, the shared
    :class:`SimCache` supplies sub-query splits and duration tables, and
    the k-server FIFO recurrence / accel admission-link-engine pipeline
    come from :mod:`repro.serving.engine` and the simulator.  ``finish``
    on the full stream prefix is bit-identical to the engine's fast path
    (pinned by ``tests/test_cluster_runtime.py``).
    """

    def __init__(self, profile: ModelProfile, device: DeviceProfile,
                 record: dict, cache: SimCache):
        self.profile = profile
        self.device = device
        self.cache = cache
        self.qps = float(record["qps"])
        self.sched = SchedConfig(
            batch=int(record["d"]), m=int(record["m"]), o=int(record["o"]),
            sd_sparse=int(record["sd_sparse"]),
        )
        self.plan = record["plan"]
        placements = enumerate_placements(profile, device)
        by_plan = [p for p in placements if p.plan == self.plan]
        self.placement = by_plan[0] if by_plan else placements[0]
        d = max(self.sched.batch, 1)
        self.d = d
        sp = cache.tables.split(d)
        self.offsets = sp["offsets"]
        self.inv = sp["inv"]
        self.sub_s = sp["sub_s"]
        t, pl, s = cache.tables, self.placement, self.sched
        self.k = max(s.m, 1)
        if self.plan == "cpu_model":
            self.dur = t.cpu_durations(pl.host_ops, s.o, s.m, d, device)
        elif self.plan == "cpu_sd":
            self.k_sparse = max(s.sd_sparse, 1)
            self.dur_sparse = t.cpu_durations(
                pl.host_sparse, s.o, self.k_sparse, d, device)
            self.dur_dense = t.cpu_durations(pl.host_dense, 1, s.m, d, device)
        else:
            self.host_threads = max(device.cpu.cores // max(s.o, 1), 1)

    # -- internals -----------------------------------------------------------

    def _sub_index(self, qidx: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Sub-query indices (into the full CRN split) for queries ``qidx``."""
        starts = self.offsets[qidx]
        counts = (self.offsets[qidx + 1] - starts).astype(np.int64)
        total = int(counts.sum())
        if total == 0:
            return np.zeros(0, np.int64), counts
        cum0 = np.concatenate([[0], np.cumsum(counts)[:-1]])
        sub = np.repeat(starts - cum0, counts) + np.arange(total)
        return sub, counts

    def _scalar_table(self, key: tuple, fn, uniq: np.ndarray) -> np.ndarray:
        return self.cache.tables.scalar_vec(key, fn, uniq)

    def _accel(self, sub_ready: np.ndarray, sub_s: np.ndarray) -> np.ndarray:
        """Fused launches through host pool -> admission -> link -> engine,
        identical to the simulator's ``_fast_accel`` structure."""
        pl, s, dev = self.placement, self.sched, self.device
        starts, totals = _fusion_groups(sub_ready, sub_s.astype(np.int64),
                                        self.d, s.fuse)
        bounds = np.append(starts, len(sub_ready))
        ready = sub_ready[bounds[1:] - 1]
        uniq_t, inv_t = np.unique(totals, return_inverse=True)
        o = max(s.o, 1)
        if pl.host_ops:
            th = self._scalar_table(
                ("cpu_stage", pl.host_ops, o, self.host_threads, dev.name),
                lambda b: cpu_stage_time(pl.host_ops, b, o, dev,
                                         self.host_threads), uniq_t)[inv_t]
            ready = fifo_finish(ready, th, self.host_threads)
        te = self._scalar_table(
            ("accel_engine", pl.accel_ops, dev.name),
            lambda b: accel_engine_time(pl.accel_ops, b, dev), uniq_t)[inv_t]
        tl = self._scalar_table(
            ("accel_link", pl.link_bytes_per_item, dev.name),
            lambda b: accel_link_time(pl.link_bytes_per_item, b, dev),
            uniq_t)[inv_t]
        e_end = _accel_pipeline(ready, tl, te, s.m)
        return np.repeat(e_end, np.diff(bounds))

    # -- public --------------------------------------------------------------

    def finish(self, qidx: np.ndarray, ready: np.ndarray) -> np.ndarray:
        """Per-query finish times for CRN-stream queries ``qidx`` entering
        this server's (initially idle) pools at ``ready`` (sorted)."""
        qidx = np.asarray(qidx, np.int64)
        out = np.array(ready, dtype=np.float64, copy=True)
        if len(qidx) == 0:
            return out
        sub, counts = self._sub_index(qidx)
        nz = counts > 0
        if not nz.any():
            return out
        sub_ready = np.repeat(out, counts)
        inv = self.inv[sub]
        if self.plan == "cpu_model":
            ends = fifo_finish(sub_ready, self.dur[inv], self.k)
        elif self.plan == "cpu_sd":
            s_end = fifo_finish(sub_ready, self.dur_sparse[inv], self.k_sparse)
            ends = fifo_finish(s_end, self.dur_dense[inv], self.k)
        else:
            ends = self._accel(sub_ready, self.sub_s[sub])
        cum0 = np.concatenate([[0], np.cumsum(counts)])
        out[nz] = np.maximum.reduceat(ends, cum0[:-1][nz])
        return out

    def solo_time(self, qidx: np.ndarray) -> np.ndarray:
        """Unloaded per-query service time (the hedge-completion model):
        list-scheduling wave bound ``max(longest sub-query, work / k)`` per
        pool stage; serialized link+engine on accelerators."""
        qidx = np.asarray(qidx, np.int64)
        sub, counts = self._sub_index(qidx)
        out = np.zeros(len(qidx))
        nz = counts > 0
        if not nz.any():
            return out
        cuts = np.concatenate([[0], np.cumsum(counts)])[:-1][nz]

        def wave(dur: np.ndarray, k: int) -> np.ndarray:
            longest = np.maximum.reduceat(dur, cuts)
            work = np.add.reduceat(dur, cuts)
            return np.maximum(longest, work / max(k, 1))

        inv = self.inv[sub]
        if self.plan == "cpu_model":
            out[nz] = wave(self.dur[inv], self.k)
        elif self.plan == "cpu_sd":
            out[nz] = wave(self.dur_sparse[inv], self.k_sparse) + \
                wave(self.dur_dense[inv], self.k)
        else:
            pl, dev = self.placement, self.device
            uniq, inv_s = np.unique(self.sub_s[sub], return_inverse=True)
            te = self._scalar_table(
                ("accel_engine", pl.accel_ops, dev.name),
                lambda b: accel_engine_time(pl.accel_ops, b, dev), uniq)
            tl = self._scalar_table(
                ("accel_link", pl.link_bytes_per_item, dev.name),
                lambda b: accel_link_time(pl.link_bytes_per_item, b, dev),
                uniq)
            per_sub = (te + tl)[inv_s]
            out[nz] = np.add.reduceat(per_sub, cuts)
            if pl.host_ops:
                th = self._scalar_table(
                    ("cpu_stage", pl.host_ops, max(self.sched.o, 1),
                     self.host_threads, dev.name),
                    lambda b: cpu_stage_time(pl.host_ops, b,
                                             max(self.sched.o, 1), dev,
                                             self.host_threads), uniq)[inv_s]
                out[nz] += wave(th, self.host_threads)
        return out


# ---------------------------------------------------------------------------
# failure schedules
# ---------------------------------------------------------------------------


def failure_schedule(n_steps: int, n_servers: int, fail_prob: float,
                     seed: int = 0) -> list[tuple[int, int, float]]:
    """``(interval, server_type, window_frac)`` events: each server type
    loses one machine with probability ``fail_prob`` per interval, at
    ``window_frac`` of the measured query window (so failover is observed
    at query granularity).  Deterministic in ``seed`` — share one schedule
    across policies for a fair (CRN) comparison."""
    rng = np.random.default_rng(seed)
    out = []
    for t in range(n_steps):
        for h in range(n_servers):
            if rng.random() < fail_prob:
                out.append((t, h, float(rng.uniform(0.2, 0.8))))
    return out


# ---------------------------------------------------------------------------
# the day simulation
# ---------------------------------------------------------------------------


def _percentiles(lat_ms: np.ndarray) -> tuple[float, float, float]:
    p50, p95, p99 = np.percentile(lat_ms, (50, 95, 99))
    return float(p50), float(p95), float(p99)


def simulate_cluster_day(
    table: EfficiencyTable,
    records: dict[str, dict],
    profiles: dict[str, ModelProfile],
    traces: np.ndarray,                 # [M, T] per-workload diurnal loads
    policy: str = "hercules",
    servers: dict[str, DeviceProfile] | None = None,
    overprovision: float = 0.05,
    transitions: TransitionConfig | None = None,
    config: RuntimeConfig | None = None,
    failures: list[tuple[int, int, float]] | None = None,
    query_sizes: np.ndarray | None = None,
    seed: int = 0,
) -> dict:
    """Serve a full diurnal day at query granularity.

    ``table``/``records`` come from ``efficiency.build_table``; ``profiles``
    maps workload name -> :class:`ModelProfile`.  Returns the provisioning
    series (power incl. transition drain, capacity, resolves/holds/churn)
    plus *achieved* per-workload latency percentiles and SLA attainment —
    the numbers ``provision_day`` only asserts via the QPS column.
    """
    servers = servers or SERVER_TYPES
    cfg = config or RuntimeConfig()
    transitions = transitions or TransitionConfig()
    if query_sizes is None:
        from repro.core.efficiency import default_query_sizes
        query_sizes = default_query_sizes()
    M, T = traces.shape
    H = len(table.servers)
    cache = SimCache(query_sizes, seed)
    services: dict[tuple[int, int], PairService] = {}

    def service(h: int, m: int) -> PairService:
        key = (h, m)
        if key not in services:
            rec = records[f"{table.workloads[m]}|{table.servers[h]}"]
            services[key] = PairService(
                profiles[table.workloads[m]], servers[table.servers[h]],
                rec, cache)
        return services[key]

    prov = StatefulProvisioner(table, policy, overprovision, transitions,
                               seed=seed)
    routers = [QueryRouter([], hedge_quantile=cfg.hedge_quantile,
                           hedge_factor=cfg.hedge_factor, seed=seed + m)
               for m in range(M)]
    fail_by_t: dict[int, list[tuple[int, float]]] = {}
    for (ft, fh, frac) in failures or []:
        fail_by_t.setdefault(ft, []).append((fh, frac))

    power = np.zeros(T)
    capacity = np.zeros(T, np.int64)
    churn = np.zeros(T, np.int64)
    events: list[str] = []
    feasible = True
    lat_by_m: list[list[np.ndarray]] = [[] for _ in range(M)]
    n_hedged = np.zeros(M, np.int64)
    n_retried = np.zeros(M, np.int64)
    cap_q = min(cfg.queries_per_interval, _PROBE_CAP)

    for t in range(T):
        step = prov.step(traces[:, t])
        power[t] = step.power_w
        capacity[t] = step.capacity
        churn[t] = step.churn
        if not step.feasible:
            feasible = False
            events.append(f"t={t}: {policy} infeasible on surviving pool")
        t0 = t * transitions.interval_s
        # map this interval's failures onto serving (h, m) victims
        victims_by_m: dict[int, list[tuple[int, float]]] = {}
        for (fh, frac) in fail_by_t.get(t, []):
            before = int(prov.avail[fh])
            cells = prov.fail(fh)
            if not cells:
                if int(prov.avail[fh]) < before:
                    events.append(
                        f"t={t}: spare {table.servers[fh]} failed")
                continue
            for (h, m) in cells:
                victims_by_m.setdefault(m, []).append((h, frac))
                events.append(
                    f"t={t}: serving {table.servers[h]} failed "
                    f"({table.workloads[m]}) -> re-route + re-provision")

        for m in range(M):
            rate = float(traces[m, t])
            if rate <= 0.0:
                continue
            if step.alloc[:, m].sum() == 0:
                feasible = False
                events.append(f"t={t}: {table.workloads[m]} unallocated")
                continue
            n = int(np.clip(rate * transitions.interval_s, 64, cap_q))
            arrivals = t0 + np.cumsum(cache.unit_gaps[:n] * (1.0 / rate))
            span = float(arrivals[-1] - arrivals[0])

            slots: list[ServerSlot] = []
            pair_of: list[PairService] = []
            for h in range(H):
                cnt = int(step.alloc[h, m])
                add = int(step.added[h, m])
                rem = int(step.removed[h, m])
                if cnt + rem == 0:
                    continue
                svc = service(h, m)
                for i in range(cnt):
                    ready = t0 + transitions.model_load_s \
                        if i >= cnt - add else t0
                    slots.append(ServerSlot(table.servers[h], svc.qps,
                                            ready_at=ready))
                    pair_of.append(svc)
                for _ in range(rem):  # draining: serves until the deadline
                    slots.append(ServerSlot(
                        table.servers[h], svc.qps, ready_at=t0,
                        retire_at=t0 + transitions.drain_s))
                    pair_of.append(svc)
            router = routers[m]
            router.refresh(slots)

            # mid-window failures: victim stops taking queries at t_f
            fail_times: list[tuple[int, float]] = []
            for (h, frac) in victims_by_m.get(m, []):
                t_f = float(arrivals[0] + frac * span)
                vi = next((i for i, s in enumerate(slots)
                           if s.server_type == table.servers[h]
                           and s.accepts(t_f)), None)
                if vi is None:
                    continue
                slots[vi].retire_at = t_f
                fail_times.append((vi, t_f))

            try:
                assigned = router.assign_stream(arrivals)
            except RuntimeError:
                feasible = False
                events.append(f"t={t}: {table.workloads[m]} had no ready "
                              "servers in the window")
                continue
            ready = arrivals.copy()
            latency = np.zeros(n)
            done = np.zeros(n, bool)

            # failed slots first: finished-before-failure queries complete,
            # the rest re-dispatch to healthy slots at the detection time
            for (vi, t_f) in fail_times:
                qv = np.flatnonzero(assigned == vi)
                if len(qv) == 0:
                    router.mark_failed(slots[vi])
                    continue
                # an earlier victim's retries may have landed here: FIFO
                # order is by ready time, not stream index
                qv = qv[np.argsort(ready[qv], kind="stable")]
                f = pair_of[vi].finish(qv, ready[qv])
                ok = f <= t_f
                latency[qv[ok]] = f[ok] - arrivals[qv[ok]]
                done[qv[ok]] = True
                router.mark_failed(slots[vi])
                lost = qv[~ok]
                if len(lost):
                    ready[lost] = t_f
                    try:
                        assigned[lost] = router.assign_stream(ready[lost])
                        n_retried[m] += len(lost)
                    except RuntimeError:
                        feasible = False
                        latency[lost] = np.inf
                        done[lost] = True
                        events.append(
                            f"t={t}: {table.workloads[m]} lost queries — "
                            "no healthy servers left to retry on")

            for si, svc in enumerate(pair_of):
                qs = np.flatnonzero((assigned == si) & ~done)
                if len(qs) == 0:
                    continue
                order = np.argsort(ready[qs], kind="stable")
                qs = qs[order]
                f = svc.finish(qs, ready[qs])
                latency[qs] = f - arrivals[qs]
                done[qs] = True

            # straggler hedging: duplicate at arrival + threshold, winner =
            # min(original, threshold + unloaded service on the best
            # alternative slot type) — optimistic about the alternate's queue
            thr = router.hedge_threshold()
            if np.isfinite(thr) and len(slots) > 1:
                straggler = np.flatnonzero(np.isfinite(latency)
                                           & (latency > thr))
                # hedge targets must actually be serving during the window
                # (loading/draining/failed slots can't take a duplicate)
                w_end = float(arrivals[-1])
                cands = sorted(
                    (i for i, s in enumerate(slots) if s.accepts(w_end)),
                    key=lambda i: slots[i].qps, reverse=True)
                if len(straggler) and cands:
                    alt = np.where(assigned[straggler] != cands[0],
                                   cands[0],
                                   cands[1] if len(cands) > 1 else -1)
                    ok = alt >= 0  # never hedge onto the straggler's own box
                    for a in np.unique(alt[ok]):
                        sub = straggler[ok & (alt == a)]
                        hedged = thr + pair_of[a].solo_time(sub)
                        better = hedged < latency[sub]
                        latency[sub[better]] = hedged[better]
                        n_hedged[m] += int(better.sum())
            router.observe_many(latency[np.isfinite(latency)])
            lat_by_m[m].append(latency)

    workloads = {}
    all_meet = True
    for m, name in enumerate(table.workloads):
        lat_ms = np.concatenate(lat_by_m[m]) * 1e3 if lat_by_m[m] else \
            np.array([np.inf])
        p50, p95, p99 = _percentiles(lat_ms)
        sla = profiles[name].sla_ms
        q = float(np.quantile(lat_ms, cfg.sla_quantile))
        attainment = float(np.mean(lat_ms <= sla))
        meets = q <= sla
        all_meet &= meets
        workloads[name] = {
            "sla_ms": sla, "p50_ms": p50, "p95_ms": p95, "p99_ms": p99,
            "sla_attainment": attainment, "meets_sla": bool(meets),
            "n_queries": int(len(lat_ms)), "n_hedged": int(n_hedged[m]),
            "n_retried": int(n_retried[m]),
        }
    return {
        "policy": policy,
        "power_w": power,
        "capacity": capacity,
        "churn": churn,
        "feasible": feasible,
        "peak_power_w": float(power.max()),
        "avg_power_w": float(power.mean()),
        "peak_capacity": int(capacity.max()),
        "avg_capacity": float(capacity.mean()),
        "resolves": prov.n_resolves,
        "holds": prov.n_holds,
        "total_churn": int(churn.sum()),
        "workloads": workloads,
        "all_meet_sla": bool(all_meet),
        "events": events,
    }
