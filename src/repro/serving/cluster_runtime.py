"""Fleet-scale, query-granular online serving (closing the paper's loop).

``provision_day`` (stage 2, `repro.core.cluster`) trusts the efficiency
table's QPS column: an interval is "served" if the LP covers the load with
profiled throughput numbers.  This module validates that claim the way
DeepRecSys and Hera do — by actually serving queries: it consumes the
allocations of a :class:`~repro.core.cluster.StatefulProvisioner` and
drives Poisson query streams through one
:class:`~repro.serving.router.QueryRouter` per workload, with per-server
behaviour reproduced from the PR-2 vectorized engine:

- each allocated server instance is a router slot backed by a
  :class:`PairService` — the (workload, server-type) pair's profiled
  optimal placement + scheduling config, whose sub-query splits and
  duration tables come from the shared :class:`~repro.serving.simulator.
  SimCache` (common random numbers across intervals, slots and policies);
- routing is the router's deterministic low-discrepancy weighted
  assignment; newly provisioned servers join the pool only after their
  model load completes, drained servers stop taking queries but finish
  in-flight work (make-before-break when ``drain_s >= model_load_s``);
- mid-day failures land *inside* the measured window: the victim's
  unfinished queries re-dispatch to healthy slots at the detection time,
  and the provisioner re-solves on the shrunken pool at the next interval;
- stragglers hedge once the router's p99-based threshold trips: the
  duplicate is admitted into the alternate slot's **live** queue at
  ``arrival + threshold`` and contends with that slot's in-flight work.

The simulation is **continuous-time across intervals**: each slot's pool
state (the per-server free times — its backlog of unfinished work) is
carried from one measured window into the next, through hysteresis holds,
make-before-break transitions, slot retirement and mid-window failures.
Measured windows therefore abut in queue time; a slot pushed past its
sustainable rate accumulates backlog day-long instead of being quietly
reset to an idle pool at every interval boundary — which is exactly the
regime (utilization → 1) where the paper's feasibility-frontier claims
are decided.  Per-interval latency/SLA series are exposed alongside the
day-level aggregate, and the achieved tail feeds back into the
provisioner's hysteresis decision (``StatefulProvisioner.step(load,
tail_ok=...)``).  See ``docs/cluster_serving.md``.
"""
from __future__ import annotations

import dataclasses
import warnings

import numpy as np

from repro.core.cluster import (
    EfficiencyTable,
    StatefulProvisioner,
    TransitionConfig,
)
from repro.core.devices import SERVER_TYPES, DeviceProfile
from repro.core.partition import enumerate_placements
from repro.core.perfmodel import (
    accel_engine_time,
    accel_link_time,
    cpu_stage_time,
)
from repro.core.workload import ModelProfile
from repro.serving.engine import fifo_finish, fifo_finish_state
from repro.serving.event_core import merge_event_streams
from repro.serving.router import QueryRouter, ServerSlot
from repro.serving.simulator import (
    _PROBE_CAP,
    SchedConfig,
    SimCache,
    _accel_pipeline,
    _fusion_groups,
)


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    """Knobs of the query-granular day simulation."""

    queries_per_interval: int = 1500  # window cap per workload (CRN prefix)
    hedge_quantile: float = 0.99
    hedge_factor: float = 2.0
    sla_quantile: float = 0.95        # "meets SLA" = this quantile <= sla_ms
    carry_backlog: bool = True        # continuous-time: carry pool state
    hedge_live_queue: bool = True     # hedges join the alternate's live queue
    tail_feedback: bool = True        # feed achieved tail into hysteresis
    # --- event core (exact full-interval mode, see event_core.py) ---------
    # event_core=True simulates every arrival of the interval (cap below,
    # not _PROBE_CAP), extends each measured window to the interval end so
    # nothing is bridged by stationarity, batches the per-slot k-server
    # pools through event_core.fleet_fifo_finish, and re-serves a hedge
    # target's own primaries event-ordered (their latencies reflect the
    # duplicate's admission instead of keeping first-pass values)
    event_core: bool = False
    event_core_queries: int = 200_000  # full-interval cap per (workload, t)
    # keep the raw per-(workload, interval) latency arrays on the result
    # (``DayResult.latencies``) — used by the geo layer to attribute spilled
    # queries to their origin region; off by default (event-core days can
    # measure 10^7+ queries)
    collect_latencies: bool = False


@dataclasses.dataclass(frozen=True)
class DayInputs:
    """Everything :func:`simulate_cluster_day` needs about *one* day.

    ``compile_scenario`` produces one of these (``CompiledScenario.inputs``);
    hand-rolled days construct it directly.  The bundle is the day's data —
    which policy serves it and with which runtime knobs stay call-site
    arguments (``simulate_cluster_day(inputs, policy=..., config=...)``), so
    the same inputs can be served under every policy for a CRN comparison.
    """

    table: EfficiencyTable
    records: dict[str, dict]
    profiles: dict[str, ModelProfile]
    traces: np.ndarray                  # [M, T] per-workload diurnal loads
    servers: dict[str, DeviceProfile] | None = None
    overprovision: float = 0.05
    transitions: TransitionConfig | None = None
    failures: list[tuple[int, int, float]] | None = None
    query_sizes: np.ndarray | None = None
    seed: int = 0
    # optional repro.core.colocation.ColocationTable: when set, the
    # provisioner may pack complementary tenants onto shared machines and
    # the runtime serves their per-tenant streams on one machine identity
    # with interference-dilated duration tables.  None (the default) keeps
    # the single-tenant day bitwise unchanged.
    colocation: object | None = None


@dataclasses.dataclass
class DayResult:
    """Typed result of :func:`simulate_cluster_day`.

    ``to_dict()`` reproduces the historical raw-dict shape bit-for-bit
    (``power`` -> ``"power_w"``, ``per_workload`` -> ``"workloads"``), so
    JSON baselines pinned against the old return value stay valid.
    """

    policy: str
    power: np.ndarray                   # [T] provisioned W incl. drain
    capacity: np.ndarray                # [T] machines allocated
    churn: np.ndarray                   # [T] machines added + removed
    feasible: bool
    peak_power_w: float
    avg_power_w: float
    peak_capacity: int
    avg_capacity: float
    resolves: int
    holds: int
    tail_resolves: int
    total_churn: int
    per_workload: dict[str, dict]       # day-level aggregates per workload
    series: dict                        # {"interval_s", "per_workload"}
    all_meet_sla: bool
    events: list[str]
    # raw per-(workload, interval) latency seconds; populated only under
    # RuntimeConfig(collect_latencies=True) and excluded from to_dict()
    latencies: list[list[np.ndarray | None]] | None = None
    # [T] shared (co-located) machines per interval; populated only when
    # the day ran with a colocation table and excluded from to_dict() so
    # pinned single-tenant baselines keep their exact key set
    co_capacity: np.ndarray | None = None

    def to_dict(self) -> dict:
        return {
            "policy": self.policy,
            "power_w": self.power,
            "capacity": self.capacity,
            "churn": self.churn,
            "feasible": self.feasible,
            "peak_power_w": self.peak_power_w,
            "avg_power_w": self.avg_power_w,
            "peak_capacity": self.peak_capacity,
            "avg_capacity": self.avg_capacity,
            "resolves": self.resolves,
            "holds": self.holds,
            "tail_resolves": self.tail_resolves,
            "total_churn": self.total_churn,
            "workloads": self.per_workload,
            "series": self.series,
            "all_meet_sla": self.all_meet_sla,
            "events": self.events,
        }


# ---------------------------------------------------------------------------
# per-slot pool state (the carried backlog)
# ---------------------------------------------------------------------------
#
# A slot's state is a dict of float arrays — one per internal pool resource
# (CPU thread pool, sparse/dense pools, accel host pool / co-location slots
# / link / engine), each entry a server's free time.  Between windows the
# state is stored *relative* to the window end (residual seconds of
# unfinished work); at the next window it is re-anchored at the interval
# start, so a drained slot re-enters idle and an overloaded one re-enters
# exactly as deep in backlog as it left.


def _state_abs(residual: dict[str, np.ndarray], t0: float) -> dict:
    """Anchor a residual (relative-seconds) state at absolute time ``t0``."""
    return {k: t0 + v for k, v in residual.items()}


def _state_residual(state: dict[str, np.ndarray], w_end: float) -> dict:
    """Convert an absolute end-of-window state to residual seconds."""
    return {k: np.maximum(v - w_end, 0.0) for k, v in state.items()}


def _drain_horizon(state: dict[str, np.ndarray], w_end: float) -> float:
    """Seconds past ``w_end`` until the slot is fully drained (0 = idle)."""
    if not state:
        return 0.0
    return max(max(float(v.max()) - w_end, 0.0) for v in state.values())


def _state_copy(state: dict[str, np.ndarray]) -> dict:
    return {k: v.copy() for k, v in state.items()}


# ---------------------------------------------------------------------------
# per-(workload, server-type) service model
# ---------------------------------------------------------------------------


class PairService:
    """Query-granular service model of one (workload, server-type) pair.

    Reproduces the single-server simulator's fast path on an arbitrary
    subset of the CRN query stream: the profiled optimal placement and
    scheduling config define the pool structure, the shared
    :class:`SimCache` supplies sub-query splits and duration tables, and
    the k-server FIFO recurrence / accel admission-link-engine pipeline
    come from :mod:`repro.serving.engine` and the simulator.  ``finish``
    on the full stream prefix is bit-identical to the engine's fast path
    (pinned by ``tests/test_cluster_runtime.py``); with a ``state`` it
    additionally starts from / hands back carried pool backlog.
    """

    def __init__(self, profile: ModelProfile, device: DeviceProfile,
                 record: dict, cache: SimCache, dilation: float = 1.0):
        self.profile = profile
        self.device = device
        self.cache = cache
        # interference dilation of a co-located tenant (>= 1): every pool
        # duration multiplies by it and the sustainable rate divides by it.
        # At exactly 1.0 no multiply runs, keeping the solo path bitwise.
        self.dilation = float(dilation)
        self.qps = float(record["qps"])
        if self.dilation != 1.0:
            self.qps = self.qps / self.dilation
        self.sched = SchedConfig(
            batch=int(record["d"]), m=int(record["m"]), o=int(record["o"]),
            sd_sparse=int(record["sd_sparse"]),
        )
        self.plan = record["plan"]
        placements = enumerate_placements(profile, device)
        by_plan = [p for p in placements if p.plan == self.plan]
        self.placement = by_plan[0] if by_plan else placements[0]
        d = max(self.sched.batch, 1)
        self.d = d
        sp = cache.tables.split(d)
        self.offsets = sp["offsets"]
        self.inv = sp["inv"]
        self.sub_s = sp["sub_s"]
        t, pl, s = cache.tables, self.placement, self.sched
        self.k = max(s.m, 1)
        if self.plan == "cpu_model":
            self.dur = t.cpu_durations(pl.host_ops, s.o, s.m, d, device)
            if self.dilation != 1.0:
                self.dur = self.dur * self.dilation
        elif self.plan == "cpu_sd":
            self.k_sparse = max(s.sd_sparse, 1)
            self.dur_sparse = t.cpu_durations(
                pl.host_sparse, s.o, self.k_sparse, d, device)
            self.dur_dense = t.cpu_durations(pl.host_dense, 1, s.m, d, device)
            if self.dilation != 1.0:
                self.dur_sparse = self.dur_sparse * self.dilation
                self.dur_dense = self.dur_dense * self.dilation
        else:
            self.host_threads = max(device.cpu.cores // max(s.o, 1), 1)

    # -- internals -----------------------------------------------------------

    def _sub_index(self, qidx: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Sub-query indices (into the full CRN split) for queries ``qidx``."""
        starts = self.offsets[qidx]
        counts = (self.offsets[qidx + 1] - starts).astype(np.int64)
        total = int(counts.sum())
        if total == 0:
            return np.zeros(0, np.int64), counts
        cum0 = np.concatenate([[0], np.cumsum(counts)[:-1]])
        sub = np.repeat(starts - cum0, counts) + np.arange(total)
        return sub, counts

    def _scalar_table(self, key: tuple, fn, uniq: np.ndarray) -> np.ndarray:
        return self.cache.tables.scalar_vec(key, fn, uniq)

    def _accel(self, sub_ready: np.ndarray, sub_s: np.ndarray,
               state: dict | None = None) -> np.ndarray:
        """Fused launches through host pool -> admission -> link -> engine,
        identical to the simulator's ``_fast_accel`` structure."""
        pl, s, dev = self.placement, self.sched, self.device
        starts, totals = _fusion_groups(sub_ready, sub_s.astype(np.int64),
                                        self.d, s.fuse)
        bounds = np.append(starts, len(sub_ready))
        ready = sub_ready[bounds[1:] - 1]
        uniq_t, inv_t = np.unique(totals, return_inverse=True)
        o = max(s.o, 1)
        if pl.host_ops:
            th = self._scalar_table(
                ("cpu_stage", pl.host_ops, o, self.host_threads, dev.name),
                lambda b: cpu_stage_time(pl.host_ops, b, o, dev,
                                         self.host_threads), uniq_t)[inv_t]
            if self.dilation != 1.0:
                th = th * self.dilation
            if state is None:
                ready = fifo_finish(ready, th, self.host_threads)
            else:
                ready, state["host"] = fifo_finish_state(
                    ready, th, self.host_threads, state["host"])
        te = self._scalar_table(
            ("accel_engine", pl.accel_ops, dev.name),
            lambda b: accel_engine_time(pl.accel_ops, b, dev), uniq_t)[inv_t]
        tl = self._scalar_table(
            ("accel_link", pl.link_bytes_per_item, dev.name),
            lambda b: accel_link_time(pl.link_bytes_per_item, b, dev),
            uniq_t)[inv_t]
        if self.dilation != 1.0:
            te = te * self.dilation
            tl = tl * self.dilation
        if state is None:
            e_end = _accel_pipeline(ready, tl, te, s.m)
        else:
            e_end, (colo, link, eng) = _accel_pipeline(
                ready, tl, te, s.m, colo0=state["colo"],
                link0=float(state["link"][0]), eng0=float(state["eng"][0]),
                return_state=True)
            state["colo"] = colo
            state["link"] = np.array([link])
            state["eng"] = np.array([eng])
        return np.repeat(e_end, np.diff(bounds))

    # -- public --------------------------------------------------------------

    def fresh_state(self) -> dict[str, np.ndarray]:
        """Idle pool state (residual form: all zeros), shaped per plan."""
        if self.plan == "cpu_model":
            return {"pool": np.zeros(self.k)}
        if self.plan == "cpu_sd":
            return {"sparse": np.zeros(self.k_sparse),
                    "dense": np.zeros(self.k)}
        st = {"colo": np.zeros(self.k), "link": np.zeros(1),
              "eng": np.zeros(1)}
        if self.placement.host_ops:
            st["host"] = np.zeros(self.host_threads)
        return st

    def finish(self, qidx: np.ndarray, ready: np.ndarray,
               state: dict | None = None) -> np.ndarray:
        """Per-query finish times for CRN-stream queries ``qidx`` entering
        this server's pools at ``ready`` (sorted).  Without ``state`` the
        pools start idle (the historical, bit-pinned path); with a
        ``state`` dict (absolute free times, see :func:`_state_abs`) the
        pools start from the carried backlog and ``state`` is updated in
        place to the end-of-stream pool state."""
        qidx = np.asarray(qidx, np.int64)
        out = np.array(ready, dtype=np.float64, copy=True)
        if len(qidx) == 0:
            return out
        sub, counts = self._sub_index(qidx)
        nz = counts > 0
        if not nz.any():
            return out
        sub_ready = np.repeat(out, counts)
        inv = self.inv[sub]
        if self.plan == "cpu_model":
            if state is None:
                ends = fifo_finish(sub_ready, self.dur[inv], self.k)
            else:
                ends, state["pool"] = fifo_finish_state(
                    sub_ready, self.dur[inv], self.k, state["pool"])
        elif self.plan == "cpu_sd":
            if state is None:
                s_end = fifo_finish(sub_ready, self.dur_sparse[inv],
                                    self.k_sparse)
                ends = fifo_finish(s_end, self.dur_dense[inv], self.k)
            else:
                s_end, state["sparse"] = fifo_finish_state(
                    sub_ready, self.dur_sparse[inv], self.k_sparse,
                    state["sparse"])
                ends, state["dense"] = fifo_finish_state(
                    s_end, self.dur_dense[inv], self.k, state["dense"])
        else:
            ends = self._accel(sub_ready, self.sub_s[sub], state)
        cum0 = np.concatenate([[0], np.cumsum(counts)])
        out[nz] = np.maximum.reduceat(ends, cum0[:-1][nz])
        return out

    def solo_time(self, qidx: np.ndarray) -> np.ndarray:
        """Unloaded per-query service time (lower bound on any completion):
        list-scheduling wave bound ``max(longest sub-query, work / k)`` per
        pool stage; serialized link+engine on accelerators."""
        qidx = np.asarray(qidx, np.int64)
        sub, counts = self._sub_index(qidx)
        out = np.zeros(len(qidx))
        nz = counts > 0
        if not nz.any():
            return out
        cuts = np.concatenate([[0], np.cumsum(counts)])[:-1][nz]

        def wave(dur: np.ndarray, k: int) -> np.ndarray:
            longest = np.maximum.reduceat(dur, cuts)
            work = np.add.reduceat(dur, cuts)
            return np.maximum(longest, work / max(k, 1))

        inv = self.inv[sub]
        if self.plan == "cpu_model":
            out[nz] = wave(self.dur[inv], self.k)
        elif self.plan == "cpu_sd":
            out[nz] = wave(self.dur_sparse[inv], self.k_sparse) + \
                wave(self.dur_dense[inv], self.k)
        else:
            pl, dev = self.placement, self.device
            uniq, inv_s = np.unique(self.sub_s[sub], return_inverse=True)
            te = self._scalar_table(
                ("accel_engine", pl.accel_ops, dev.name),
                lambda b: accel_engine_time(pl.accel_ops, b, dev), uniq)
            tl = self._scalar_table(
                ("accel_link", pl.link_bytes_per_item, dev.name),
                lambda b: accel_link_time(pl.link_bytes_per_item, b, dev),
                uniq)
            if self.dilation != 1.0:
                te = te * self.dilation
                tl = tl * self.dilation
            per_sub = (te + tl)[inv_s]
            out[nz] = np.add.reduceat(per_sub, cuts)
            if pl.host_ops:
                th = self._scalar_table(
                    ("cpu_stage", pl.host_ops, max(self.sched.o, 1),
                     self.host_threads, dev.name),
                    lambda b: cpu_stage_time(pl.host_ops, b,
                                             max(self.sched.o, 1), dev,
                                             self.host_threads), uniq)[inv_s]
                if self.dilation != 1.0:
                    th = th * self.dilation
                out[nz] += wave(th, self.host_threads)
        return out


# ---------------------------------------------------------------------------
# batched slot solving (event-core fleet path)
# ---------------------------------------------------------------------------


def _reduce_queries(out, ends, counts, nz):
    """Per-query max over sub-query ends (PairService.finish epilogue)."""
    cum0 = np.concatenate([[0], np.cumsum(counts)])
    out[nz] = np.maximum.reduceat(ends, cum0[:-1][nz])


def _finish_many(jobs, fleet: bool = False) -> list[np.ndarray]:
    """Finish a batch of per-slot query streams.

    ``jobs`` is a list of ``(svc, qidx, ready, state)`` — one entry per
    slot; states are updated in place.  With ``fleet=False`` this is the
    historical sequential pass (one ``svc.finish`` per slot).  With
    ``fleet=True`` all k > 1 front pools (cpu_model thread pools, cpu_sd
    sparse pools) solve in one :func:`event_core.fleet_fifo_finish` call,
    then all dependent cpu_sd dense pools in a second — amortizing the
    per-step cost across slots (the recurrence is sequential per stream
    but embarrassingly parallel across slots).  ``k == 1`` pools keep the
    engine's Lindley dispatch and the accel admission/link/engine pipeline
    stays scalar (three coupled serialized resources, not a k-server
    pool), so every stream is bitwise-identical to its sequential
    ``svc.finish`` result."""
    if not fleet:
        return [svc.finish(qidx, ready, state=state)
                for (svc, qidx, ready, state) in jobs]
    from repro.serving import event_core

    outs: list[np.ndarray] = []
    pre: list[tuple | None] = []
    for (svc, qidx, ready, state) in jobs:
        qidx = np.asarray(qidx, np.int64)
        out = np.array(ready, dtype=np.float64, copy=True)
        outs.append(out)
        if len(qidx) == 0:
            pre.append(None)
            continue
        sub, counts = svc._sub_index(qidx)
        nz = counts > 0
        if not nz.any():
            pre.append(None)
            continue
        sub_ready = np.repeat(out, counts)
        pre.append((svc, sub, counts, nz, sub_ready, svc.inv[sub], state))

    stage1: list[tuple[int, tuple]] = []   # k>1 front pools
    for j, p in enumerate(pre):
        if p is None:
            continue
        svc, sub, counts, nz, sub_ready, inv, state = p
        if svc.plan == "cpu_model" and svc.k > 1:
            stage1.append((j, (sub_ready, svc.dur[inv], svc.k,
                               state["pool"])))
        elif svc.plan == "cpu_sd" and svc.k_sparse > 1:
            stage1.append((j, (sub_ready, svc.dur_sparse[inv],
                               svc.k_sparse, state["sparse"])))
    ends1: dict[int, np.ndarray] = {}
    for (j, _), (e, st_out) in zip(
            stage1, event_core.fleet_fifo_finish([s for _, s in stage1])):
        svc = pre[j][0]
        ends1[j] = e
        key = "pool" if svc.plan == "cpu_model" else "sparse"
        pre[j][6][key] = st_out

    stage2: list[tuple[int, tuple]] = []   # cpu_sd dense pools (chained)
    for j, p in enumerate(pre):
        if p is None:
            continue
        svc, sub, counts, nz, sub_ready, inv, state = p
        if svc.plan == "cpu_model":
            if svc.k > 1:
                e = ends1[j]
            else:
                e, state["pool"] = fifo_finish_state(
                    sub_ready, svc.dur[inv], svc.k, state["pool"])
            _reduce_queries(outs[j], e, counts, nz)
        elif svc.plan == "cpu_sd":
            if svc.k_sparse > 1:
                s_end = ends1[j]
            else:
                s_end, state["sparse"] = fifo_finish_state(
                    sub_ready, svc.dur_sparse[inv], svc.k_sparse,
                    state["sparse"])
            if svc.k > 1:
                stage2.append((j, (s_end, svc.dur_dense[inv], svc.k,
                                   state["dense"])))
            else:
                e, state["dense"] = fifo_finish_state(
                    s_end, svc.dur_dense[inv], svc.k, state["dense"])
                _reduce_queries(outs[j], e, counts, nz)
        else:
            e = svc._accel(sub_ready, svc.sub_s[sub], state)
            _reduce_queries(outs[j], e, counts, nz)
    for (j, _), (e, st_out) in zip(
            stage2, event_core.fleet_fifo_finish([s for _, s in stage2])):
        svc, sub, counts, nz, sub_ready, inv, state = pre[j]
        state["dense"] = st_out
        _reduce_queries(outs[j], e, counts, nz)
    return outs


# ---------------------------------------------------------------------------
# failure schedules
# ---------------------------------------------------------------------------


def failure_schedule(n_steps: int, n_servers: int, fail_prob: float,
                     seed: int = 0) -> list[tuple[int, int, float]]:
    """``(interval, server_type, window_frac)`` events: each server type
    loses one machine with probability ``fail_prob`` per interval, at
    ``window_frac`` of the measured query window (so failover is observed
    at query granularity).  Deterministic in ``seed`` — share one schedule
    across policies for a fair (CRN) comparison."""
    rng = np.random.default_rng(seed)
    out = []
    for t in range(n_steps):
        for h in range(n_servers):
            if rng.random() < fail_prob:
                out.append((t, h, float(rng.uniform(0.2, 0.8))))
    return out


# ---------------------------------------------------------------------------
# the day simulation
# ---------------------------------------------------------------------------


def _percentiles(lat_ms: np.ndarray) -> tuple[float, float, float]:
    p50, p95, p99 = np.percentile(lat_ms, (50, 95, 99))
    return float(p50), float(p95), float(p99)


def simulate_cluster_day(
    inputs: DayInputs | EfficiencyTable,
    records: dict[str, dict] | None = None,
    profiles: dict[str, ModelProfile] | None = None,
    traces: np.ndarray | None = None,   # [M, T] per-workload diurnal loads
    policy: str = "hercules",
    servers: dict[str, DeviceProfile] | None = None,
    overprovision: float = 0.05,
    transitions: TransitionConfig | None = None,
    config: RuntimeConfig | None = None,
    failures: list[tuple[int, int, float]] | None = None,
    query_sizes: np.ndarray | None = None,
    seed: int = 0,
) -> DayResult:
    """Serve a full diurnal day at query granularity, continuous in time.

    ``inputs`` is a :class:`DayInputs` (``table``/``records`` from
    ``efficiency.build_table``, ``profiles`` mapping workload name ->
    :class:`ModelProfile`); ``policy`` and ``config`` select how the day is
    served.  Returns a :class:`DayResult`: the provisioning series (power
    incl. transition drain, capacity, resolves/holds/churn), *achieved*
    per-workload latency percentiles and SLA attainment — the numbers
    ``provision_day`` only asserts via the QPS column — plus a per-interval
    ``series`` block (the Fig. 8b-style SLA-over-the-day record) and the
    carried-backlog trajectory.

    The pre-``DayInputs`` 13-argument call (table/records/profiles/traces
    passed loose) still works but raises a :class:`DeprecationWarning`; it
    wraps the arguments into a ``DayInputs`` and is bit-identical to the
    bundled call (pinned by ``tests/test_geo.py``).
    """
    if not isinstance(inputs, DayInputs):
        warnings.warn(
            "simulate_cluster_day(table, records, profiles, traces, ...) is "
            "deprecated; bundle the day into DayInputs and call "
            "simulate_cluster_day(inputs, policy=..., config=...)",
            DeprecationWarning, stacklevel=2)
        inputs = DayInputs(
            table=inputs, records=records, profiles=profiles, traces=traces,
            servers=servers, overprovision=overprovision,
            transitions=transitions, failures=failures,
            query_sizes=query_sizes, seed=seed)
    table, records, profiles = inputs.table, inputs.records, inputs.profiles
    traces = inputs.traces
    overprovision = inputs.overprovision
    failures = inputs.failures
    seed = inputs.seed
    servers = inputs.servers or SERVER_TYPES
    cfg = config or RuntimeConfig()
    transitions = inputs.transitions or TransitionConfig()
    query_sizes = inputs.query_sizes
    if query_sizes is None:
        from repro.core.efficiency import default_query_sizes
        query_sizes = default_query_sizes()
    M, T = traces.shape
    H = len(table.servers)
    cache = SimCache(query_sizes, seed)
    if cfg.event_core:
        cap_q = int(cfg.event_core_queries)
        # grow the CRN streams once, up front, to the day's largest
        # interval population: every window is then a bitwise prefix of
        # the same streams and no PairService ever binds stale tables
        n_max = int(np.clip(float(traces.max()) * transitions.interval_s,
                            64, cap_q))
        cache.ensure(n_max)
    else:
        cap_q = min(cfg.queries_per_interval, _PROBE_CAP)
    services: dict[tuple[int, int], PairService] = {}

    def service(h: int, m: int) -> PairService:
        key = (h, m)
        if key not in services:
            rec = records[f"{table.workloads[m]}|{table.servers[h]}"]
            services[key] = PairService(
                profiles[table.workloads[m]], servers[table.servers[h]],
                rec, cache)
        return services[key]

    # shared-machine services: the tenant's solo record with its duration
    # tables dilated by the co-resident set's interference factor, keyed
    # separately so solo services stay untouched
    co_services: dict[tuple, PairService] = {}

    def co_service(m: int, c) -> PairService:
        name = table.workloads[m]
        f = c.dilation_of(name)
        key = (c.server, c.tenants, m, f)
        if key not in co_services:
            rec = records[f"{name}|{c.server}"]
            co_services[key] = PairService(
                profiles[name], servers[c.server], rec, cache, dilation=f)
        return co_services[key]

    prov = StatefulProvisioner(table, policy, overprovision, transitions,
                               seed=seed, colocation=inputs.colocation)
    routers = [QueryRouter([], hedge_quantile=cfg.hedge_quantile,
                           hedge_factor=cfg.hedge_factor, seed=seed + m)
               for m in range(M)]
    fail_by_t: dict[int, list[tuple[int, float]]] = {}
    for (ft, fh, frac) in failures or []:
        fail_by_t.setdefault(ft, []).append((fh, frac))

    power = np.zeros(T)
    capacity = np.zeros(T, np.int64)
    churn = np.zeros(T, np.int64)
    co_cap = np.zeros(T, np.int64)
    events: list[str] = []
    feasible = True
    # per-(workload, interval) latency arrays (None = not measured) and the
    # carried-backlog trajectory (seconds of residual work at window end)
    lat_mt: list[list[np.ndarray | None]] = [[None] * T for _ in range(M)]
    backlog_mt = np.zeros((M, T))
    # per-workload residual slot states keyed by (server type, instance)
    slot_states: list[dict[tuple[int, int], dict]] = [{} for _ in range(M)]
    n_hedged = np.zeros(M, np.int64)
    n_retried = np.zeros(M, np.int64)
    bridged_mt: list[list] = [[None] * T for _ in range(M)]
    tail_ok_prev = True

    for t in range(T):
        step = prov.step(traces[:, t], tail_ok=tail_ok_prev)
        power[t] = step.power_w
        capacity[t] = step.capacity
        churn[t] = step.churn
        if not step.feasible:
            feasible = False
            events.append(f"t={t}: {policy} infeasible on surviving pool")
        t0 = t * transitions.interval_s
        co_cap[t] = len(step.coalloc)
        # map this interval's failures onto serving victims: solo (h, m)
        # cells, or a shared CoMachine whose loss hits every tenant
        victims_by_m: dict[int, list[tuple]] = {}
        for (fh, frac) in fail_by_t.get(t, []):
            before = int(prov.avail[fh])
            cells = prov.fail(fh)
            if not cells:
                if int(prov.avail[fh]) < before:
                    events.append(
                        f"t={t}: spare {table.servers[fh]} failed")
                continue
            for v in cells:
                if isinstance(v, tuple):
                    h, m = v
                    victims_by_m.setdefault(m, []).append((h, frac))
                    events.append(
                        f"t={t}: serving {table.servers[h]} failed "
                        f"({table.workloads[m]}) -> re-route + re-provision")
                else:  # shared machine: every tenant pool loses its view
                    g = ("c", v.server, v.tenants)
                    for name in v.tenants:
                        victims_by_m.setdefault(
                            table.workloads.index(name), []).append((g, frac))
                    events.append(
                        f"t={t}: shared {v.server} failed "
                        f"({'+'.join(v.tenants)}) -> re-route + re-provision")

        for m in range(M):
            rate = float(traces[m, t])
            if rate <= 0.0:
                slot_states[m] = {}  # a whole idle interval drains the pool
                continue
            if step.alloc[:, m].sum() == 0 and not any(
                    table.workloads[m] in c.tenants
                    and c.rate_of(table.workloads[m]) > 0.0
                    for c in step.coalloc):
                feasible = False
                slot_states[m] = {}
                events.append(f"t={t}: {table.workloads[m]} unallocated")
                continue
            n = int(np.clip(rate * transitions.interval_s, 64, cap_q))
            arrivals = t0 + np.cumsum(cache.unit_gaps[:n] * (1.0 / rate))
            span = float(arrivals[-1] - arrivals[0])
            w_end = float(arrivals[-1])
            # a window that did not reach the interval end is bridged by
            # stationarity (the historical approximation); the event core
            # instead measures to the interval boundary so carried backlog
            # sees the real inter-window drain
            bridged_mt[m][t] = bool(n == cap_q
                                    and w_end < t0 + transitions.interval_s)
            if cfg.event_core:
                w_end = max(w_end, t0 + transitions.interval_s)

            # build the slot pool; each serving machine keeps a stable
            # (type, instance) identity so its backlog carries across
            # intervals — removed machines become draining slots that
            # inherit (and finish) their backlog, added ones start idle
            prev_states = slot_states[m] if cfg.carry_backlog else {}
            slots: list[ServerSlot] = []
            pair_of: list[PairService] = []
            states: list[dict] = []      # absolute, updated by the passes
            keys: list[tuple[int, int] | None] = []  # None = no carry-out
            for h in range(H):
                cnt = int(step.alloc[h, m])
                add = int(step.added[h, m])
                rem = int(step.removed[h, m])
                if cnt + rem == 0:
                    continue
                svc = service(h, m)
                keep = cnt - add
                for i in range(cnt):
                    ready = t0 + transitions.model_load_s \
                        if i >= keep else t0
                    slots.append(ServerSlot(table.servers[h], svc.qps,
                                            ready_at=ready))
                    pair_of.append(svc)
                    res = prev_states.get((h, i)) if i < keep else None
                    states.append(_state_abs(
                        res if res is not None else svc.fresh_state(), t0))
                    keys.append((h, i))
                for j in range(rem):  # draining: serves until the deadline
                    slots.append(ServerSlot(
                        table.servers[h], svc.qps, ready_at=t0,
                        retire_at=t0 + transitions.drain_s))
                    pair_of.append(svc)
                    res = prev_states.get((h, keep + j))
                    states.append(_state_abs(
                        res if res is not None else svc.fresh_state(), t0))
                    keys.append(None)
            # shared (co-located) machines: one slot per tenant pool per
            # machine, weighted by the tenant's assigned rate and carrying
            # a composite ("c", server, tenants, i) machine identity so a
            # hardware failure correlates across every tenant it serves
            name_m = table.workloads[m]
            co_cur: dict[tuple, list] = {}
            for c in step.coalloc:
                if name_m in c.tenants:
                    co_cur.setdefault(("c", c.server, c.tenants),
                                      []).append(c)
            co_rem: dict[tuple, list] = {}
            for c in step.co_removed:
                if name_m in c.tenants:
                    co_rem.setdefault(("c", c.server, c.tenants),
                                      []).append(c)
            co_add: dict[tuple, list] = {}
            for c in step.co_added:
                if name_m in c.tenants:
                    co_add.setdefault(("c", c.server, c.tenants),
                                      []).append(c)
            for g in sorted(set(co_cur) | set(co_rem)):
                cur = co_cur.get(g, [])
                # kept machines first: they map onto carried (g, i) states,
                # newly added ones load their model before serving
                pend = list(co_add.get(g, []))
                kept_c, fresh_c = [], []
                for c in cur:
                    if c in pend:
                        pend.remove(c)
                        fresh_c.append(c)
                    else:
                        kept_c.append(c)
                cur = kept_c + fresh_c
                keep = len(kept_c)
                for i, c in enumerate(cur):
                    rate_c = c.rate_of(name_m)
                    if rate_c <= 0.0:
                        continue
                    svc = co_service(m, c)
                    ready = t0 + transitions.model_load_s \
                        if i >= keep else t0
                    slots.append(ServerSlot(c.server, rate_c,
                                            ready_at=ready,
                                            machine=g + (i,)))
                    pair_of.append(svc)
                    res = prev_states.get((g, i)) if i < keep else None
                    states.append(_state_abs(
                        res if res is not None else svc.fresh_state(), t0))
                    keys.append((g, i))
                for j, c in enumerate(co_rem.get(g, [])):
                    rate_c = c.rate_of(name_m)
                    if rate_c <= 0.0:
                        continue
                    svc = co_service(m, c)
                    slots.append(ServerSlot(
                        c.server, rate_c, ready_at=t0,
                        retire_at=t0 + transitions.drain_s,
                        machine=g + (len(cur) + j,)))
                    pair_of.append(svc)
                    res = prev_states.get((g, keep + j))
                    states.append(_state_abs(
                        res if res is not None else svc.fresh_state(), t0))
                    keys.append(None)
            router = routers[m]
            router.refresh(slots)
            thr = router.hedge_threshold()
            carry_in = [_state_copy(st) for st in states] \
                if cfg.hedge_live_queue and np.isfinite(thr) else None

            # mid-window failures: victim stops taking queries at t_f.
            # A tuple key is a shared machine's identity — every tenant
            # pool retires the same machine index; an int key is a solo
            # server type (shared slots are excluded from its match)
            fail_times: list[tuple[int, float]] = []
            for (h, frac) in victims_by_m.get(m, []):
                t_f = float(arrivals[0] + frac * span)
                if isinstance(h, tuple):
                    vi = next((i for i, s in enumerate(slots)
                               if s.machine is not None
                               and s.machine[:3] == h
                               and s.accepts(t_f)), None)
                else:
                    vi = next((i for i, s in enumerate(slots)
                               if s.machine is None
                               and s.server_type == table.servers[h]
                               and s.accepts(t_f)), None)
                if vi is None:
                    continue
                slots[vi].retire_at = t_f
                keys[vi] = None          # a dead machine carries nothing
                fail_times.append((vi, t_f))

            try:
                assigned = router.assign_stream(arrivals)
            except RuntimeError:
                feasible = False
                slot_states[m] = {}
                events.append(f"t={t}: {table.workloads[m]} had no ready "
                              "servers in the window")
                continue
            ready = arrivals.copy()
            latency = np.zeros(n)
            done = np.zeros(n, bool)

            # failed slots first: finished-before-failure queries complete,
            # the rest re-dispatch to healthy slots at the detection time
            for (vi, t_f) in fail_times:
                qv = np.flatnonzero(assigned == vi)
                if len(qv) == 0:
                    router.mark_failed(slots[vi])
                    continue
                # an earlier victim's retries may have landed here: FIFO
                # order is by ready time, not stream index
                qv = qv[np.argsort(ready[qv], kind="stable")]
                f = pair_of[vi].finish(qv, ready[qv], state=states[vi])
                ok = f <= t_f
                latency[qv[ok]] = f[ok] - arrivals[qv[ok]]
                done[qv[ok]] = True
                router.mark_failed(slots[vi])
                lost = qv[~ok]
                if len(lost):
                    ready[lost] = t_f
                    try:
                        assigned[lost] = router.assign_stream(ready[lost])
                        n_retried[m] += len(lost)
                    except RuntimeError:
                        feasible = False
                        latency[lost] = np.inf
                        done[lost] = True
                        events.append(
                            f"t={t}: {table.workloads[m]} lost queries — "
                            "no healthy servers left to retry on")

            streams: dict[int, tuple[np.ndarray, np.ndarray]] = {}
            jobs: list[tuple] = []
            job_q: list[np.ndarray] = []
            for si, svc in enumerate(pair_of):
                qs = np.flatnonzero((assigned == si) & ~done)
                if len(qs) == 0:
                    continue
                order = np.argsort(ready[qs], kind="stable")
                qs = qs[order]
                jobs.append((svc, qs, ready[qs], states[si]))
                job_q.append(qs)
                streams[si] = (qs, ready[qs])
            for qs, f in zip(job_q,
                             _finish_many(jobs, fleet=cfg.event_core)):
                latency[qs] = f - arrivals[qs]
                done[qs] = True

            # straggler hedging: a duplicate issued at arrival + threshold
            # is admitted into the alternate slot's live queue — it rides
            # the slot's carried backlog plus its in-window stream, so a
            # busy alternate cannot complete the hedge faster than its own
            # queue allows (first completion wins)
            if np.isfinite(thr) and len(slots) > 1:
                straggler, t_issue, alt = router.hedge_events(
                    assigned, arrivals, latency, thr)
                ok = alt >= 0
                if len(straggler) and cfg.event_core \
                        and carry_in is not None:
                    # event-ordered pass: one merged re-simulation per
                    # target slot, duplicates interleaved into the slot's
                    # primary stream at their issue times.  The target's
                    # own primaries are re-served in that order, so their
                    # latencies now REFLECT the duplicate's admission
                    # (the exact coupling the first-pass model bridges);
                    # each straggler keeps first-completion-wins.
                    alts = np.unique(alt[ok])
                    hjobs, hmeta = [], []
                    for a in alts:
                        sel = straggler[ok & (alt == a)]
                        ti = arrivals[sel] + thr
                        prim_q, prim_r = streams.get(
                            a, (np.zeros(0, np.int64), np.zeros(0)))
                        times, order = merge_event_streams(prim_r, ti)
                        mq = np.concatenate([prim_q, sel])[order]
                        st = _state_copy(carry_in[a])
                        hjobs.append((pair_of[a], mq, times, st))
                        hmeta.append((a, sel, prim_q, order, st))
                    fins = _finish_many(hjobs, fleet=True)
                    # apply all primary re-serves first, then the
                    # duplicate minima, so a straggler that is also a
                    # perturbed primary competes against its updated
                    # first-pass finish
                    dup_lat = []
                    for (a, sel, prim_q, order, st), f_all in zip(hmeta,
                                                                  fins):
                        pos = np.empty(len(order), np.int64)
                        pos[order] = np.arange(len(order))
                        latency[prim_q] = \
                            f_all[pos[:len(prim_q)]] - arrivals[prim_q]
                        dup_lat.append(f_all[pos[len(prim_q):]]
                                       - arrivals[sel])
                        states[a] = st
                    for (a, sel, _, _, _), hedged in zip(hmeta, dup_lat):
                        better = hedged < latency[sel]
                        latency[sel[better]] = hedged[better]
                        n_hedged[m] += int(better.sum())
                elif len(straggler):
                    for a in np.unique(alt[ok]):
                        sel = straggler[ok & (alt == a)]
                        ti = arrivals[sel] + thr
                        if carry_in is not None:
                            prim_q, prim_r = streams.get(
                                a, (np.zeros(0, np.int64), np.zeros(0)))
                            mq = np.concatenate([prim_q, sel])
                            mr = np.concatenate([prim_r, ti])
                            order = np.argsort(mr, kind="stable")
                            st = _state_copy(carry_in[a])
                            f_all = pair_of[a].finish(mq[order], mr[order],
                                                      state=st)
                            pos = np.empty(len(mq), np.int64)
                            pos[order] = np.arange(len(mq))
                            hedged = f_all[pos[len(prim_q):]] - arrivals[sel]
                            # the merged pass re-serves the primaries too;
                            # their first-pass latencies stand (duplicates
                            # are a tail mechanism, not extra accounting),
                            # but the slot's carried state now includes the
                            # hedge work it actually absorbed
                            states[a] = st
                        else:  # legacy optimistic model: unloaded service
                            hedged = (ti - arrivals[sel]) + \
                                pair_of[a].solo_time(sel)
                        better = hedged < latency[sel]
                        latency[sel[better]] = hedged[better]
                        n_hedged[m] += int(better.sum())
            router.observe_many(latency[np.isfinite(latency)])
            lat_mt[m][t] = latency

            # carry-out: serving machines that survived the window keep
            # their residual backlog under a compacted instance index (a
            # failed machine's slot disappears; draining slots retire)
            new_states: dict[tuple[int, int], dict] = {}
            counters: dict[int, int] = {}
            bl = 0.0
            for si, key in enumerate(keys):
                if key is None:
                    continue
                h = key[0]
                idx = counters.get(h, 0)
                counters[h] = idx + 1
                bl += _drain_horizon(states[si], w_end)
                new_states[(h, idx)] = _state_residual(states[si], w_end)
            backlog_mt[m, t] = bl
            slot_states[m] = new_states if cfg.carry_backlog else {}

        # achieved-tail feedback for the next provisioning decision
        if cfg.tail_feedback:
            ok = True
            for m in range(M):
                lat = lat_mt[m][t]
                if lat is None:
                    continue
                if not np.isfinite(lat).all():
                    ok = False
                    break
                sla = profiles[table.workloads[m]].sla_ms
                if float(np.quantile(lat, cfg.sla_quantile)) * 1e3 > sla:
                    ok = False
                    break
            tail_ok_prev = ok

    # day-level aggregates + the per-interval (Fig. 8b-style) series
    workloads = {}
    series: dict[str, dict] = {}
    all_meet = True
    for m, name in enumerate(table.workloads):
        sla = profiles[name].sla_ms
        measured = [lat for lat in lat_mt[m] if lat is not None]
        lat_ms = np.concatenate(measured) * 1e3 if measured else \
            np.array([np.inf])
        p50, p95, p99 = _percentiles(lat_ms)
        q = float(np.quantile(lat_ms, cfg.sla_quantile))
        attainment = float(np.mean(lat_ms <= sla))
        meets = q <= sla
        all_meet &= meets
        s: dict[str, list] = {k: [] for k in (
            "p50_ms", "p95_ms", "p99_ms", "sla_attainment", "meets_sla",
            "n_queries")}
        met_t = 0
        for t in range(T):
            lat = lat_mt[m][t]
            if lat is None:
                for k in s:
                    s[k].append(None)
                continue
            ms = lat * 1e3
            i50, i95, i99 = _percentiles(ms)
            s["p50_ms"].append(i50)
            s["p95_ms"].append(i95)
            s["p99_ms"].append(i99)
            s["sla_attainment"].append(float(np.mean(ms <= sla)))
            im = bool(float(np.quantile(ms, cfg.sla_quantile)) <= sla)
            s["meets_sla"].append(im)
            s["n_queries"].append(int(len(ms)))
            met_t += im
        s["backlog_s"] = [float(b) for b in backlog_mt[m]]
        # True = window hit the query cap before the interval end and the
        # remainder is stationarity-bridged; False = fully simulated
        # (always False under cfg.event_core unless event_core_queries is
        # exceeded); None = interval not measured
        s["bridged"] = bridged_mt[m]
        n_meas = sum(1 for lat in lat_mt[m] if lat is not None)
        series[name] = s
        workloads[name] = {
            "sla_ms": sla, "p50_ms": p50, "p95_ms": p95, "p99_ms": p99,
            "sla_attainment": attainment, "meets_sla": bool(meets),
            "interval_sla_met_frac":
                float(met_t / n_meas) if n_meas else 0.0,
            "n_queries": int(len(lat_ms)), "n_hedged": int(n_hedged[m]),
            "n_retried": int(n_retried[m]),
        }
    return DayResult(
        policy=policy,
        power=power,
        capacity=capacity,
        churn=churn,
        feasible=feasible,
        peak_power_w=float(power.max()),
        avg_power_w=float(power.mean()),
        peak_capacity=int(capacity.max()),
        avg_capacity=float(capacity.mean()),
        resolves=prov.n_resolves,
        holds=prov.n_holds,
        tail_resolves=prov.n_tail_resolves,
        total_churn=int(churn.sum()),
        per_workload=workloads,
        series={
            "interval_s": transitions.interval_s,
            "per_workload": series,
        },
        all_meet_sla=bool(all_meet),
        events=events,
        latencies=lat_mt if cfg.collect_latencies else None,
        co_capacity=co_cap if inputs.colocation is not None else None,
    )
