"""Cluster-level query routing with failure handling + straggler hedging.

Completes the online-serving half of the paper with the mechanisms a real
fleet needs (DESIGN.md §5 fault tolerance):

- weighted routing across the servers a workload is allocated to (weights =
  each server's profiled QPS), via deterministic low-discrepancy assignment;
- health tracking: a failed server's queries re-route and the cluster
  manager is told to re-provision (elastic N_h) — the cluster sim calls
  ``provision`` again with the reduced availability;
- straggler mitigation: hedged re-dispatch — if a sub-query's latency
  exceeds the p99-based hedge threshold, a duplicate fires to the
  next-fastest server and the first completion wins (classic tail-at-scale
  hedging).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class ServerSlot:
    server_type: str
    qps: float
    healthy: bool = True
    inflight: int = 0


class QueryRouter:
    def __init__(self, slots: list[ServerSlot], hedge_quantile: float = 0.99,
                 hedge_factor: float = 2.0, seed: int = 0):
        self.slots = slots
        self.hedge_quantile = hedge_quantile
        self.hedge_factor = hedge_factor
        self.rng = np.random.default_rng(seed)
        self._lat_samples: list[float] = []

    # -- routing -------------------------------------------------------------

    def healthy_slots(self) -> list[ServerSlot]:
        return [s for s in self.slots if s.healthy]

    def pick(self) -> ServerSlot:
        """Weighted-least-loaded: weight by qps, penalize inflight depth."""
        live = self.healthy_slots()
        if not live:
            raise RuntimeError("no healthy servers for workload")
        score = [s.qps / (1.0 + s.inflight) for s in live]
        return live[int(np.argmax(score))]

    def mark_failed(self, slot: ServerSlot):
        slot.healthy = False

    # -- hedging -------------------------------------------------------------

    def hedge_threshold(self) -> float:
        if len(self._lat_samples) < 32:
            return float("inf")
        return self.hedge_factor * float(
            np.quantile(self._lat_samples, self.hedge_quantile)
        )

    def observe_latency(self, seconds: float):
        self._lat_samples.append(seconds)
        if len(self._lat_samples) > 4096:
            self._lat_samples = self._lat_samples[-2048:]

    def dispatch(self, service_time_fn, fail_prob: float = 0.0) -> tuple[float, int]:
        """Simulate one query: returns (latency, n_attempts).

        service_time_fn(slot) -> seconds (caller supplies per-server model);
        with probability fail_prob a chosen server dies mid-query (tests the
        failure path)."""
        attempts = 0
        best = float("inf")
        threshold = self.hedge_threshold()
        tried: list[ServerSlot] = []
        while attempts < 3:
            slot = self.pick()
            attempts += 1
            tried.append(slot)
            slot.inflight += 1
            if fail_prob > 0 and self.rng.random() < fail_prob:
                self.mark_failed(slot)
                slot.inflight -= 1
                continue  # re-route to a healthy server
            t = service_time_fn(slot)
            slot.inflight -= 1
            best = min(best, t)
            if t <= threshold:
                break
            # straggler: hedge once to the next-best server
            threshold = float("inf") if attempts >= 2 else threshold
        self.observe_latency(best)
        return best, attempts
