"""Cluster-level query routing with failure handling + straggler hedging.

Completes the online-serving half of the paper with the mechanisms a real
fleet needs (DESIGN.md §5 fault tolerance):

- weighted routing across the servers a workload is allocated to (weights =
  each server's profiled QPS), via deterministic low-discrepancy assignment
  (:meth:`QueryRouter.assign_stream` — the golden-ratio sequence over the
  cumulative weight profile, segment-vectorized between pool changes);
- health tracking: a failed server's queries re-route and the cluster
  manager is told to re-provision (elastic N_h) — the cluster runtime calls
  the provisioner again with the reduced availability;
- transition awareness: a slot only takes new queries inside its
  ``[ready_at, retire_at)`` window — newly provisioned servers join the
  pool once their model load completes, drained servers leave it while
  still finishing in-flight work (`repro.serving.cluster_runtime`);
- straggler mitigation: hedged re-dispatch — if a sub-query's latency
  exceeds the p99-based hedge threshold, a duplicate fires to the
  next-fastest server and the first completion wins (classic tail-at-scale
  hedging).  :meth:`QueryRouter.hedge_assign` picks the duplicate's target
  per straggler — the fastest slot, *other than the primary*, that accepts
  queries at the hedge's issue time; the cluster runtime then admits the
  duplicate into that slot's **live** queue (it contends with the slot's
  in-flight work, not its unloaded service time).
"""
from __future__ import annotations

import dataclasses

import numpy as np

_GOLDEN = 0.6180339887498949  # frac(phi): lowest-discrepancy 1-D sequence


def split_stream_by_share(n: int, shares: np.ndarray,
                          seq: int = 0) -> np.ndarray:
    """Partition stream positions ``0..n-1`` among ``len(shares)`` groups.

    Group counts are the largest-remainder apportionment of ``n`` by
    ``shares`` (exact: counts sum to ``n``, every position lands in exactly
    one group); positions are interleaved by the same golden-ratio sequence
    ``assign_stream`` uses, so each group receives an evenly spread — not
    contiguous — slice of the stream.  Deterministic in ``(n, shares,
    seq)``.  The geo layer uses this to attribute a merged post-spill
    stream back to its origin regions (``repro.serving.geo``).
    """
    shares = np.asarray(shares, dtype=np.float64)
    if shares.ndim != 1 or len(shares) == 0:
        raise ValueError("shares must be a non-empty 1-D array")
    if (shares < 0).any() or shares.sum() <= 0:
        raise ValueError("shares must be non-negative with a positive sum")
    out = np.empty(n, np.int64)
    if n == 0:
        return out
    quota = n * shares / shares.sum()
    counts = np.floor(quota).astype(np.int64)
    rem = n - int(counts.sum())
    if rem:  # largest fractional parts win; ties break to the lowest index
        frac = quota - counts
        order = np.lexsort((np.arange(len(shares)), -frac))
        counts[order[:rem]] += 1
    u = ((seq + np.arange(n)) * _GOLDEN) % 1.0
    pos = np.argsort(u, kind="stable")
    out[pos] = np.repeat(np.arange(len(shares), dtype=np.int64), counts)
    return out


@dataclasses.dataclass
class ServerSlot:
    server_type: str
    qps: float
    healthy: bool = True
    inflight: int = 0
    ready_at: float = 0.0          # model load completes (serving starts)
    retire_at: float = float("inf")  # drain deadline (stops taking queries)
    # physical machine identity.  None = a machine this tenant owns alone;
    # a shared (co-located) machine appears as one slot per tenant pool,
    # all carrying the same identity prefix, so a hardware failure can be
    # attributed to every tenant it serves (``mark_machine_failed``).
    machine: tuple | None = None

    def accepts(self, t: float) -> bool:
        return self.healthy and self.ready_at <= t < self.retire_at


class QueryRouter:
    def __init__(self, slots: list[ServerSlot], hedge_quantile: float = 0.99,
                 hedge_factor: float = 2.0, seed: int = 0):
        self.slots = slots
        self.hedge_quantile = hedge_quantile
        self.hedge_factor = hedge_factor
        self.rng = np.random.default_rng(seed)
        self._lat_samples: list[float] = []
        # low-discrepancy phase: seed-derived without consuming self.rng
        # (dispatch()'s failure draws stay bit-stable across this addition)
        self._seq = (int(seed) * 2654435761) % (1 << 16)

    # -- routing -------------------------------------------------------------

    def refresh(self, slots: list[ServerSlot]):
        """Swap in a new interval's slot pool, keeping latency history (the
        hedge threshold carries over) and the assignment sequence."""
        self.slots = slots

    def healthy_slots(self) -> list[ServerSlot]:
        return [s for s in self.slots if s.healthy]

    def pick(self) -> ServerSlot:
        """Weighted-least-loaded: weight by qps, penalize inflight depth."""
        live = self.healthy_slots()
        if not live:
            raise RuntimeError("no healthy servers for workload")
        score = [s.qps / (1.0 + s.inflight) for s in live]
        return live[int(np.argmax(score))]

    def mark_failed(self, slot: ServerSlot):
        slot.healthy = False

    def mark_machine_failed(self, machine: tuple) -> list[ServerSlot]:
        """Fail every slot whose identity starts with ``machine`` — the
        per-tenant views of one shared physical machine go down together.
        Returns the slots marked (for the caller's re-dispatch pass)."""
        hit = [s for s in self.slots if s.machine is not None
               and s.machine[:len(machine)] == machine]
        for s in hit:
            s.healthy = False
        return hit

    def sla_attribution(self, assigned: np.ndarray, latency: np.ndarray,
                        sla_s: float) -> dict[tuple | None, dict]:
        """Per-machine SLA attribution of one served stream: for every
        machine identity in the pool (``None`` groups all tenant-exclusive
        slots), the queries it served and how many met ``sla_s``.  Lets a
        co-located day answer "which shared machine hurt which tenant"
        without re-simulating."""
        assigned = np.asarray(assigned, np.int64)
        latency = np.asarray(latency, np.float64)
        out: dict[tuple | None, dict] = {}
        for i, s in enumerate(self.slots):
            sel = latency[assigned == i]
            if len(sel) == 0:
                continue
            g = out.setdefault(s.machine, {"n_queries": 0, "n_met": 0})
            g["n_queries"] += int(len(sel))
            g["n_met"] += int((sel <= sla_s).sum())
        return out

    def assign_stream(self, arrivals: np.ndarray) -> np.ndarray:
        """Assign each arrival to a slot; returns slot indices.

        Deterministic low-discrepancy weighted assignment: query ``i`` maps
        to the slot whose cumulative-weight bin contains ``frac(i * phi)``
        (weights = profiled QPS), so every weight-``w`` slot receives a
        ``w``-proportional, evenly interleaved share of the stream without
        per-query randomness — reproducible across policies (CRN) and free
        of the clumping a multinomial draw would add.  The pool is
        re-evaluated at slot readiness/retirement boundaries inside the
        stream (segment-vectorized); raises ``RuntimeError`` when no slot
        accepts queries at some point of the stream.
        """
        arrivals = np.asarray(arrivals, dtype=np.float64)
        n = len(arrivals)
        out = np.empty(n, np.int64)
        if n == 0:
            return out
        # pool-change boundaries that fall inside this stream
        edges = {s.ready_at for s in self.slots} | {s.retire_at for s in self.slots}
        cuts = sorted(e for e in edges if arrivals[0] < e <= arrivals[-1])
        bounds = [0] + [int(np.searchsorted(arrivals, c, side="left"))
                        for c in cuts] + [n]
        for lo, hi in zip(bounds[:-1], bounds[1:]):
            if lo >= hi:
                continue
            t = float(arrivals[lo])
            w = np.array([s.qps if s.accepts(t) else 0.0 for s in self.slots])
            total = w.sum()
            if total <= 0.0:
                raise RuntimeError("no healthy servers for workload")
            cum = np.cumsum(w) / total
            u = ((self._seq + np.arange(lo, hi)) * _GOLDEN) % 1.0
            out[lo:hi] = np.minimum(np.searchsorted(cum, u, side="right"),
                                    len(self.slots) - 1)
        self._seq += n
        return out

    # -- hedging -------------------------------------------------------------

    def hedge_assign(self, primary: np.ndarray,
                     t_issue: np.ndarray) -> np.ndarray:
        """Hedge target per straggler: the highest-QPS slot other than the
        straggler's ``primary`` slot that accepts queries at the hedge's
        issue time (``-1`` when no such slot exists — loading, draining and
        failed slots can't take a duplicate).  The caller admits the
        duplicate into the target's live queue at ``t_issue``."""
        primary = np.asarray(primary, np.int64)
        t_issue = np.asarray(t_issue, np.float64)
        out = np.full(len(primary), -1, np.int64)
        for j, (p, ti) in enumerate(zip(primary.tolist(), t_issue.tolist())):
            best, best_qps = -1, -1.0
            for i, s in enumerate(self.slots):
                if i != p and s.qps > best_qps and s.accepts(ti):
                    best, best_qps = i, s.qps
            out[j] = best
        return out

    def hedge_events(self, assigned: np.ndarray, arrivals: np.ndarray,
                     latency: np.ndarray, threshold: float,
                     ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Straggler detection + duplicate targeting as one event extraction.

        Returns ``(straggler, t_issue, alt)``: indices of finite-latency
        queries over ``threshold``, the duplicates' issue times
        (``arrival + threshold`` — the moment the client gives up waiting),
        and each duplicate's target slot from :meth:`hedge_assign`
        (``-1`` = no slot accepts at issue time).  This is the runtime's
        historical straggler selection consolidated behind the router, so
        both the first-pass and the event-ordered hedging passes emit the
        identical event stream."""
        straggler = np.flatnonzero(np.isfinite(latency)
                                   & (latency > threshold))
        t_issue = np.asarray(arrivals, np.float64)[straggler] + threshold
        if len(straggler) == 0:
            return straggler, t_issue, np.zeros(0, np.int64)
        alt = self.hedge_assign(np.asarray(assigned, np.int64)[straggler],
                                t_issue)
        return straggler, t_issue, alt

    def hedge_threshold(self) -> float:
        if len(self._lat_samples) < 32:
            return float("inf")
        return self.hedge_factor * float(
            np.quantile(self._lat_samples, self.hedge_quantile)
        )

    def observe_latency(self, seconds: float):
        self._lat_samples.append(seconds)
        if len(self._lat_samples) > 4096:
            self._lat_samples = self._lat_samples[-2048:]

    def observe_many(self, seconds: np.ndarray):
        self._lat_samples.extend(np.asarray(seconds, dtype=float).tolist())
        if len(self._lat_samples) > 4096:
            self._lat_samples = self._lat_samples[-2048:]

    def dispatch(self, service_time_fn, fail_prob: float = 0.0) -> tuple[float, int]:
        """Simulate one query: returns (latency, n_attempts).

        service_time_fn(slot) -> seconds (caller supplies per-server model);
        with probability fail_prob a chosen server dies mid-query (tests the
        failure path)."""
        attempts = 0
        best = float("inf")
        threshold = self.hedge_threshold()
        tried: list[ServerSlot] = []
        while attempts < 3:
            slot = self.pick()
            attempts += 1
            tried.append(slot)
            slot.inflight += 1
            if fail_prob > 0 and self.rng.random() < fail_prob:
                self.mark_failed(slot)
                slot.inflight -= 1
                continue  # re-route to a healthy server
            t = service_time_fn(slot)
            slot.inflight -= 1
            best = min(best, t)
            if t <= threshold:
                break
            # straggler: hedge once to the next-best server
            threshold = float("inf") if attempts >= 2 else threshold
        self.observe_latency(best)
        return best, attempts
