"""k-server FIFO queueing engine — the simulator's hot core.

Every pool in the serving simulator (CPU thread pools, the S-D sparse and
dense pools, the accelerator host pool) is the same discrete-event object:
jobs processed FIFO *in a given order*, each job taken by the earliest-free
of ``k`` identical servers (the Kiefer-Wolfowitz recurrence).  The PR-1
implementation interleaved that recurrence with per-job NumPy indexing,
dict lookups and byte accounting, which made offline profiling
interpreter-bound.  This module isolates the recurrence so everything
around it (query splitting, duration tables, fusion grouping, utilization
accounting, per-query finish reduction) becomes NumPy array sweeps in
``simulator.py``, and solves the recurrence itself in closed form where an
exact vectorization exists:

- ``k == 1``: the Lindley recurrence ``e_j = max(ready_j, e_{j-1}) + dur_j``
  unrolls to ``e_j = T_j + max_{l<=j}(ready_l - T_{l-1})`` with
  ``T = cumsum(dur)`` — one ``cumsum`` plus one ``maximum.accumulate``.  A
  carried prefix (the server still busy from an earlier window) enters the
  closed form as ``e_{-1} = f0``, i.e. the first accumulate term becomes
  ``max(ready_0, f0)`` — continuous-time windows cost one extra ``max``.
- ``k >= n``: every job finds an idle server — ``max(ready, 0) + dur``.
- otherwise: a minimal-overhead scalar sweep over pre-extracted float lists
  (``heapreplace`` on a k-element heap).  The general earliest-free
  recurrence is inherently sequential — each pop depends on the running
  order statistics of all earlier ends — so the fast path wins by stripping
  the per-job Python/NumPy overhead, not by pretending the data dependence
  away.  (An exact "assignment relaxation" vectorization was prototyped and
  measured: it converges only in light traffic and loses 10x under the
  overloaded probes the throughput bisection must evaluate, so it was
  dropped.)  Large ``k > 1`` streams (``n >= 4096``) dispatch to the
  ``event_core`` blocked kernel instead: bitwise-equal
  speculate-and-verify blocks that win outright in light/constant-
  duration regimes and cost a few percent when every block falls back
  to this sweep; batches of *independent* streams should use
  ``event_core.fleet_fifo_finish``, which is ~10x regardless of regime.

Floating point: the Lindley transform reassociates max/plus, so k == 1
fast-path finish times can differ from the reference loop by accumulated
rounding (~1e-12 relative); equivalence tests use tight tolerances rather
than bitwise equality.  The k > 1 sweep performs the identical operations
as the reference and is bitwise-exact.
"""
from __future__ import annotations

import heapq
import sys

import numpy as np

# introspection counters (benchmarks report path mix; "blocked" counts
# dispatches to the event_core blocked kernel)
stats = {"lindley": 0, "idle": 0, "sweep": 0, "reference": 0, "blocked": 0}

# auto-dispatch threshold: below this the blocked kernel's speculation
# setup cannot win over the plain sweep even when a path hits
_BLOCKED_MIN_N = 4096


def stats_reset() -> None:
    """Reset the path-mix counters (and the event core's, if loaded).

    Benchmarks report the mix per-bench and tests assert on it, so a
    shared global counter must be resettable — ``tests/conftest.py``
    calls this around every test."""
    for key in stats:
        stats[key] = 0
    ec = sys.modules.get("repro.serving.event_core")
    if ec is not None:
        ec.stats_reset()


def _event_core():
    """Lazy import: event_core imports ``_sweep`` from this module, so
    the dependency must not be circular at import time."""
    from repro.serving import event_core
    return event_core


def fifo_finish(
    ready: np.ndarray, dur: np.ndarray, k: int, slow: bool = False,
    free0: np.ndarray | None = None, blocked: bool | None = None,
) -> np.ndarray:
    """Finish times of jobs processed FIFO (in array order) by ``k``
    identical servers, each job taken by the earliest-free server.

    ``ready`` need not be sorted: the j-th job enters service at
    ``max(ready_j, pop_j)`` where pops are handed out in array order —
    exactly the semantics of the reference ``heapq`` loop.

    ``free0`` (length ``k``) seeds the servers' initial free times — the
    carried backlog of an earlier window.  ``None`` keeps the historical
    idle-pool start (all zeros) and its fast paths bit-for-bit.

    ``blocked=True`` forces the event-core blocked kernel for ``k > 1``
    (bitwise-equal to the sweep, see ``event_core``); ``None`` lets the
    dispatcher pick it automatically for large streams, where its
    speculation paths win in light/constant-duration regimes and its
    failed-speculation overhead is a few percent otherwise.
    """
    ready = np.asarray(ready, dtype=np.float64)
    dur = np.asarray(dur, dtype=np.float64)
    n = ready.shape[0]
    if n == 0:
        return np.zeros(0)
    k = max(int(k), 1)
    if slow:
        stats["reference"] += 1
        return _sweep(ready, dur, k, free0)
    if k == 1:
        stats["lindley"] += 1
        f0 = 0.0 if free0 is None else float(np.max(free0, initial=0.0))
        return _lindley(ready, dur, f0)
    if k >= n and (free0 is None or
                   float(free0.max()) <= float(ready.min())):
        # every job gets a server that is free by its arrival
        stats["idle"] += 1
        if free0 is None:
            return np.maximum(ready, 0.0) + dur
        return ready + dur
    if blocked or (blocked is None and n >= _BLOCKED_MIN_N):
        stats["blocked"] += 1
        return _event_core().blocked_fifo_finish(ready, dur, k, free0=free0)
    stats["sweep"] += 1
    return _sweep(ready, dur, k, free0)


def fifo_finish_state(
    ready: np.ndarray, dur: np.ndarray, k: int,
    free0: np.ndarray | None = None, blocked: bool | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """:func:`fifo_finish` plus the pool's end state — the ``k`` server
    free times after the last job, sorted ascending.  This is what a
    continuous-time caller carries into the next window as ``free0``.

    Finish times are identical to ``fifo_finish``: the ``k == 1`` closed
    form is shared, and the ``k >= n`` shortcut applies whenever every
    server is free by the first arrival — pops then consume the ``n``
    smallest initial free times and every job starts at its arrival, so
    both the ends and the end state are exact array expressions.
    """
    ready = np.asarray(ready, dtype=np.float64)
    dur = np.asarray(dur, dtype=np.float64)
    k = max(int(k), 1)
    if free0 is None:
        free0 = np.zeros(k)
    free0 = np.asarray(free0, dtype=np.float64)
    if ready.shape[0] == 0:
        return np.zeros(0), np.sort(free0)
    if k == 1:
        stats["lindley"] += 1
        ends = _lindley(ready, dur, float(np.max(free0, initial=0.0)))
        return ends, ends[-1:].copy()
    if k >= len(ready) and float(free0.max()) <= float(ready.min()):
        stats["idle"] += 1
        ends = np.maximum(ready, 0.0) + dur if not free0.any() else \
            ready + dur
        state = np.sort(np.concatenate([np.sort(free0)[len(ready):], ends]))
        return ends, state
    if blocked or (blocked is None and len(ready) >= _BLOCKED_MIN_N):
        stats["blocked"] += 1
        return _event_core().blocked_fifo_finish(
            ready, dur, k, free0=free0, return_state=True)
    stats["sweep"] += 1
    return _sweep(ready, dur, k, free0, return_state=True)


def _sweep(ready: np.ndarray, dur: np.ndarray, k: int,
           free0: np.ndarray | None = None, return_state: bool = False):
    """Earliest-free k-server FIFO, one heap op per job and nothing else."""
    free = [0.0] * k if free0 is None else \
        np.asarray(free0, dtype=np.float64).tolist()
    heapq.heapify(free)
    replace = heapq.heapreplace
    ends: list[float] = []
    append = ends.append
    for a, t in zip(ready.tolist(), dur.tolist()):
        f = free[0]
        e = (a if a > f else f) + t
        append(e)
        replace(free, e)
    if return_state:
        return np.asarray(ends), np.sort(free)
    return np.asarray(ends)


def _lindley(ready: np.ndarray, dur: np.ndarray, f0: float = 0.0) -> np.ndarray:
    """Exact single-server FIFO via the unrolled Lindley recurrence,
    extended to a carried prefix: ``f0`` is the server's free time before
    the first job (``e_{-1}``), so the first accumulate term is
    ``max(ready_0, f0)``."""
    T = np.cumsum(dur)
    adj = ready - (T - dur)
    if f0 > adj[0]:
        adj = adj.copy()
        adj[0] = f0
    return T + np.maximum.accumulate(adj)
