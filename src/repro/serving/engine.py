"""k-server FIFO queueing engine — the simulator's hot core.

Every pool in the serving simulator (CPU thread pools, the S-D sparse and
dense pools, the accelerator host pool) is the same discrete-event object:
jobs processed FIFO *in a given order*, each job taken by the earliest-free
of ``k`` identical servers (the Kiefer-Wolfowitz recurrence).  The PR-1
implementation interleaved that recurrence with per-job NumPy indexing,
dict lookups and byte accounting, which made offline profiling
interpreter-bound.  This module isolates the recurrence so everything
around it (query splitting, duration tables, fusion grouping, utilization
accounting, per-query finish reduction) becomes NumPy array sweeps in
``simulator.py``, and solves the recurrence itself in closed form where an
exact vectorization exists:

- ``k == 1``: the Lindley recurrence ``e_j = max(ready_j, e_{j-1}) + dur_j``
  unrolls to ``e_j = T_j + max_{l<=j}(ready_l - T_{l-1})`` with
  ``T = cumsum(dur)`` — one ``cumsum`` plus one ``maximum.accumulate``.
- ``k >= n``: every job finds an idle server — ``max(ready, 0) + dur``.
- otherwise: a minimal-overhead scalar sweep over pre-extracted float lists
  (``heapreplace`` on a k-element heap).  The general earliest-free
  recurrence is inherently sequential — each pop depends on the running
  order statistics of all earlier ends — so the fast path wins by stripping
  the per-job Python/NumPy overhead, not by pretending the data dependence
  away.  (An exact "assignment relaxation" vectorization was prototyped and
  measured: it converges only in light traffic and loses 10x under the
  overloaded probes the throughput bisection must evaluate, so it was
  dropped.)

Floating point: the Lindley transform reassociates max/plus, so k == 1
fast-path finish times can differ from the reference loop by accumulated
rounding (~1e-12 relative); equivalence tests use tight tolerances rather
than bitwise equality.  The k > 1 sweep performs the identical operations
as the reference and is bitwise-exact.
"""
from __future__ import annotations

import heapq

import numpy as np

# introspection counters (benchmarks report path mix)
stats = {"lindley": 0, "idle": 0, "sweep": 0, "reference": 0}


def fifo_finish(
    ready: np.ndarray, dur: np.ndarray, k: int, slow: bool = False
) -> np.ndarray:
    """Finish times of jobs processed FIFO (in array order) by ``k``
    identical servers, each job taken by the earliest-free server.

    ``ready`` need not be sorted: the j-th job enters service at
    ``max(ready_j, pop_j)`` where pops are handed out in array order —
    exactly the semantics of the reference ``heapq`` loop.
    """
    ready = np.asarray(ready, dtype=np.float64)
    dur = np.asarray(dur, dtype=np.float64)
    n = ready.shape[0]
    if n == 0:
        return np.zeros(0)
    k = max(int(k), 1)
    if slow:
        stats["reference"] += 1
        return _sweep(ready, dur, k)
    if k == 1:
        stats["lindley"] += 1
        return _lindley(ready, dur)
    if k >= n:  # every job gets an idle server
        stats["idle"] += 1
        return np.maximum(ready, 0.0) + dur
    stats["sweep"] += 1
    return _sweep(ready, dur, k)


def _sweep(ready: np.ndarray, dur: np.ndarray, k: int) -> np.ndarray:
    """Earliest-free k-server FIFO, one heap op per job and nothing else."""
    free = [0.0] * k
    replace = heapq.heapreplace
    ends: list[float] = []
    append = ends.append
    for a, t in zip(ready.tolist(), dur.tolist()):
        f = free[0]
        e = (a if a > f else f) + t
        append(e)
        replace(free, e)
    return np.asarray(ends)


def _lindley(ready: np.ndarray, dur: np.ndarray) -> np.ndarray:
    """Exact single-server FIFO via the unrolled Lindley recurrence."""
    T = np.cumsum(dur)
    return T + np.maximum.accumulate(ready - (T - dur))
