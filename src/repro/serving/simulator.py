"""Discrete-event single-server serving simulator.

Models one server executing one recommendation workload under a partition
placement and a scheduling configuration, with the paper's arrival process:
Poisson query arrivals, heavy-tailed query sizes (Fig. 2b). It reproduces
the mechanisms the paper measures:

- CPU pools: ``m`` inference threads × ``o`` operator workers; big queries
  split into sub-queries of <= d items distributed over threads
  (DeepRecSys-style data parallelism); memory-bandwidth contention across
  co-located threads.
- S-D pipeline (cpu_sd): sparse pool -> intermediate queue -> dense pool.
- Accelerator: co-located inference threads (<= max m in flight) pipelining
  through two serialized resources — host link (data loading; the paper's
  Fig. 7 bottleneck) and engine (kernels) — with query fusion up to d items
  per launch. Host-side stage (cold-psum / SparseNet) runs on a host pool.

Outputs: achieved QPS, latency percentiles, component utilizations, and
average/provisioned power via the PowerModel.
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from repro.core.devices import DeviceProfile
from repro.core.partition import Placement
from repro.core.perfmodel import (
    PowerModel,
    accel_engine_time,
    accel_link_time,
    cpu_stage_time,
)


@dataclasses.dataclass(frozen=True)
class SchedConfig:
    """One point in the parallelism space P(M+D+O)."""

    batch: int          # d: sub-query size (CPU) / fused launch size (accel)
    m: int              # model-parallelism: CPU threads or accel co-location
    o: int = 1          # op-parallelism: operator workers per CPU thread
    sd_sparse: int = 0  # cpu_sd: threads in the sparse pool (o workers each)
    fuse: bool = True   # accel query fusion (False = DeepRecSys/Baymax mode)


@dataclasses.dataclass
class SimResult:
    qps: float
    p50_ms: float
    p95_ms: float
    p99_ms: float
    avg_power_w: float
    utils: dict
    n_queries: int

    def meets(self, sla_ms: float, power_budget_w: float | None = None) -> bool:
        ok = self.p95_ms <= sla_ms
        if power_budget_w is not None:
            ok = ok and self.avg_power_w <= power_budget_w
        return ok


class _Pool:
    """k-server FIFO resource; returns per-job start times."""

    def __init__(self, k: int):
        self.free_at = [0.0] * max(k, 1)

    def schedule(self, ready: float, duration: float) -> tuple[float, float]:
        start, end, _ = self.schedule_idx(ready, duration)
        return start, end

    def schedule_idx(self, ready: float, duration: float) -> tuple[float, float, int]:
        i = int(np.argmin(self.free_at))
        start = max(ready, self.free_at[i])
        self.free_at[i] = start + duration
        return start, start + duration, i

    @property
    def busy_until(self) -> float:
        return max(self.free_at)


def _split_queries(sizes: np.ndarray, arrivals: np.ndarray, d: int):
    """Split each query into sub-batches of <= d items (vectorized).

    Returns (sub_arrival, sub_size, query_id) arrays."""
    sizes = sizes.astype(np.int64)
    n_sub = -(-sizes // d)  # ceil
    qid = np.repeat(np.arange(len(sizes)), n_sub)
    sub_a = arrivals[qid]
    sub_s = np.full(len(qid), d, np.int64)
    last = np.cumsum(n_sub) - 1
    rem = sizes - (n_sub - 1) * d
    sub_s[last] = rem
    return sub_a, sub_s, qid


def simulate(
    placement: Placement,
    device: DeviceProfile,
    sched: SchedConfig,
    arrival_qps: float,
    query_sizes: np.ndarray,
    seed: int = 0,
) -> SimResult:
    rng = np.random.default_rng(seed)
    n = len(query_sizes)
    gaps = rng.exponential(1.0 / max(arrival_qps, 1e-9), n)
    arrivals = np.cumsum(gaps)
    d = max(sched.batch, 1)

    finish = np.zeros(n)
    busy = {"cores": 0.0, "mem_bytes": 0.0, "engine": 0.0, "link": 0.0}

    if placement.plan == "cpu_model":
        finish = _sim_cpu_model(placement, device, sched, arrivals, query_sizes, busy)
    elif placement.plan == "cpu_sd":
        finish = _sim_cpu_sd(placement, device, sched, arrivals, query_sizes, busy)
    else:
        finish = _sim_accel(placement, device, sched, arrivals, query_sizes, busy)

    latency_ms = (finish - arrivals) * 1e3
    span = max(finish.max() - arrivals[0], 1e-9)
    utils = {
        "cores": min(busy["cores"] / (span * device.cpu.cores), 1.0),
        "mem": min(busy["mem_bytes"] / (span * device.mem.bw_gbs * 1e9), 1.0),
        "engine": min(busy["engine"] / span, 1.0) if device.accel else 0.0,
        "link": min(busy["link"] / span, 1.0) if device.accel else 0.0,
    }
    power = PowerModel(device).average_power(utils)
    return SimResult(
        qps=n / span,
        p50_ms=float(np.percentile(latency_ms, 50)),
        p95_ms=float(np.percentile(latency_ms, 95)),
        p99_ms=float(np.percentile(latency_ms, 99)),
        avg_power_w=power,
        utils=utils,
        n_queries=n,
    )


def _items_bytes(ops, batch):
    return sum(
        (op.stream_bytes + op.gather_bytes) * batch + op.weight_bytes for op in ops
    )


def _duration_table(ops, workers, device, active, sub_s):
    """Memoized service times for the distinct sub-batch sizes."""
    return {
        int(b): cpu_stage_time(ops, int(b), workers, device, active)
        for b in np.unique(sub_s)
    }


def _sim_cpu_model(placement, device, sched, arrivals, sizes, busy):
    """m threads × o workers; shared sub-query FIFO (heap of free times)."""
    import heapq

    ops = placement.host_ops
    sub_a, sub_s, qid = _split_queries(sizes, arrivals, sched.batch)
    durs = _duration_table(ops, sched.o, device, sched.m, sub_s)
    bts = {b: _items_bytes(ops, b) for b in durs}
    free = [0.0] * max(sched.m, 1)
    heapq.heapify(free)
    finish = np.zeros(len(sizes))
    order = np.argsort(sub_a, kind="stable")
    for j in order:
        b = int(sub_s[j])
        t = durs[b]
        start = max(sub_a[j], heapq.heappop(free))
        end = start + t
        heapq.heappush(free, end)
        if end > finish[qid[j]]:
            finish[qid[j]] = end
        busy["cores"] += t * sched.o
        busy["mem_bytes"] += bts[b]
    return finish


def _sim_cpu_sd(placement, device, sched, arrivals, sizes, busy):
    """Sparse pool (sd_sparse threads × o workers) -> dense pool (m × 1).

    Bandwidth/LLC contention is per-pool: the dedicated sparse pool contends
    only with itself — the S-D partition's core advantage."""
    import heapq

    m_sparse = max(sched.sd_sparse, 1)
    m_dense = max(sched.m, 1)
    sub_a, sub_s, qid = _split_queries(sizes, arrivals, sched.batch)
    durs_s = _duration_table(placement.host_sparse, sched.o, device, m_sparse, sub_s)
    durs_d = _duration_table(placement.host_dense, 1, device, m_dense, sub_s)
    bts = {b: _items_bytes(placement.host_ops, b) for b in durs_s}
    free_s = [0.0] * m_sparse
    free_d = [0.0] * m_dense
    heapq.heapify(free_s)
    heapq.heapify(free_d)
    finish = np.zeros(len(sizes))
    order = np.argsort(sub_a, kind="stable")
    for j in order:
        b = int(sub_s[j])
        ts, td = durs_s[b], durs_d[b]
        s_start = max(sub_a[j], heapq.heappop(free_s))
        s_end = s_start + ts
        heapq.heappush(free_s, s_end)
        d_start = max(s_end, heapq.heappop(free_d))
        d_end = d_start + td
        heapq.heappush(free_d, d_end)
        if d_end > finish[qid[j]]:
            finish[qid[j]] = d_end
        busy["cores"] += ts * sched.o + td
        busy["mem_bytes"] += bts[b]
    return finish


def _sim_accel(placement, device, sched, arrivals, sizes, busy):
    """Host stage pool -> link -> engine, with m-way co-location and
    query fusion up to d items per launch."""
    cores = device.cpu.cores
    host_ops = placement.host_ops
    # host pool: remaining cores as sparse threads with o workers each
    host_threads = max(cores // max(sched.o, 1), 1)
    host_pool = _Pool(host_threads)
    link = _Pool(1)
    engine = _Pool(1)
    colocate = _Pool(max(sched.m, 1))  # admission: <= m fused launches in flight

    d = max(sched.batch, 1)
    sub_a, sub_s, qid = _split_queries(sizes, arrivals, d)
    order = np.argsort(sub_a, kind="stable")
    finish = np.zeros(len(sizes))

    # Greedy fusion: walk sub-queries in arrival order, fuse consecutive
    # sub-queries into one launch while total items <= d.
    host_durs: dict[int, float] = {}
    eng_durs: dict[int, float] = {}
    link_durs: dict[int, float] = {}

    def _host_t(b):
        if b not in host_durs:
            host_durs[b] = cpu_stage_time(host_ops, b, sched.o, device, host_threads)
        return host_durs[b]

    def _eng_t(b):
        if b not in eng_durs:
            eng_durs[b] = accel_engine_time(placement.accel_ops, b, device)
        return eng_durs[b]

    def _link_t(b):
        if b not in link_durs:
            link_durs[b] = accel_link_time(placement.link_bytes_per_item, b, device)
        return link_durs[b]

    i = 0
    idx = order.tolist()
    while i < len(idx):
        batch_ids = [idx[i]]
        total = int(sub_s[idx[i]])
        i += 1
        while sched.fuse and i < len(idx) and total + int(sub_s[idx[i]]) <= d:
            # fuse only queries that have already arrived by the time the
            # first arrived (no artificial waiting -> no added queuing delay)
            if sub_a[idx[i]] - sub_a[batch_ids[0]] > 0.002:
                break
            batch_ids.append(idx[i])
            total += int(sub_s[idx[i]])
            i += 1
        ready = max(sub_a[j] for j in batch_ids)
        if host_ops:
            th = _host_t(total)
            _, ready = host_pool.schedule(ready, th)
            busy["cores"] += th * sched.o
            busy["mem_bytes"] += _items_bytes(host_ops, total)
        # admission slot (co-location degree): holds until engine completes
        slot_start, _, slot = colocate.schedule_idx(ready, 0.0)
        tl = _link_t(total)
        _, l_end = link.schedule(slot_start, tl)
        te = _eng_t(total)
        _, e_end = engine.schedule(l_end, te)
        busy["link"] += tl
        busy["engine"] += te
        colocate.free_at[slot] = e_end
        for j in batch_ids:
            finish[qid[j]] = max(finish[qid[j]], e_end)
    return finish


def capacity_bound_qps(
    placement: Placement,
    device: DeviceProfile,
    sched: SchedConfig,
    mean_query_size: float,
) -> float:
    """Analytic steady-state throughput ceiling (items/s across the binding
    resource, converted to queries/s). Brackets the bisection so the sim is
    never asked to 'sustain' a rate it only drains as a burst."""
    d = max(sched.batch, 1)
    caps = []
    if placement.plan in ("cpu_model", "cpu_sd"):
        if placement.plan == "cpu_model":
            t = cpu_stage_time(placement.host_ops, d, sched.o, device, sched.m)
            caps.append(sched.m * d / max(t, 1e-12))
        else:
            m_s, m_d = max(sched.sd_sparse, 1), max(sched.m, 1)
            ts = cpu_stage_time(placement.host_sparse, d, sched.o, device, m_s)
            td = cpu_stage_time(placement.host_dense, d, 1, device, m_d)
            caps.append(m_s * d / max(ts, 1e-12))
            caps.append(m_d * d / max(td, 1e-12))
    else:
        if placement.host_ops:
            ht = max(device.cpu.cores // max(sched.o, 1), 1)
            th = cpu_stage_time(placement.host_ops, d, sched.o, device, ht)
            caps.append(ht * d / max(th, 1e-12))
        tl = accel_link_time(placement.link_bytes_per_item, d, device)
        te = accel_engine_time(placement.accel_ops, d, device)
        caps.append(d / max(tl, 1e-12))
        caps.append(d / max(te, 1e-12))
    return min(caps) / max(mean_query_size, 1.0)


def _sized_queries(base_sizes: np.ndarray, rate: float, sla_ms: float, seed: int):
    """Resample query sizes so the sim spans >= ~20 SLA windows (steady
    state), capped for runtime. Above the cap the run is burst-shaped; the
    analytic capacity bound caps the reported throughput instead."""
    duration = max(0.3, 20.0 * sla_ms * 1e-3)
    n = int(np.clip(rate * duration, 200, 6000))
    rng = np.random.default_rng(seed + 17)
    return base_sizes[rng.integers(0, len(base_sizes), n)]


def max_sustainable_qps(
    placement: Placement,
    device: DeviceProfile,
    sched: SchedConfig,
    sla_ms: float,
    query_sizes: np.ndarray,
    power_budget_w: float | None = None,
    seed: int = 0,
    n_bisect: int = 7,
) -> tuple[float, SimResult | None]:
    """Latency-bounded throughput: max Poisson rate with p95 <= SLA."""
    mean_size = float(np.mean(query_sizes))
    bound = capacity_bound_qps(placement, device, sched, mean_size)
    if bound <= 0:
        return 0.0, None
    lo, hi = 0.0, bound * 1.25
    best: SimResult | None = None
    r = simulate(placement, device, sched, hi,
                 _sized_queries(query_sizes, hi, sla_ms, seed), seed)
    if r.meets(sla_ms, power_budget_w):
        # capacity-bound regime: report the analytic ceiling, never more
        return bound, r
    for _ in range(n_bisect):
        mid = 0.5 * (lo + hi)
        r = simulate(placement, device, sched, mid,
                     _sized_queries(query_sizes, mid, sla_ms, seed), seed)
        if r.meets(sla_ms, power_budget_w):
            lo, best = mid, r
        else:
            hi = mid
    return min(lo, bound), best
