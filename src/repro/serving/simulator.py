"""Discrete-event single-server serving simulator.

Models one server executing one recommendation workload under a partition
placement and a scheduling configuration, with the paper's arrival process:
Poisson query arrivals, heavy-tailed query sizes (Fig. 2b). It reproduces
the mechanisms the paper measures:

- CPU pools: ``m`` inference threads × ``o`` operator workers; big queries
  split into sub-queries of <= d items distributed over threads
  (DeepRecSys-style data parallelism); memory-bandwidth contention across
  co-located threads.
- S-D pipeline (cpu_sd): sparse pool -> intermediate queue -> dense pool.
- Accelerator: co-located inference threads (<= max m in flight) pipelining
  through two serialized resources — host link (data loading; the paper's
  Fig. 7 bottleneck) and engine (kernels) — with query fusion up to d items
  per launch. Host-side stage (cold-psum / SparseNet) runs on a host pool.

Outputs: achieved QPS, latency percentiles, component utilizations, and
average/provisioned power via the PowerModel.

Execution engines
-----------------
Every entry point takes ``engine="fast" | "reference" | "event"``:

- ``fast`` (default): array-sweep pipeline — queries are split, mapped to
  duration/byte tables, and reduced back to per-query finish times with
  NumPy; the k-server FIFO recurrence itself runs in
  :mod:`repro.serving.engine`.  Finish times match the reference within
  floating-point reassociation (~1e-12 relative).
- ``reference``: the original per-sub-query ``heapq`` loops, retained
  verbatim as the ground truth for equivalence tests and as the "before"
  engine in ``benchmarks/bench_gradient_search.py``.
- ``event``: the fast pipeline with every k > 1 pool routed through the
  blocked event core (:mod:`repro.serving.event_core`) regardless of
  stream length — bitwise-identical to ``fast`` (the blocked kernel is
  bitwise-equal to the sweep it replaces), it simply forces the new
  path where ``fast`` would auto-dispatch only above a size threshold.

Rate sweeps share work through :class:`SimCache`: the Poisson gap stream is
drawn once at unit rate and rescaled (``exponential(1/r, n)`` is bitwise
``unit_gaps[:n] / r`` for NumPy Generators), the query-size resample is a
prefix of one seed-fixed stream, and splits/duration tables depend only on
the batch size — so every bisection probe of ``max_sustainable_qps`` and
every configuration of a search reuses the same arrays (common random
numbers, which also makes the p95-vs-rate curve monotone in practice).
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from repro.core.devices import DeviceProfile
from repro.core.partition import Placement
from repro.core.perfmodel import (
    PowerModel,
    accel_engine_time,
    accel_link_time,
    cpu_stage_time,
)
from repro.serving.engine import fifo_finish

# Probe sizing for latency-bounded-throughput measurements: span >= ~20 SLA
# windows of queries, floored/capped for statistical quality vs runtime.
_PROBE_FLOOR = 200
_PROBE_CAP = 6000
_FUSE_WINDOW_S = 0.002  # fuse only sub-queries within 2 ms of the group head


@dataclasses.dataclass(frozen=True)
class SchedConfig:
    """One point in the parallelism space P(M+D+O)."""

    batch: int          # d: sub-query size (CPU) / fused launch size (accel)
    m: int              # model-parallelism: CPU threads or accel co-location
    o: int = 1          # op-parallelism: operator workers per CPU thread
    sd_sparse: int = 0  # cpu_sd: threads in the sparse pool (o workers each)
    fuse: bool = True   # accel query fusion (False = DeepRecSys/Baymax mode)


@dataclasses.dataclass
class SimResult:
    qps: float
    p50_ms: float
    p95_ms: float
    p99_ms: float
    avg_power_w: float
    utils: dict
    n_queries: int

    def meets(self, sla_ms: float, power_budget_w: float | None = None) -> bool:
        ok = self.p95_ms <= sla_ms
        if power_budget_w is not None:
            ok = ok and self.avg_power_w <= power_budget_w
        return ok


class _Pool:
    """k-server FIFO resource; returns per-job start times (reference path)."""

    def __init__(self, k: int):
        self.free_at = [0.0] * max(k, 1)

    def schedule(self, ready: float, duration: float) -> tuple[float, float]:
        start, end, _ = self.schedule_idx(ready, duration)
        return start, end

    def schedule_idx(self, ready: float, duration: float) -> tuple[float, float, int]:
        i = int(np.argmin(self.free_at))
        start = max(ready, self.free_at[i])
        self.free_at[i] = start + duration
        return start, start + duration, i

    @property
    def busy_until(self) -> float:
        return max(self.free_at)


def _split_queries(sizes: np.ndarray, arrivals: np.ndarray, d: int):
    """Split each query into sub-batches of <= d items (vectorized).

    Zero-size queries yield no sub-queries (the caller reports them as
    finishing at their arrival); without the ``nz`` mask their remainder
    write would corrupt the preceding query's last sub-batch.

    Returns (sub_arrival, sub_size, query_id) arrays."""
    sizes = np.maximum(np.asarray(sizes).astype(np.int64), 0)
    n_sub = -(-sizes // d)  # ceil
    qid = np.repeat(np.arange(len(sizes)), n_sub)
    sub_a = arrivals[qid]
    sub_s = np.full(len(qid), d, np.int64)
    nz = n_sub > 0
    last = (np.cumsum(n_sub) - 1)[nz]
    sub_s[last] = (sizes - (n_sub - 1) * d)[nz]
    return sub_a, sub_s, qid


# ---------------------------------------------------------------------------
# shared precomputation (CRN probe streams + split/duration/byte tables)
# ---------------------------------------------------------------------------


class _SizeTables:
    """Splits and service-time/byte tables for one concrete query-size
    array.  Sub-query splits are per-query independent, so a probe over the
    first ``n`` queries uses prefixes of the full arrays.  One instance is
    bound to one device (service times are device-dependent)."""

    def __init__(self, sizes: np.ndarray):
        self.sizes = np.maximum(np.asarray(sizes).astype(np.int64), 0)
        self._splits: dict[int, dict] = {}
        self._cpu_t: dict[tuple, dict[int, float]] = {}
        self._cpu_vec: dict[tuple, np.ndarray] = {}
        self._bytes_vec: dict[tuple, np.ndarray] = {}
        self._scalar: dict[tuple, dict[int, float]] = {}

    def split(self, d: int) -> dict:
        sp = self._splits.get(d)
        if sp is None:
            sizes = self.sizes
            n_sub = -(-sizes // d)
            offsets = np.concatenate([[0], np.cumsum(n_sub)])
            qid = np.repeat(np.arange(len(sizes)), n_sub)
            sub_s = np.full(len(qid), d, np.int64)
            nz = n_sub > 0
            sub_s[(offsets[1:] - 1)[nz]] = (sizes - (n_sub - 1) * d)[nz]
            uniq, inv = np.unique(sub_s, return_inverse=True)
            sp = dict(qid=qid, sub_s=sub_s, offsets=offsets, uniq=uniq, inv=inv)
            self._splits[d] = sp
        return sp

    def cpu_durations(self, ops, workers: int, active: int, d: int,
                      device: DeviceProfile) -> np.ndarray:
        """Service seconds aligned with split(d)['uniq']."""
        vkey = (ops, workers, active, d, device.name)
        vec = self._cpu_vec.get(vkey)
        if vec is None:
            tab = self._cpu_t.setdefault((ops, workers, active, device.name), {})
            uniq = self.split(d)["uniq"]
            vec = np.empty(len(uniq))
            for i, b in enumerate(uniq.tolist()):
                t = tab.get(b)
                if t is None:
                    t = tab[b] = cpu_stage_time(ops, b, workers, device, active)
                vec[i] = t
            self._cpu_vec[vkey] = vec
        return vec

    def op_bytes(self, ops, d: int) -> np.ndarray:
        """Memory traffic per sub-batch aligned with split(d)['uniq']."""
        key = (ops, d)
        vec = self._bytes_vec.get(key)
        if vec is None:
            uniq = self.split(d)["uniq"]
            vec = np.array([_items_bytes(ops, int(b)) for b in uniq])
            self._bytes_vec[key] = vec
        return vec

    def scalar(self, key: tuple) -> dict[int, float]:
        """Persistent {batch: value} memo (accel fusion totals)."""
        tab = self._scalar.get(key)
        if tab is None:
            tab = self._scalar[key] = {}
        return tab

    def scalar_vec(self, key: tuple, fn, uniq: np.ndarray) -> np.ndarray:
        """Vector of ``fn(b)`` over the distinct batch sizes ``uniq``,
        memoized under ``key`` (shared by the accel fast path and the
        cluster runtime's per-slot service model)."""
        tab = self.scalar(key)
        return np.array([
            tab.get(b) if b in tab else tab.setdefault(b, fn(b))
            for b in uniq.tolist()
        ])


class SimCache:
    """Common-random-number probe cache for one (query-size distribution,
    seed): the unit-rate Poisson gap stream, the probe-capped query-size
    resample, and the :class:`_SizeTables` over it.  Sharing one instance
    across every bisection probe and every scheduling configuration of a
    search removes all redundant splitting, duration-table construction and
    random-number generation while reproducing the per-probe streams
    bitwise (``exponential(1/r, n) == unit_gaps[:n] * (1/r)`` and
    ``integers(0, L, n)`` is prefix-stable for NumPy Generators)."""

    def __init__(self, query_sizes: np.ndarray, seed: int = 0):
        self.base_sizes = np.asarray(query_sizes)
        self.seed = int(seed)
        self.unit_gaps = np.random.default_rng(seed).exponential(1.0, _PROBE_CAP)
        r = np.random.default_rng(seed + 17)
        self.sized = self.base_sizes[r.integers(0, len(self.base_sizes), _PROBE_CAP)]
        self.tables = _SizeTables(self.sized)

    def ensure(self, n: int) -> None:
        """Grow the cached streams to capacity >= ``n`` (power-of-two
        regrowth).  NumPy ``Generator`` draws are sequential, so redrawing
        a longer stream from the same seeds reproduces the existing prefix
        bitwise — every probe that fit the old capacity sees identical
        arrays after a grow.  Full-interval simulation (the runtime's
        ``event_core`` path) calls this once up front with the day's
        largest interval population, then every window is a prefix."""
        cap = len(self.unit_gaps)
        if n <= cap:
            return
        new = 1 << (int(n) - 1).bit_length()
        self.unit_gaps = np.random.default_rng(self.seed).exponential(1.0, new)
        r = np.random.default_rng(self.seed + 17)
        self.sized = self.base_sizes[r.integers(0, len(self.base_sizes), new)]
        self.tables = _SizeTables(self.sized)


# ---------------------------------------------------------------------------
# simulation entry points
# ---------------------------------------------------------------------------


def simulate(
    placement: Placement,
    device: DeviceProfile,
    sched: SchedConfig,
    arrival_qps: float,
    query_sizes: np.ndarray,
    seed: int = 0,
    engine: str = "fast",
) -> SimResult:
    rng = np.random.default_rng(seed)
    n = len(query_sizes)
    gaps = rng.exponential(1.0 / max(arrival_qps, 1e-9), n)
    arrivals = np.cumsum(gaps)
    tables = _SizeTables(query_sizes) if engine != "reference" else None
    finish, busy = _run_plan(placement, device, sched, arrivals, query_sizes,
                             engine, tables, n)
    return _metrics(finish, arrivals, busy, device, n)


def simulate_rates(
    placement: Placement,
    device: DeviceProfile,
    sched: SchedConfig,
    rates,
    sla_ms: float,
    query_sizes: np.ndarray,
    seed: int = 0,
    cache: SimCache | None = None,
    engine: str = "fast",
) -> list[SimResult]:
    """Simulate one configuration at several arrival rates, sharing the
    split sub-query arrays, duration tables and common random numbers
    across all rates (each rate reproduces ``simulate`` at that rate)."""
    cache = _checked_cache(cache, query_sizes, seed)
    return [
        _probe(placement, device, sched, float(r), sla_ms, cache, engine)
        for r in rates
    ]


def _checked_cache(cache, query_sizes, seed) -> SimCache:
    """A supplied cache must have been built from the same streams it is
    asked to reproduce — a mismatch would silently change results."""
    if cache is None:
        return SimCache(query_sizes, seed)
    if cache.seed != int(seed) or not np.array_equal(cache.base_sizes,
                                                     query_sizes):
        raise ValueError(
            "SimCache was built for different (query_sizes, seed) than this "
            "call; build one SimCache per (size sample, seed) pair")
    return cache


def _probe(placement, device, sched, rate, sla_ms, cache, engine) -> SimResult:
    duration = max(0.3, 20.0 * sla_ms * 1e-3)
    n = int(np.clip(rate * duration, _PROBE_FLOOR, _PROBE_CAP))
    arrivals = np.cumsum(cache.unit_gaps[:n] * (1.0 / max(rate, 1e-9)))
    sizes = cache.sized[:n]
    tables = cache.tables if engine != "reference" else None
    finish, busy = _run_plan(placement, device, sched, arrivals, sizes,
                             engine, tables, n)
    return _metrics(finish, arrivals, busy, device, n)


def _metrics(finish, arrivals, busy, device, n) -> SimResult:
    latency_ms = (finish - arrivals) * 1e3
    span = max(finish.max() - arrivals[0], 1e-9)
    utils = {
        "cores": min(busy["cores"] / (span * device.cpu.cores), 1.0),
        "mem": min(busy["mem_bytes"] / (span * device.mem.bw_gbs * 1e9), 1.0),
        "engine": min(busy["engine"] / span, 1.0) if device.accel else 0.0,
        "link": min(busy["link"] / span, 1.0) if device.accel else 0.0,
    }
    power = PowerModel(device).average_power(utils)
    p50, p95, p99 = np.percentile(latency_ms, (50, 95, 99))
    return SimResult(
        qps=n / span,
        p50_ms=float(p50),
        p95_ms=float(p95),
        p99_ms=float(p99),
        avg_power_w=power,
        utils=utils,
        n_queries=n,
    )


def _run_plan(placement, device, sched, arrivals, sizes, engine, tables, n):
    busy = {"cores": 0.0, "mem_bytes": 0.0, "engine": 0.0, "link": 0.0}
    blk = True if engine == "event" else None
    if engine == "reference" or tables is None:
        if placement.plan == "cpu_model":
            finish = _sim_cpu_model(placement, device, sched, arrivals, sizes, busy)
        elif placement.plan == "cpu_sd":
            finish = _sim_cpu_sd(placement, device, sched, arrivals, sizes, busy)
        else:
            finish = _sim_accel(placement, device, sched, arrivals, sizes, busy)
        empty = np.asarray(sizes) <= 0
        if empty.any():  # zero-size queries finish at arrival (no work)
            finish = np.where(empty, arrivals, finish)
    elif placement.plan == "cpu_model":
        finish = _fast_cpu_model(placement, device, sched, arrivals, busy,
                                 tables, n, blocked=blk)
    elif placement.plan == "cpu_sd":
        finish = _fast_cpu_sd(placement, device, sched, arrivals, busy,
                              tables, n, blocked=blk)
    else:
        finish = _fast_accel(placement, device, sched, arrivals, busy,
                             tables, n, blocked=blk)
    return finish, busy


def _items_bytes(ops, batch):
    return sum(
        (op.stream_bytes + op.gather_bytes) * batch + op.weight_bytes for op in ops
    )


# ---------------------------------------------------------------------------
# fast path: array sweeps around the k-server FIFO engine
# ---------------------------------------------------------------------------


def _finish_per_query(ends, offsets, n, arrivals):
    """Per-query max over its sub-query ends; empty queries finish at
    arrival.  Sub-queries stay grouped by query in original order."""
    counts = np.diff(offsets[: n + 1])
    finish = np.array(arrivals, dtype=np.float64, copy=True)
    nz = counts > 0
    if nz.any():
        finish[nz] = np.maximum.reduceat(ends, offsets[:n][nz])
    return finish


def _sub_order(sub_a):
    """Processing order of sub-queries (arrival order).  Probe arrivals are
    already sorted (cumsum of non-negative gaps indexed by sorted qid), so
    this is almost always the identity."""
    if len(sub_a) and np.any(np.diff(sub_a) < 0):
        return np.argsort(sub_a, kind="stable")
    return None


def _fast_cpu_model(placement, device, sched, arrivals, busy, tables, n,
                    blocked=None):
    """m threads × o workers; shared sub-query FIFO."""
    d = max(sched.batch, 1)
    sp = tables.split(d)
    ns = int(sp["offsets"][n])
    inv = sp["inv"][:ns]
    sub_a = arrivals[sp["qid"][:ns]]
    dv = tables.cpu_durations(placement.host_ops, sched.o, sched.m, d, device)[inv]
    order = _sub_order(sub_a)
    if order is None:
        ends = fifo_finish(sub_a, dv, sched.m, blocked=blocked)
    else:
        ends = np.empty(ns)
        ends[order] = fifo_finish(sub_a[order], dv[order], sched.m,
                                  blocked=blocked)
    busy["cores"] += float(dv.sum()) * sched.o
    busy["mem_bytes"] += float(tables.op_bytes(placement.host_ops, d)[inv].sum())
    return _finish_per_query(ends, sp["offsets"], n, arrivals)


def _fast_cpu_sd(placement, device, sched, arrivals, busy, tables, n,
                 blocked=None):
    """Sparse pool (sd_sparse × o) -> dense pool (m × 1); dense jobs are
    processed in sub-query arrival order with ready = sparse finish."""
    d = max(sched.batch, 1)
    m_sparse = max(sched.sd_sparse, 1)
    m_dense = max(sched.m, 1)
    sp = tables.split(d)
    ns = int(sp["offsets"][n])
    inv = sp["inv"][:ns]
    sub_a = arrivals[sp["qid"][:ns]]
    ts = tables.cpu_durations(placement.host_sparse, sched.o, m_sparse, d, device)[inv]
    td = tables.cpu_durations(placement.host_dense, 1, m_dense, d, device)[inv]
    order = _sub_order(sub_a)
    if order is None:
        s_end = fifo_finish(sub_a, ts, m_sparse, blocked=blocked)
        ends = fifo_finish(s_end, td, m_dense, blocked=blocked)
    else:
        s_end = fifo_finish(sub_a[order], ts[order], m_sparse,
                            blocked=blocked)
        ends = np.empty(ns)
        ends[order] = fifo_finish(s_end, td[order], m_dense,
                                  blocked=blocked)
    busy["cores"] += float(ts.sum()) * sched.o + float(td.sum())
    busy["mem_bytes"] += float(tables.op_bytes(placement.host_ops, d)[inv].sum())
    return _finish_per_query(ends, sp["offsets"], n, arrivals)


def _fusion_groups(sub_a, sub_s, d, fuse):
    """Greedy fusion boundaries (identical to the reference walk): pack
    consecutive arrival-sorted sub-queries while the fused launch stays
    <= d items and the arrival gap from the group head stays <= 2 ms.
    Returns (group start indices, fused item totals)."""
    ns = len(sub_a)
    cs = np.concatenate([[0], np.cumsum(sub_s)])
    if not fuse:
        return np.arange(ns), sub_s.astype(np.int64)
    idx = np.arange(ns)
    max_w = np.searchsorted(sub_a, sub_a + _FUSE_WINDOW_S, side="right") - idx
    max_s = np.searchsorted(cs, cs[:-1] + d, side="right") - 1 - idx
    lim = np.maximum(np.minimum(max_w, max_s), 1).tolist()
    starts: list[int] = []
    append = starts.append
    pos = 0
    while pos < ns:
        append(pos)
        pos += lim[pos]
    starts = np.asarray(starts, np.int64)
    totals = cs[np.append(starts[1:], ns)] - cs[starts]
    return starts, totals


def _accel_pipeline(ready, tl, te, m, colo0=None, link0=0.0, eng0=0.0,
                    return_state=False):
    """Fused launches through admission (earliest of m co-location slots,
    held until engine completion) -> serialized link -> serialized engine.

    ``colo0``/``link0``/``eng0`` seed the resources' initial free times (a
    continuous-time caller's carried backlog; defaults reproduce the idle
    start bit-for-bit).  With ``return_state`` the end state
    ``(colo free times sorted, link_free, eng_free)`` is returned too."""
    colo = [0.0] * max(m, 1) if colo0 is None else \
        np.asarray(colo0, dtype=np.float64).tolist()
    heapq.heapify(colo)
    replace = heapq.heapreplace
    link_free = float(link0)
    eng_free = float(eng0)
    out: list[float] = []
    append = out.append
    for r, l, t in zip(ready.tolist(), tl.tolist(), te.tolist()):
        s = colo[0]
        if r > s:
            s = r
        l_end = (s if s > link_free else link_free) + l
        e_end = (l_end if l_end > eng_free else eng_free) + t
        link_free = l_end
        eng_free = e_end
        replace(colo, e_end)
        append(e_end)
    if return_state:
        return np.asarray(out), (np.sort(colo), link_free, eng_free)
    return np.asarray(out)


def _fast_accel(placement, device, sched, arrivals, busy, tables, n,
                blocked=None):
    """Host stage pool -> link -> engine, with m-way co-location and query
    fusion; all duration/byte lookups are table sweeps over fused totals.
    The admission/link/engine pipeline itself stays scalar — it is three
    coupled resources, not a k-server pool (see docs/cluster_serving.md)."""
    host_ops = placement.host_ops
    o = max(sched.o, 1)
    host_threads = max(device.cpu.cores // o, 1)
    d = max(sched.batch, 1)
    sp = tables.split(d)
    ns = int(sp["offsets"][n])
    sub_a = arrivals[sp["qid"][:ns]]
    sub_s = sp["sub_s"][:ns]
    order = _sub_order(sub_a)
    if order is not None:
        sub_a, sub_s = sub_a[order], sub_s[order]
    starts, totals = _fusion_groups(sub_a, sub_s, d, sched.fuse)
    bounds = np.append(starts, ns)
    ready = sub_a[bounds[1:] - 1]  # group ready = last (max) member arrival
    uniq_t, inv_t = np.unique(totals, return_inverse=True)

    def table(key, fn):
        return tables.scalar_vec(key, fn, uniq_t)

    if host_ops:
        th_u = table(("cpu_stage", host_ops, o, host_threads, device.name),
                     lambda b: cpu_stage_time(host_ops, b, o, device, host_threads))
        th = th_u[inv_t]
        ready = fifo_finish(ready, th, host_threads, blocked=blocked)
        busy["cores"] += float(th.sum()) * o
        by_u = table(("items_bytes", host_ops), lambda b: _items_bytes(host_ops, b))
        busy["mem_bytes"] += float(by_u[inv_t].sum())
    te = table(("accel_engine", placement.accel_ops, device.name),
               lambda b: accel_engine_time(placement.accel_ops, b, device))[inv_t]
    tl = table(("accel_link", placement.link_bytes_per_item, device.name),
               lambda b: accel_link_time(placement.link_bytes_per_item, b, device))[inv_t]
    e_end = _accel_pipeline(ready, tl, te, sched.m)
    busy["link"] += float(tl.sum())
    busy["engine"] += float(te.sum())
    ends = np.repeat(e_end, np.diff(bounds))
    if order is not None:
        unsorted = np.empty(ns)
        unsorted[order] = ends
        ends = unsorted
    return _finish_per_query(ends, sp["offsets"], n, arrivals)


# ---------------------------------------------------------------------------
# reference path: the original per-sub-query heapq loops (slow ground truth)
# ---------------------------------------------------------------------------


def _duration_table(ops, workers, device, active, sub_s):
    """Memoized service times for the distinct sub-batch sizes."""
    return {
        int(b): cpu_stage_time(ops, int(b), workers, device, active)
        for b in np.unique(sub_s)
    }


def _sim_cpu_model(placement, device, sched, arrivals, sizes, busy):
    """m threads × o workers; shared sub-query FIFO (heap of free times)."""
    ops = placement.host_ops
    sub_a, sub_s, qid = _split_queries(sizes, arrivals, sched.batch)
    durs = _duration_table(ops, sched.o, device, sched.m, sub_s)
    bts = {b: _items_bytes(ops, b) for b in durs}
    free = [0.0] * max(sched.m, 1)
    heapq.heapify(free)
    finish = np.zeros(len(sizes))
    order = np.argsort(sub_a, kind="stable")
    for j in order:
        b = int(sub_s[j])
        t = durs[b]
        start = max(sub_a[j], heapq.heappop(free))
        end = start + t
        heapq.heappush(free, end)
        if end > finish[qid[j]]:
            finish[qid[j]] = end
        busy["cores"] += t * sched.o
        busy["mem_bytes"] += bts[b]
    return finish


def _sim_cpu_sd(placement, device, sched, arrivals, sizes, busy):
    """Sparse pool (sd_sparse threads × o workers) -> dense pool (m × 1).

    Bandwidth/LLC contention is per-pool: the dedicated sparse pool contends
    only with itself — the S-D partition's core advantage."""
    m_sparse = max(sched.sd_sparse, 1)
    m_dense = max(sched.m, 1)
    sub_a, sub_s, qid = _split_queries(sizes, arrivals, sched.batch)
    durs_s = _duration_table(placement.host_sparse, sched.o, device, m_sparse, sub_s)
    durs_d = _duration_table(placement.host_dense, 1, device, m_dense, sub_s)
    bts = {b: _items_bytes(placement.host_ops, b) for b in durs_s}
    free_s = [0.0] * m_sparse
    free_d = [0.0] * m_dense
    heapq.heapify(free_s)
    heapq.heapify(free_d)
    finish = np.zeros(len(sizes))
    order = np.argsort(sub_a, kind="stable")
    for j in order:
        b = int(sub_s[j])
        ts, td = durs_s[b], durs_d[b]
        s_start = max(sub_a[j], heapq.heappop(free_s))
        s_end = s_start + ts
        heapq.heappush(free_s, s_end)
        d_start = max(s_end, heapq.heappop(free_d))
        d_end = d_start + td
        heapq.heappush(free_d, d_end)
        if d_end > finish[qid[j]]:
            finish[qid[j]] = d_end
        busy["cores"] += ts * sched.o + td
        busy["mem_bytes"] += bts[b]
    return finish


def _sim_accel(placement, device, sched, arrivals, sizes, busy):
    """Host stage pool -> link -> engine, with m-way co-location and
    query fusion up to d items per launch."""
    cores = device.cpu.cores
    host_ops = placement.host_ops
    # host pool: remaining cores as sparse threads with o workers each
    host_threads = max(cores // max(sched.o, 1), 1)
    host_pool = _Pool(host_threads)
    link = _Pool(1)
    engine = _Pool(1)
    colocate = _Pool(max(sched.m, 1))  # admission: <= m fused launches in flight

    d = max(sched.batch, 1)
    sub_a, sub_s, qid = _split_queries(sizes, arrivals, d)
    order = np.argsort(sub_a, kind="stable")
    finish = np.zeros(len(sizes))

    # Greedy fusion: walk sub-queries in arrival order, fuse consecutive
    # sub-queries into one launch while total items <= d.
    host_durs: dict[int, float] = {}
    eng_durs: dict[int, float] = {}
    link_durs: dict[int, float] = {}

    def _host_t(b):
        if b not in host_durs:
            host_durs[b] = cpu_stage_time(host_ops, b, sched.o, device, host_threads)
        return host_durs[b]

    def _eng_t(b):
        if b not in eng_durs:
            eng_durs[b] = accel_engine_time(placement.accel_ops, b, device)
        return eng_durs[b]

    def _link_t(b):
        if b not in link_durs:
            link_durs[b] = accel_link_time(placement.link_bytes_per_item, b, device)
        return link_durs[b]

    i = 0
    idx = order.tolist()
    while i < len(idx):
        batch_ids = [idx[i]]
        total = int(sub_s[idx[i]])
        i += 1
        while sched.fuse and i < len(idx) and total + int(sub_s[idx[i]]) <= d:
            # fuse only queries that have already arrived by the time the
            # first arrived (no artificial waiting -> no added queuing delay)
            if sub_a[idx[i]] - sub_a[batch_ids[0]] > _FUSE_WINDOW_S:
                break
            batch_ids.append(idx[i])
            total += int(sub_s[idx[i]])
            i += 1
        ready = max(sub_a[j] for j in batch_ids)
        if host_ops:
            th = _host_t(total)
            _, ready = host_pool.schedule(ready, th)
            busy["cores"] += th * sched.o
            busy["mem_bytes"] += _items_bytes(host_ops, total)
        # admission slot (co-location degree): holds until engine completes
        slot_start, _, slot = colocate.schedule_idx(ready, 0.0)
        tl = _link_t(total)
        _, l_end = link.schedule(slot_start, tl)
        te = _eng_t(total)
        _, e_end = engine.schedule(l_end, te)
        busy["link"] += tl
        busy["engine"] += te
        colocate.free_at[slot] = e_end
        for j in batch_ids:
            finish[qid[j]] = max(finish[qid[j]], e_end)
    return finish


# ---------------------------------------------------------------------------
# latency-bounded throughput
# ---------------------------------------------------------------------------


def capacity_bound_qps(
    placement: Placement,
    device: DeviceProfile,
    sched: SchedConfig,
    mean_query_size: float,
) -> float:
    """Analytic steady-state throughput ceiling (items/s across the binding
    resource, converted to queries/s). Brackets the bisection so the sim is
    never asked to 'sustain' a rate it only drains as a burst."""
    d = max(sched.batch, 1)
    caps = []
    if placement.plan in ("cpu_model", "cpu_sd"):
        if placement.plan == "cpu_model":
            t = cpu_stage_time(placement.host_ops, d, sched.o, device, sched.m)
            caps.append(sched.m * d / max(t, 1e-12))
        else:
            m_s, m_d = max(sched.sd_sparse, 1), max(sched.m, 1)
            ts = cpu_stage_time(placement.host_sparse, d, sched.o, device, m_s)
            td = cpu_stage_time(placement.host_dense, d, 1, device, m_d)
            caps.append(m_s * d / max(ts, 1e-12))
            caps.append(m_d * d / max(td, 1e-12))
    else:
        if placement.host_ops:
            ht = max(device.cpu.cores // max(sched.o, 1), 1)
            th = cpu_stage_time(placement.host_ops, d, sched.o, device, ht)
            caps.append(ht * d / max(th, 1e-12))
        tl = accel_link_time(placement.link_bytes_per_item, d, device)
        te = accel_engine_time(placement.accel_ops, d, device)
        caps.append(d / max(tl, 1e-12))
        caps.append(d / max(te, 1e-12))
    return min(caps) / max(mean_query_size, 1.0)


def _sized_queries(base_sizes: np.ndarray, rate: float, sla_ms: float, seed: int):
    """Resample query sizes so the sim spans >= ~20 SLA windows (steady
    state), capped for runtime. Above the cap the run is burst-shaped; the
    analytic capacity bound caps the reported throughput instead.

    Kept for compatibility: probes now slice the equivalent prefix out of
    :class:`SimCache` instead of re-drawing per rate."""
    duration = max(0.3, 20.0 * sla_ms * 1e-3)
    n = int(np.clip(rate * duration, _PROBE_FLOOR, _PROBE_CAP))
    rng = np.random.default_rng(seed + 17)
    return base_sizes[rng.integers(0, len(base_sizes), n)]


def max_sustainable_qps(
    placement: Placement,
    device: DeviceProfile,
    sched: SchedConfig,
    sla_ms: float,
    query_sizes: np.ndarray,
    power_budget_w: float | None = None,
    seed: int = 0,
    n_bisect: int = 7,
    cache: SimCache | None = None,
    engine: str = "fast",
    qps_tol: float = 0.0,
) -> tuple[float, SimResult | None]:
    """Latency-bounded throughput: max Poisson rate with p95 <= SLA.

    All probes share ``cache`` (CRN), so the p95-vs-rate curve is sampled
    on one noise realization and the bisection bracket is monotone in
    practice; ``qps_tol > 0`` stops early once the bracket is within that
    relative tolerance of the answer (fewer probes at bounded error).
    """
    mean_size = float(np.mean(query_sizes))
    bound = capacity_bound_qps(placement, device, sched, mean_size)
    if bound <= 0:
        return 0.0, None
    cache = _checked_cache(cache, query_sizes, seed)
    lo, hi = 0.0, bound * 1.25
    best: SimResult | None = None
    r = _probe(placement, device, sched, hi, sla_ms, cache, engine)
    if r.meets(sla_ms, power_budget_w):
        # capacity-bound regime: report the analytic ceiling, never more
        return bound, r
    for _ in range(n_bisect):
        if qps_tol > 0.0 and (hi - lo) <= qps_tol * hi:
            break
        mid = 0.5 * (lo + hi)
        r = _probe(placement, device, sched, mid, sla_ms, cache, engine)
        if r.meets(sla_ms, power_budget_w):
            lo, best = mid, r
        else:
            hi = mid
    return min(lo, bound), best
