"""Batched event-ordered serving core (ROADMAP item 4).

``engine._sweep`` solves the k-server earliest-free FIFO recurrence one
heap op per job — exact, but interpreter-bound at the 1e5–1e6 job counts
a full 86400 s day produces.  This module attacks that cost twice, both
times **bitwise-equal to the scalar sweep**:

1.  :func:`blocked_fifo_finish` — a single-stream blocked kernel built on
    speculate-and-verify.  One structural fact makes cheap verification
    possible: in the true run the popped server-free times are
    non-decreasing and are exactly the B smallest elements of
    ``free0 ∪ ends`` (each end is pushed once, pops only grow), so the
    whole pop sequence is ``sorted(free0 ∪ ends)[:B]`` and the end state
    is the k largest.  A candidate ``ends`` vector produced any way at
    all is *the* solution iff it is consistent with its own pop sequence
    bitwise and every pop drawn from ``ends`` comes from an earlier job.
    Two regimes verify in O(B log B) with tiny constants:

    - **light** (every job finds a free server): candidate
      ``ready + dur``; for sorted arrivals and strictly positive
      durations the single check ``sorted(free0 ∪ ends)[:B] <= ready``
      certifies both consistency and availability;
    - **saturated** (no job ever finds a free server, near-constant
      durations): candidate from a round-robin column fold
      (``np.add.accumulate`` down a ``[G, k]`` duration matrix — the
      exact adds the scalar sweep performs), verified by pop
      monotonicity plus ``ready <= pops``;
    - anything else falls back to ``engine._sweep`` for that block, so
      correctness never depends on speculation succeeding.  (A general
      fixpoint iteration over the claimed pop structure was prototyped
      and measured: convergence is linear — ~50 resolved positions per
      round — because beyond-frontier structure is chaotic in busy
      regimes.  It was dropped; failed-speculation overhead is now
      ~15 ns/job against the sweep's ~250 ns/job.)

2.  :func:`fleet_fifo_finish` — the headline batched path.  A full-day
    interval does not produce one million-job stream; it produces
    hundreds of *independent* per-slot streams (profiling sweeps:
    ~10⁴ calls, k mostly 2–10).  The recurrence is sequential per
    stream but embarrassingly parallel across streams, so the fleet
    kernel transposes the problem: one time-step loop advances S
    streams at once against an ``[S, K]`` server-free matrix.  Per step:
    ``argmin`` row-wise, gather, ``where``-max, add, and a one-hot
    masked write-back (an arithmetic select — XLA's scatter lowers to a
    serial loop on CPU and is ~7x slower).  The jitted ``lax.scan``
    amortizes all per-op overhead across rows: measured ~25 ns/job at
    k=8 against the sweep's ~250 ns/job, holding from S=32 to S=1024
    and at 10⁶ total jobs.  Streams are grouped by k (pool slot groups
    are k-homogeneous) and padded to shape buckets so XLA recompiles
    O(log) times, not per call.

Floating point (why bitwise equality is possible): the per-step min over
k server-free times is an exact associative reduction, each finish time
is one ``max`` and one ``+`` on the same operands the sweep uses, and an
``argmin`` tie picks a *slot*, never a value — the free-time multiset is
identical either way, and the end state is compared sorted.  Only the
k == 1 Lindley closed form in ``engine`` reassociates; nothing here does.

Determinism: simulated path (see ``repro.analysis``) — no RNG, no wall
clocks; all state is threaded explicitly.  The optional JAX path runs
under a scoped ``enable_x64`` so it is float64 end-to-end regardless of
the process-wide JAX default.
"""
from __future__ import annotations

import numpy as np

from repro.serving.engine import _sweep

_DEFAULT_BLOCK = 8192
# fleet batching only pays when the step loop advances several jobs at
# once; below this effective width the sequential sweep is already fine
_MIN_FLEET_WIDTH = 4

# per-call path mix (benchmarks report these; tests reset via conftest)
stats = {
    "light": 0, "saturated": 0, "fallback": 0, "blocks": 0, "calls": 0,
    "fleet_calls": 0, "fleet_groups": 0, "fleet_jobs": 0,
    "fleet_jax": 0, "fleet_seq": 0,
}


def stats_reset() -> None:
    for key in stats:
        stats[key] = 0


# ---------------------------------------------------------------------------
# single-stream blocked kernel
# ---------------------------------------------------------------------------

def blocked_fifo_finish(
    ready: np.ndarray, dur: np.ndarray, k: int,
    free0: np.ndarray | None = None, block: int = _DEFAULT_BLOCK,
    return_state: bool = False,
):
    """Bitwise drop-in for ``engine._sweep``: finish times of jobs served
    FIFO (array order) by the earliest-free of ``k`` servers, solved in
    blocks of ``block`` jobs with the k-vector free state carried across
    seams.  With ``return_state`` also returns the k server free times
    after the last job, sorted ascending (same as ``_sweep``'s
    ``np.sort(free)``)."""
    ready = np.ascontiguousarray(ready, dtype=np.float64)
    dur = np.ascontiguousarray(dur, dtype=np.float64)
    n = ready.shape[0]
    k = max(int(k), 1)
    h = np.zeros(k) if free0 is None else \
        np.sort(np.asarray(free0, dtype=np.float64))
    if n == 0:
        return (np.zeros(0), h) if return_state else np.zeros(0)
    stats["calls"] += 1
    block = max(int(block), 1)
    ends = np.empty(n)
    for start in range(0, n, block):
        stop = min(start + block, n)
        e_blk, h = _solve_block(ready[start:stop], dur[start:stop], h, k)
        ends[start:stop] = e_blk
    return (ends, h) if return_state else ends


def _solve_block(r, d, h, k):
    """One block against the sorted free-state ``h``; returns
    ``(ends, next_h)`` with ``next_h`` sorted ascending."""
    stats["blocks"] += 1
    B = r.shape[0]
    d_min = float(d.min())
    if d_min > 0.0 and (B == 1 or bool(np.all(r[1:] >= r[:-1]))):
        out = _try_light(r, d, h)
        if out is not None:
            stats["light"] += 1
            return out
    out = _try_saturated(r, d, h, k)
    if out is not None:
        stats["saturated"] += 1
        return out
    stats["fallback"] += 1
    return _sweep(r, d, k, free0=h, return_state=True)


def _try_light(r, d, h):
    """All-idle speculation for sorted arrivals with positive durations.

    Hypothesis: every job starts at its arrival, ``e = r + d``.  The pop
    sequence is then the B smallest of ``h ∪ e``; the hypothesis holds
    iff every pop value is ``<= r_t``.  Availability is automatic: a pop
    sourced from ``e_j`` has ``e_j <= r_t`` and ``e_j = r_j + d_j > r_j``
    (durations strictly positive), so ``r_j < r_t`` and — arrivals
    sorted — ``j < t``.  One concatenate + one sort, ~8 ns/job."""
    B = r.shape[0]
    e = r + d
    merged = np.sort(np.concatenate([h, e]))
    if not bool(np.all(merged[:B] <= r)):
        return None
    return e, merged[B:].copy()


def _try_saturated(r, d, h, k):
    """Round-robin speculation for the always-busy regime.

    Hypothesis: no job ever finds a free server, so job ``t`` pops the
    end of job ``t - k`` on the same "column" (or ``h_sorted[t]`` for the
    first k) and ``e_t = pop_t + d_t``.  Column ends are one
    ``np.add.accumulate`` down a ``[G, k]`` duration matrix — the exact
    adds the scalar sweep performs.  Sufficient check: the claimed pop
    sequence (extended k-1 steps past the block, i.e. each column's
    next pop) is non-decreasing — then the heap at step t is exactly the
    next k claimed pops and its min is pop_t — and ``r <= pops`` so no
    job is idle.  The k pops just past the block are the end state.
    Holds for near-constant durations under overload; mixed durations
    unbalance the columns and the check rejects."""
    B = r.shape[0]
    G = -(-B // k)
    pad = G * k - B
    D = d if pad == 0 else np.concatenate([d, np.zeros(pad)])
    E = np.add.accumulate(np.vstack([h, D.reshape(G, k)]), axis=0)
    pops = E[:-1].ravel()
    p = pops[:B]
    if not np.all(r <= p):
        return None
    rem = B % k
    tail = E[-1] if rem == 0 else E[-1, :rem]
    q = np.concatenate([pops, tail])          # claimed pops 0 .. B+k-1
    qq = q[:B + k - 1]
    if not np.all(qq[1:] >= qq[:-1]):
        return None
    e = E[1:].ravel()[:B]
    return e, np.sort(q[B:B + k])


# ---------------------------------------------------------------------------
# fleet kernel — S independent streams in one transposed time-step loop
# ---------------------------------------------------------------------------

_fleet_scan = None  # lazily-built jitted scan (None until first use)
_jax = None


def _load_jax():
    """Import jax once; build the jitted fleet scan.  Returns False when
    jax is unavailable (the fleet then runs streams sequentially)."""
    global _fleet_scan, _jax
    if _fleet_scan is not None:
        return True
    if _jax is False:
        return False
    try:
        import jax
        import jax.numpy as jnp
        from jax import lax
    except Exception:  # pragma: no cover - jax ships with the container
        _jax = False
        return False
    _jax = jax

    @jax.jit
    def fleet_scan(W0, RT, DT, ACT):
        rows = jnp.arange(W0.shape[0])
        cols = jnp.arange(W0.shape[1])

        def step(W, inp):
            r, d, act = inp
            am = W.argmin(axis=1)
            f = W[rows, am]
            e = jnp.where(r > f, r, f) + d
            hit = (am[:, None] == cols[None, :]) & act[:, None]
            W = jnp.where(hit, e[:, None], W)
            return W, e

        return lax.scan(step, W0, (RT, DT, ACT))

    _fleet_scan = fleet_scan
    return True


def _pow2_at_least(x: int, floor: int) -> int:
    x = max(int(x), floor)
    return 1 << (x - 1).bit_length()


def fleet_fifo_finish(streams, use_jax: bool | None = None):
    """Solve many independent k-server FIFO streams at once.

    ``streams`` is a sequence of ``(ready, dur, k)`` or
    ``(ready, dur, k, free0)`` tuples — one per pool slot.  Returns a
    list of ``(ends, state)`` pairs aligned with the input, each
    bitwise-equal to ``engine._sweep(ready, dur, k, free0,
    return_state=True)``.

    Streams are grouped by ``k`` (slot groups of one pool config share
    k, so real batches are already homogeneous) and each group runs as
    one jitted ``lax.scan`` over time steps with an ``[S, K]``
    server-free matrix.  Shapes are padded to power-of-two buckets so
    the XLA compile cache stays O(log) in batch geometry.  Groups too
    narrow to amortize the step loop — and everything when jax is
    unavailable or ``use_jax=False`` — run sequentially through the
    scalar sweep instead (same results, status-quo speed).
    """
    items = []
    for s in streams:
        r, d, k = s[0], s[1], int(s[2])
        f0 = s[3] if len(s) > 3 else None
        items.append((np.ascontiguousarray(r, dtype=np.float64),
                      np.ascontiguousarray(d, dtype=np.float64),
                      max(k, 1),
                      None if f0 is None else
                      np.asarray(f0, dtype=np.float64)))
    out: list = [None] * len(items)
    if not items:
        return out
    stats["fleet_calls"] += 1
    stats["fleet_jobs"] += sum(it[0].shape[0] for it in items)
    have_jax = (use_jax is not False) and _load_jax()
    if use_jax is True and not have_jax:
        raise RuntimeError("fleet_fifo_finish(use_jax=True): jax unavailable")

    by_k: dict[int, list[int]] = {}
    for i, it in enumerate(items):
        by_k.setdefault(it[2], []).append(i)

    for k, idxs in sorted(by_k.items()):
        ns = [items[i][0].shape[0] for i in idxs]
        n_max = max(ns)
        # effective width: jobs advanced per step across the group
        wide = n_max > 0 and sum(ns) / n_max >= _MIN_FLEET_WIDTH
        if have_jax and wide:
            stats["fleet_groups"] += 1
            stats["fleet_jax"] += len(idxs)
            _run_fleet_group(items, idxs, k, n_max, out)
        else:
            stats["fleet_seq"] += len(idxs)
            for i in idxs:
                r, d, kk, f0 = items[i]
                out[i] = _sweep(r, d, kk, free0=f0, return_state=True)
    return out


def _run_fleet_group(items, idxs, k, n_max, out):
    """One k-homogeneous group through the jitted scan."""
    S = len(idxs)
    S_pad = _pow2_at_least(S, 8)
    N_pad = _pow2_at_least(n_max, 16)
    RT = np.zeros((N_pad, S_pad))
    DT = np.zeros((N_pad, S_pad))
    ACT = np.zeros((N_pad, S_pad), dtype=bool)
    # dummy rows stay all-inf: argmin hits slot 0, the masked write-back
    # never lands, and inf + 0.0 is inf (no NaNs)
    W0 = np.full((S_pad, k), np.inf)
    for j, i in enumerate(idxs):
        r, d, _, f0 = items[i]
        n = r.shape[0]
        RT[:n, j] = r
        DT[:n, j] = d
        ACT[:n, j] = True
        W0[j, :] = 0.0 if f0 is None else f0
    jax = _jax
    with jax.experimental.enable_x64():
        Wf, E = _fleet_scan(W0, RT, DT, ACT)
        Wf = np.asarray(Wf)
        E = np.asarray(E)
    for j, i in enumerate(idxs):
        n = items[i][0].shape[0]
        out[i] = (E[:n, j].copy(), np.sort(Wf[j]))


def merge_event_streams(*streams: np.ndarray):
    """Stable event-ordered merge of per-source time arrays.

    Returns ``(times, order)`` where ``order`` indexes the concatenation
    of the inputs and ``times = concat(streams)[order]`` is sorted
    ascending with ties broken by source order then in-source order —
    the deterministic tie-break the runtime's hedge-admission pass
    relies on (primaries before duplicates at equal timestamps)."""
    cat = np.concatenate([np.asarray(s, dtype=np.float64) for s in streams])
    order = np.argsort(cat, kind="stable")
    return cat[order], order
