"""Diurnal load traces (paper Fig. 2d / Fig. 8b).

Synchronous day-night pattern with a morning shoulder and an evening peak,
plus Poisson-ish jitter; all services peak at similar times (the paper's
key observation — synchronized peaks force worst-case provisioning).
"""
from __future__ import annotations

import numpy as np


def diurnal_trace(
    peak_qps: float,
    n_steps: int = 144,            # 24h at 10-minute provisioning intervals
    valley_frac: float = 0.45,     # >50% peak-to-valley fluctuation (paper)
    peak_hour: float = 20.0,
    shoulder_hour: float = 11.0,
    jitter: float = 0.02,
    seed: int = 0,
) -> np.ndarray:
    rng = np.random.default_rng(seed)
    t = np.linspace(0.0, 24.0, n_steps, endpoint=False)
    main = np.exp(-0.5 * ((t - peak_hour) / 3.5) ** 2)
    shoulder = 0.7 * np.exp(-0.5 * ((t - shoulder_hour) / 3.0) ** 2)
    base = valley_frac + (1.0 - valley_frac) * np.maximum(main, shoulder)
    noise = 1.0 + jitter * rng.standard_normal(n_steps)
    return np.clip(peak_qps * base * noise, 0.0, None)


def load_increment_rate(trace: np.ndarray) -> float:
    """Max step-to-step relative increase — the paper's estimate for the
    over-provision rate R (load growth within one provisioning interval)."""
    prev = np.maximum(trace[:-1], 1e-9)
    return float(np.max((trace[1:] - trace[:-1]) / prev).clip(0.0, 1.0))
