"""Declarative serving scenarios: the whole scenario zoo as data.

Every serving scenario the repo evaluates — diurnal baseline days, days
with mid-peak machine failures, flash crowds, phase-shifted regions,
model pushes, hedge storms — used to be hand-wired imperatively in
``benchmarks/bench_cluster.py`` and ``examples/cluster_day.py``.  This
module turns each one into a declaration:

- :class:`WorkloadSpec` — one workload's arrival curve, layered on
  :func:`repro.serving.diurnal.diurnal_trace`: a load fraction of the
  fleet's best-case capacity (:meth:`EfficiencyTable.fleet_capacity`),
  a CRN trace seed, and the curve-shape knobs (peak/shoulder hours for
  phase-shifted regions, valley fraction, jitter);
- :class:`Event` — one typed timeline event, validated against the
  :data:`EVENT_TYPES` registry (machine failures, seeded failure
  schedules, load surges, model pushes/drains, hedge storms);
- :class:`ScenarioSpec` — topology (workloads, server types,
  availability), day length, provisioning policy/headroom, transition
  and runtime-config overrides, and the event timeline.  Specs are
  frozen, validate on construction, and round-trip through
  ``to_dict``/``from_dict`` (strict: unknown keys and malformed event
  timelines are rejected with actionable errors).

:func:`compile_scenario` resolves a spec into the exact inputs of
:func:`repro.serving.cluster_runtime.simulate_cluster_day` — the
profiled :class:`EfficiencyTable` (per-pair records via the persistent
profile cache), the per-workload diurnal traces with events applied, a
``failure_schedule``-style event list, :class:`TransitionConfig` and
:class:`RuntimeConfig` — so a :class:`CompiledScenario` runs the day
with any provisioning policy.  The registry (:func:`register` /
:func:`get_scenario` / :func:`registry`) holds the scenario zoo at
smoke scale; :func:`full_scale` lifts a spec to the full paper zoo
(all six workloads, all eleven server types, the 96-interval day).

Bit-exactness: the registered ``baseline_day`` and ``failure_day``
scenarios re-declare the previously hand-wired benchmark/example days
and reproduce them bit-for-bit (pinned by ``tests/test_scenarios.py``);
the scenario-matrix suite there runs *every* registered scenario as a
smoke day, so a new scenario is covered the moment it is registered.
Everything here is deterministic: all randomness flows through seeds
declared in the spec (this file is in ``repro.analysis``'s
determinism-lint scope).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np

from repro.configs.paper_models import (PAPER_MODELS, SERVING_MODELS,
                                        paper_profile)
from repro.core.cluster import POLICIES, EfficiencyTable, TransitionConfig
from repro.core.devices import SERVER_TYPES
from repro.serving.cluster_runtime import (
    DayInputs,
    DayResult,
    RuntimeConfig,
    failure_schedule,
    simulate_cluster_day,
)
from repro.serving.diurnal import diurnal_trace, load_increment_rate


class ScenarioError(ValueError):
    """A scenario spec, event, or serialized dict failed validation."""


# ---------------------------------------------------------------------------
# field validation helpers
# ---------------------------------------------------------------------------

_REQUIRED = object()


def _coerce(where: str, name: str, value, types):
    """Type-check ``value`` against ``types`` (a type or tuple); ints are
    accepted for float fields (and coerced), bools are never ints."""
    tt = types if isinstance(types, tuple) else (types,)
    if float in tt and isinstance(value, int) and not isinstance(value, bool):
        return float(value)
    if isinstance(value, bool) and bool not in tt:
        raise ScenarioError(
            f"{where}: field '{name}' must be "
            f"{'/'.join(t.__name__ for t in tt)}, got bool {value!r}")
    if not isinstance(value, tt):
        raise ScenarioError(
            f"{where}: field '{name}' must be "
            f"{'/'.join(t.__name__ for t in tt)}, "
            f"got {type(value).__name__} {value!r}")
    return value


def _check_keys(where: str, got: dict, known) -> None:
    unknown = [k for k in got if k not in known]
    if unknown:
        raise ScenarioError(
            f"{where}: unknown key(s) {', '.join(map(repr, unknown))}; "
            f"expected one of: {', '.join(sorted(known))}")


def _config_overrides(where: str, overrides: dict, config_cls) -> dict:
    """Validate a dict of dataclass-field overrides (TransitionConfig /
    RuntimeConfig) by name and type."""
    fields = {f.name: f.type for f in dataclasses.fields(config_cls)}
    _check_keys(where, overrides, fields)
    out = {}
    for k, v in overrides.items():
        ftype = fields[k]
        tname = ftype if isinstance(ftype, str) else ftype.__name__
        types: tuple = (bool,) if tname == "bool" else \
            (int,) if tname == "int" else (float,)
        out[k] = _coerce(where, k, v, types)
    return out


# ---------------------------------------------------------------------------
# workload arrival curves
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """One workload's arrival curve, layered on ``diurnal_trace``.

    ``load_frac`` scales the curve's peak to that fraction of the fleet's
    best-case capacity for this workload (``table.fleet_capacity()[m]``),
    so a spec stays meaningful across topologies.  The shape knobs default
    to the synchronized-peak day of the paper (Fig. 2d); ``peak_hour`` /
    ``shoulder_hour`` shifts declare phase-shifted (geo-style) regions.
    """

    name: str
    load_frac: float = 0.09
    trace_seed: int = 0
    peak_hour: float = 20.0
    shoulder_hour: float = 11.0
    valley_frac: float = 0.45
    jitter: float = 0.02

    def __post_init__(self):
        where = f"workload {self.name!r}" if isinstance(self.name, str) \
            else "workload"
        _coerce(where, "name", self.name, str)
        if self.name not in SERVING_MODELS:
            raise ScenarioError(
                f"{where}: unknown workload; known workloads: "
                f"{', '.join(sorted(SERVING_MODELS))}")
        for f in dataclasses.fields(self):
            if f.name == "name":
                continue
            types = int if f.name == "trace_seed" else float
            object.__setattr__(
                self, f.name,
                _coerce(where, f.name, getattr(self, f.name), types))
        if not self.load_frac > 0.0:
            raise ScenarioError(f"{where}: load_frac must be > 0, "
                                f"got {self.load_frac}")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "WorkloadSpec":
        _coerce("workload", "<spec>", d, dict)
        _check_keys("workload", d, {f.name for f in
                                    dataclasses.fields(WorkloadSpec)})
        if "name" not in d:
            raise ScenarioError("workload: missing required field 'name'")
        return WorkloadSpec(**d)


# ---------------------------------------------------------------------------
# geo-distributed regions (see repro.serving.geo for the serving semantics)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RegionSpec:
    """One region (datacenter) of a geo-distributed scenario.

    A region re-uses the owning spec's workload curves with its local
    clock: ``phase_hours`` shifts every workload's ``peak_hour`` /
    ``shoulder_hour`` (mod 24), ``load_scale`` scales its offered load,
    and ``trace_seed_offset`` decorrelates the trace jitter across
    regions.  ``servers`` / ``availability`` of ``None`` inherit the
    spec-level pool; overriding them gives the region its own topology.
    """

    name: str
    phase_hours: float = 0.0
    load_scale: float = 1.0
    trace_seed_offset: int = 0
    servers: tuple[str, ...] | None = None
    availability: dict[str, int] | None = None

    def __post_init__(self):
        _coerce("region", "name", self.name, str)
        if not self.name:
            raise ScenarioError("region: name must be non-empty")
        where = f"region {self.name!r}"
        object.__setattr__(self, "phase_hours",
                           _coerce(where, "phase_hours", self.phase_hours,
                                   float))
        scale = _coerce(where, "load_scale", self.load_scale, float)
        object.__setattr__(self, "load_scale", scale)
        if not scale > 0.0:
            raise ScenarioError(f"{where}: load_scale must be > 0, "
                                f"got {scale}")
        _coerce(where, "trace_seed_offset", self.trace_seed_offset, int)
        if self.servers is not None:
            srv = tuple(self.servers)
            object.__setattr__(self, "servers", srv)
            for s in srv:
                if s not in SERVER_TYPES:
                    raise ScenarioError(
                        f"{where}: unknown server type {s!r}; known: "
                        f"{', '.join(SERVER_TYPES)}")
            if len(set(srv)) != len(srv):
                raise ScenarioError(f"{where}: duplicate server types")
        if self.availability is not None:
            _coerce(where, "availability", self.availability, dict)
            for s, n in self.availability.items():
                if _coerce(where, f"availability[{s!r}]", n, int) <= 0:
                    raise ScenarioError(
                        f"{where}: availability[{s!r}] must be > 0, got {n}")

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "phase_hours": self.phase_hours,
            "load_scale": self.load_scale,
            "trace_seed_offset": self.trace_seed_offset,
            "servers": None if self.servers is None else list(self.servers),
            "availability": None if self.availability is None
            else dict(self.availability),
        }

    @staticmethod
    def from_dict(d: dict) -> "RegionSpec":
        _coerce("region", "<spec>", d, dict)
        _check_keys("region", d, {f.name for f in
                                  dataclasses.fields(RegionSpec)})
        if "name" not in d:
            raise ScenarioError("region: missing required field 'name'")
        kw = dict(d)
        if kw.get("servers") is not None:
            _coerce("region", "servers", kw["servers"], (list, tuple))
            kw["servers"] = tuple(kw["servers"])
        return RegionSpec(**kw)


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    """One bidirectional inter-region link.

    ``rtt_ms`` is the round-trip a spilled query pays on top of its remote
    service time.  ``capacity_frac`` bounds the spill rate per direction as
    a fraction of the *smaller* endpoint's total best-case fleet capacity
    (summed over workloads), so a link declaration stays meaningful when
    the topology is scaled.
    """

    a: str
    b: str
    rtt_ms: float
    capacity_frac: float = 1.0

    def __post_init__(self):
        _coerce("link", "a", self.a, str)
        _coerce("link", "b", self.b, str)
        where = f"link {self.a!r}<->{self.b!r}"
        if self.a == self.b:
            raise ScenarioError(f"{where}: endpoints must differ")
        rtt = _coerce(where, "rtt_ms", self.rtt_ms, float)
        object.__setattr__(self, "rtt_ms", rtt)
        if rtt < 0:
            raise ScenarioError(f"{where}: rtt_ms must be >= 0, got {rtt}")
        cap = _coerce(where, "capacity_frac", self.capacity_frac, float)
        object.__setattr__(self, "capacity_frac", cap)
        if not cap > 0:
            raise ScenarioError(f"{where}: capacity_frac must be > 0, "
                                f"got {cap}")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "LinkSpec":
        _coerce("link", "<spec>", d, dict)
        _check_keys("link", d, {f.name for f in
                                dataclasses.fields(LinkSpec)})
        for req in ("a", "b", "rtt_ms"):
            if req not in d:
                raise ScenarioError(f"link: missing required field {req!r}")
        return LinkSpec(**d)


# ---------------------------------------------------------------------------
# typed timeline events
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EventType:
    """One registered event kind: its field schema, cross-field validation
    against the owning spec, and its compile-time application."""

    kind: str
    doc: str
    # field name -> (accepted type(s), default or _REQUIRED)
    fields: dict[str, tuple]
    # validate(spec, params) -> error message or None
    validate: Callable[["ScenarioSpec", dict], str | None]
    # apply(compiled, runtime_overrides, params) — mutates traces/failures
    apply: Callable[["CompiledScenario", dict, dict], None]
    # interval-indexed fields, rescaled by full_scale()
    interval_fields: tuple[str, ...] = ()


def _window(spec: "ScenarioSpec", p: dict) -> str | None:
    if not 0 <= p["start"] < p["end"] <= spec.n_steps:
        return (f"window [{p['start']}, {p['end']}) outside the day "
                f"(n_steps={spec.n_steps})")
    return None


def _known_workload(spec: "ScenarioSpec", name) -> str | None:
    names = [w.name for w in spec.workloads]
    if name is not None and name not in names:
        return (f"workload {name!r} not in this scenario's workloads "
                f"({', '.join(names)})")
    return None


def _wl_index(comp: "CompiledScenario", name: str) -> int:
    return [w.name for w in comp.spec.workloads].index(name)


def _v_machine_failure(spec, p):
    if not 0 <= p["at"] < spec.n_steps:
        return f"at={p['at']} outside the day (n_steps={spec.n_steps})"
    if p["server"] not in spec.server_names():
        return (f"server {p['server']!r} not in this scenario's pool "
                f"({', '.join(spec.server_names())})")
    if not 0.0 < p["window_frac"] < 1.0:
        return f"window_frac must be in (0, 1), got {p['window_frac']}"
    return None


def _a_machine_failure(comp, runtime, p):
    h = comp.spec.server_names().index(p["server"])
    comp.failures.append((p["at"], h, p["window_frac"]))


def _v_random_failures(spec, p):
    if not 0.0 <= p["fail_prob"] <= 1.0:
        return f"fail_prob must be in [0, 1], got {p['fail_prob']}"
    return None


def _a_random_failures(comp, runtime, p):
    comp.failures.extend(failure_schedule(
        comp.spec.n_steps, len(comp.table.servers), p["fail_prob"],
        seed=p["seed"]))


def _v_load_surge(spec, p):
    return _window(spec, p) or _known_workload(spec, p["workload"]) or (
        None if p["factor"] > 0 else f"factor must be > 0, got {p['factor']}")


def _a_load_surge(comp, runtime, p):
    rows = slice(None) if p["workload"] is None \
        else _wl_index(comp, p["workload"])
    comp.traces[rows, p["start"]:p["end"]] *= p["factor"]


def _v_model_push(spec, p):
    if not 0 <= p["at"] < spec.n_steps:
        return f"at={p['at']} outside the day (n_steps={spec.n_steps})"
    if p["ramp"] < 1:
        return f"ramp must be >= 1 interval, got {p['ramp']}"
    if "canary_frac" in p and not 0.0 <= p["canary_frac"] < 1.0:
        return f"canary_frac must be in [0, 1), got {p['canary_frac']}"
    return _known_workload(spec, p["workload"])


def _a_model_push(comp, runtime, p):
    # canary trickle before the push keeps a sliver of the fleet allocated
    # and warm, so cutover traffic has ready servers while the scaled-up
    # pool is still loading (canary_frac=0 models a cold push: the first
    # model_load_s of the cutover interval has no ready servers at all,
    # which simulate_cluster_day reports as an infeasible day)
    T, at, ramp = comp.spec.n_steps, p["at"], p["ramp"]
    gate = np.full(T, p["canary_frac"])
    end = min(at + ramp, T)
    steps = np.arange(end - at) + 1
    gate[at:end] = p["canary_frac"] + steps * (1.0 - p["canary_frac"]) / ramp
    gate[end:] = 1.0
    comp.traces[_wl_index(comp, p["workload"])] *= gate


def _a_model_drain(comp, runtime, p):
    T, at, ramp = comp.spec.n_steps, p["at"], p["ramp"]
    gate = np.ones(T)
    end = min(at + ramp, T)
    gate[at:end] = 1.0 - (np.arange(end - at) + 1) / ramp
    gate[end:] = 0.0
    comp.traces[_wl_index(comp, p["workload"])] *= gate


def _v_hedge_storm(spec, p):
    if err := _window(spec, p):
        return err
    if not p["factor"] > 0:
        return f"factor must be > 0, got {p['factor']}"
    if not 0.0 < p["hedge_quantile"] < 1.0:
        return f"hedge_quantile must be in (0, 1), got {p['hedge_quantile']}"
    if not p["hedge_factor"] > 0:
        return f"hedge_factor must be > 0, got {p['hedge_factor']}"
    return None


def _a_hedge_storm(comp, runtime, p):
    comp.traces[:, p["start"]:p["end"]] *= p["factor"]
    runtime["hedge_quantile"] = p["hedge_quantile"]
    runtime["hedge_factor"] = p["hedge_factor"]


def _known_region(spec, name) -> str | None:
    if spec.regions is None:
        return ("region events require a geo scenario "
                "(ScenarioSpec.regions is None)")
    names = [r.name for r in spec.regions]
    if name not in names:
        return (f"region {name!r} not in this scenario's regions "
                f"({', '.join(names)})")
    return None


def _v_region_partition(spec, p):
    return _known_region(spec, p["region"]) or _window(spec, p)


def _a_region_partition(comp, runtime, p):
    # consumed by the geo compiler (repro.serving.geo): severs every link
    # touching the region over [start, end)
    comp.partitions.append((p["region"], p["start"], p["end"]))


def _v_region_drain(spec, p):
    if err := _known_region(spec, p["region"]):
        return err
    if len(spec.regions) < 2:
        return "region_drain needs another region to evacuate into"
    if not 0 <= p["at"] < spec.n_steps:
        return f"at={p['at']} outside the day (n_steps={spec.n_steps})"
    if p["ramp"] < 1:
        return f"ramp must be >= 1 interval, got {p['ramp']}"
    return None


def _a_region_drain(comp, runtime, p):
    # consumed by the geo compiler: the region's keepable load ramps to 0
    # over [at, at+ramp); the remainder force-spills over surviving links
    comp.drains.append((p["region"], p["at"], p["ramp"]))


EVENT_TYPES: dict[str, EventType] = {
    "machine_failure": EventType(
        "machine_failure",
        "one machine of `server` dies at `window_frac` of interval `at`'s "
        "measured window (victim drawn serving-proportionally)",
        fields={"at": (int, _REQUIRED), "server": (str, _REQUIRED),
                "window_frac": (float, 0.5)},
        validate=_v_machine_failure, apply=_a_machine_failure,
        interval_fields=("at",)),
    "random_failures": EventType(
        "random_failures",
        "seeded day-long failure schedule: each server type loses one "
        "machine w.p. `fail_prob` per interval (failure_schedule)",
        fields={"fail_prob": (float, _REQUIRED), "seed": (int, 0)},
        validate=_v_random_failures, apply=_a_random_failures),
    "load_surge": EventType(
        "load_surge",
        "flash crowd: multiply `workload`'s (or every workload's) offered "
        "load by `factor` over intervals [start, end)",
        fields={"start": (int, _REQUIRED), "end": (int, _REQUIRED),
                "factor": (float, _REQUIRED),
                "workload": ((str, type(None)), None)},
        validate=_v_load_surge, apply=_a_load_surge,
        interval_fields=("start", "end")),
    "model_push": EventType(
        "model_push",
        "model push: `workload` serves only a `canary_frac` trickle before "
        "interval `at` (keeping a warm sliver of the fleet), then ramps in "
        "linearly over `ramp` intervals; canary_frac=0 is a cold push — "
        "the cutover interval has no ready servers during model load",
        fields={"workload": (str, _REQUIRED), "at": (int, _REQUIRED),
                "ramp": (int, 1), "canary_frac": (float, 0.02)},
        validate=_v_model_push, apply=_a_model_push,
        interval_fields=("at", "ramp")),
    "model_drain": EventType(
        "model_drain",
        "model drain: `workload` ramps out linearly over `ramp` intervals "
        "from interval `at`, then serves no traffic",
        fields={"workload": (str, _REQUIRED), "at": (int, _REQUIRED),
                "ramp": (int, 1)},
        validate=_v_model_push, apply=_a_model_drain,
        interval_fields=("at", "ramp")),
    "hedge_storm": EventType(
        "hedge_storm",
        "straggler storm: aggressive hedge knobs (hedge_quantile / "
        "hedge_factor, overriding the spec's runtime block) plus a "
        "`factor` surge over [start, end) that trips them",
        fields={"start": (int, _REQUIRED), "end": (int, _REQUIRED),
                "factor": (float, 1.5), "hedge_quantile": (float, 0.9),
                "hedge_factor": (float, 1.2)},
        validate=_v_hedge_storm, apply=_a_hedge_storm,
        interval_fields=("start", "end")),
    "region_partition": EventType(
        "region_partition",
        "network partition: every inter-region link touching `region` is "
        "severed over intervals [start, end) — the region serves (and "
        "spills) nothing across the partition and runs local-only",
        fields={"region": (str, _REQUIRED), "start": (int, _REQUIRED),
                "end": (int, _REQUIRED)},
        validate=_v_region_partition, apply=_a_region_partition,
        interval_fields=("start", "end")),
    "region_drain": EventType(
        "region_drain",
        "whole-DC evacuation: `region`'s keepable load ramps to 0 over "
        "`ramp` intervals from interval `at`; the evacuated load "
        "force-spills over surviving links and the receiving regions "
        "provision *before* the source stops serving (make-before-break "
        "power accounting via each region's StatefulProvisioner)",
        fields={"region": (str, _REQUIRED), "at": (int, _REQUIRED),
                "ramp": (int, 1)},
        validate=_v_region_drain, apply=_a_region_drain,
        interval_fields=("at", "ramp")),
}

# event kinds consumed by the geo compiler rather than a single-region day
GEO_EVENT_KINDS = ("region_partition", "region_drain")


@dataclasses.dataclass(frozen=True)
class Event:
    """One typed timeline event: a kind from :data:`EVENT_TYPES` plus its
    normalized parameters (defaults filled, names and types validated)."""

    kind: str
    params: dict[str, Any]

    def __post_init__(self):
        _coerce("event", "kind", self.kind, str)
        if self.kind not in EVENT_TYPES:
            raise ScenarioError(
                f"event: unknown event kind {self.kind!r}; registered "
                f"kinds: {', '.join(sorted(EVENT_TYPES))}")
        et = EVENT_TYPES[self.kind]
        where = f"event '{self.kind}'"
        _coerce(where, "params", self.params, dict)
        _check_keys(where, self.params, et.fields)
        norm = {}
        for fname, (types, default) in et.fields.items():
            if fname not in self.params:
                if default is _REQUIRED:
                    raise ScenarioError(
                        f"{where}: missing required field {fname!r} "
                        f"(fields: {', '.join(et.fields)})")
                norm[fname] = default
            else:
                norm[fname] = _coerce(where, fname, self.params[fname],
                                      types)
        object.__setattr__(self, "params", norm)

    @staticmethod
    def create(kind: str, **params) -> "Event":
        return Event(kind, params)

    def to_dict(self) -> dict:
        return {"kind": self.kind, **self.params}

    @staticmethod
    def from_dict(d: dict) -> "Event":
        _coerce("event", "<event>", d, dict)
        if "kind" not in d:
            raise ScenarioError(
                "event: missing 'kind'; registered kinds: "
                f"{', '.join(sorted(EVENT_TYPES))}")
        p = dict(d)
        return Event(p.pop("kind"), p)


# ---------------------------------------------------------------------------
# the scenario spec
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """A full serving scenario as data (see the module docstring).

    ``servers``/``availability`` of ``None`` mean the full paper pool
    (``SERVER_TYPES`` / ``DEFAULT_AVAILABILITY``).  ``overprovision`` of
    ``None`` derives the paper's rate R from the *base* arrival curves
    (events are disruptions the provisioner must absorb, not forecast).
    ``transitions`` / ``runtime`` are validated field overrides of
    :class:`TransitionConfig` / :class:`RuntimeConfig`.
    """

    name: str
    workloads: tuple[WorkloadSpec, ...]
    description: str = ""
    servers: tuple[str, ...] | None = None
    availability: dict[str, int] | None = None
    n_steps: int = 24
    seed: int = 0
    overprovision: float | None = None
    policy: str = "hercules"
    transitions: dict[str, float] = dataclasses.field(default_factory=dict)
    runtime: dict[str, Any] = dataclasses.field(default_factory=dict)
    events: tuple[Event, ...] = ()
    # geo-distributed scenarios (repro.serving.geo): regions of phase-shifted
    # copies of the workload curves, joined by capacity/RTT links
    regions: tuple[RegionSpec, ...] | None = None
    links: tuple[LinkSpec, ...] | None = None
    # interference-aware multi-tenant packing (repro.core.colocation): the
    # provisioner may merge complementary tenants onto shared machines
    colocation: bool = False

    def __post_init__(self):
        _coerce("scenario", "name", self.name, str)
        if not self.name:
            raise ScenarioError("scenario: name must be non-empty")
        where = f"scenario {self.name!r}"
        _coerce(where, "description", self.description, str)
        for fname in ("workloads", "events"):
            v = getattr(self, fname)
            if isinstance(v, list):
                object.__setattr__(self, fname, tuple(v))
        if not self.workloads:
            raise ScenarioError(f"{where}: at least one workload required")
        for w in self.workloads:
            if not isinstance(w, WorkloadSpec):
                raise ScenarioError(f"{where}: workloads must be "
                                    f"WorkloadSpec, got {type(w).__name__}")
        names = [w.name for w in self.workloads]
        if len(set(names)) != len(names):
            raise ScenarioError(f"{where}: duplicate workload names "
                                f"({', '.join(names)})")
        if self.servers is not None:
            srv = tuple(self.servers)
            object.__setattr__(self, "servers", srv)
            for s in srv:
                if s not in SERVER_TYPES:
                    raise ScenarioError(
                        f"{where}: unknown server type {s!r}; known: "
                        f"{', '.join(SERVER_TYPES)}")
            if len(set(srv)) != len(srv):
                raise ScenarioError(f"{where}: duplicate server types")
        if self.availability is not None:
            _coerce(where, "availability", self.availability, dict)
            for s, n in self.availability.items():
                if s not in self.server_names():
                    raise ScenarioError(
                        f"{where}: availability for {s!r} which is not in "
                        f"the pool ({', '.join(self.server_names())})")
                if _coerce(where, f"availability[{s!r}]", n, int) <= 0:
                    raise ScenarioError(
                        f"{where}: availability[{s!r}] must be > 0, got {n}")
        if _coerce(where, "n_steps", self.n_steps, int) < 2:
            raise ScenarioError(f"{where}: n_steps must be >= 2, "
                                f"got {self.n_steps}")
        _coerce(where, "seed", self.seed, int)
        if self.overprovision is not None:
            over = _coerce(where, "overprovision", self.overprovision, float)
            object.__setattr__(self, "overprovision", over)
            if over < 0:
                raise ScenarioError(f"{where}: overprovision must be >= 0")
        if self.policy not in POLICIES:
            raise ScenarioError(
                f"{where}: unknown policy {self.policy!r}; known: "
                f"{', '.join(POLICIES)}")
        object.__setattr__(self, "colocation",
                           _coerce(where, "colocation", self.colocation,
                                   bool))
        if self.colocation and self.regions is not None:
            raise ScenarioError(f"{where}: colocation is not supported for "
                                "geo (multi-region) scenarios yet")
        if self.regions is not None:
            reg = tuple(self.regions)
            object.__setattr__(self, "regions", reg)
            if not reg:
                raise ScenarioError(f"{where}: regions must be non-empty "
                                    "(or None for a single-DC scenario)")
            for r in reg:
                if not isinstance(r, RegionSpec):
                    raise ScenarioError(f"{where}: regions must be "
                                        f"RegionSpec, got {type(r).__name__}")
            rnames = [r.name for r in reg]
            if len(set(rnames)) != len(rnames):
                raise ScenarioError(f"{where}: duplicate region names "
                                    f"({', '.join(rnames)})")
            for r in reg:
                pool = r.servers if r.servers is not None else \
                    self.server_names()
                for s in (r.availability or {}):
                    if s not in pool:
                        raise ScenarioError(
                            f"{where}: region {r.name!r} availability for "
                            f"{s!r} which is not in its pool "
                            f"({', '.join(pool)})")
        if self.links is not None:
            if self.regions is None:
                raise ScenarioError(f"{where}: links require regions")
            lnk = tuple(self.links)
            object.__setattr__(self, "links", lnk)
            rnames = [r.name for r in self.regions]
            seen_pairs = []
            for li in lnk:
                if not isinstance(li, LinkSpec):
                    raise ScenarioError(f"{where}: links must be LinkSpec, "
                                        f"got {type(li).__name__}")
                for end in (li.a, li.b):
                    if end not in rnames:
                        raise ScenarioError(
                            f"{where}: link endpoint {end!r} is not a "
                            f"region ({', '.join(rnames)})")
                pair = tuple(sorted((li.a, li.b)))
                if pair in seen_pairs:
                    raise ScenarioError(
                        f"{where}: duplicate link {pair[0]}<->{pair[1]}")
                seen_pairs.append(pair)
        object.__setattr__(
            self, "transitions",
            _config_overrides(f"{where} transitions", self.transitions,
                              TransitionConfig))
        object.__setattr__(
            self, "runtime",
            _config_overrides(f"{where} runtime", self.runtime,
                              RuntimeConfig))
        for i, ev in enumerate(self.events):
            if not isinstance(ev, Event):
                raise ScenarioError(f"{where}: events[{i}] must be Event, "
                                    f"got {type(ev).__name__}")
            if err := EVENT_TYPES[ev.kind].validate(self, ev.params):
                raise ScenarioError(
                    f"{where}: events[{i}] ({ev.kind}): {err}")

    # -- resolved topology ---------------------------------------------------

    def server_names(self) -> tuple[str, ...]:
        """The effective server pool (spec order; full pool when None)."""
        return self.servers if self.servers is not None \
            else tuple(SERVER_TYPES)

    def workload_names(self) -> tuple[str, ...]:
        return tuple(w.name for w in self.workloads)

    # -- serialization -------------------------------------------------------

    def to_dict(self) -> dict:
        """JSON-safe dict; ``from_dict`` round-trips it exactly."""
        return {
            "name": self.name,
            "description": self.description,
            "workloads": [w.to_dict() for w in self.workloads],
            "servers": None if self.servers is None else list(self.servers),
            "availability": None if self.availability is None
            else dict(self.availability),
            "n_steps": self.n_steps,
            "seed": self.seed,
            "overprovision": self.overprovision,
            "policy": self.policy,
            "transitions": dict(self.transitions),
            "runtime": dict(self.runtime),
            "events": [ev.to_dict() for ev in self.events],
            "regions": None if self.regions is None
            else [r.to_dict() for r in self.regions],
            "links": None if self.links is None
            else [li.to_dict() for li in self.links],
            "colocation": self.colocation,
        }

    @staticmethod
    def from_dict(d: dict) -> "ScenarioSpec":
        """Strict inverse of :meth:`to_dict`: unknown keys, unknown event
        kinds, missing required fields and type mismatches all raise
        :class:`ScenarioError` with an actionable message."""
        _coerce("scenario", "<spec>", d, dict)
        known = {f.name for f in dataclasses.fields(ScenarioSpec)}
        _check_keys("scenario", d, known)
        for req in ("name", "workloads"):
            if req not in d:
                raise ScenarioError(
                    f"scenario: missing required key {req!r}")
        kw = dict(d)
        _coerce("scenario", "workloads", kw["workloads"], (list, tuple))
        kw["workloads"] = tuple(
            WorkloadSpec.from_dict(w) for w in kw["workloads"])
        if kw.get("events") is not None:
            _coerce("scenario", "events", kw["events"], (list, tuple))
            kw["events"] = tuple(Event.from_dict(e) for e in kw["events"])
        if kw.get("servers") is not None:
            _coerce("scenario", "servers", kw["servers"], (list, tuple))
            kw["servers"] = tuple(kw["servers"])
        if kw.get("regions") is not None:
            _coerce("scenario", "regions", kw["regions"], (list, tuple))
            kw["regions"] = tuple(
                RegionSpec.from_dict(r) for r in kw["regions"])
        if kw.get("links") is not None:
            _coerce("scenario", "links", kw["links"], (list, tuple))
            kw["links"] = tuple(LinkSpec.from_dict(li) for li in kw["links"])
        return ScenarioSpec(**kw)


# ---------------------------------------------------------------------------
# compilation: spec -> simulate_cluster_day inputs
# ---------------------------------------------------------------------------

# in-process memo of profiled bundles keyed by topology, so compiling many
# scenarios over the same pool (the matrix suite, the bench's per-policy
# and per-fraction sweeps) builds the efficiency table once; the persistent
# profile cache (artifacts/profiles/) already dedups across processes
_BUNDLES: dict[tuple, tuple] = {}


def _bundle(spec: ScenarioSpec, verbose: bool = False):
    # deferred: core.efficiency reaches repro.serving through the engine
    # stack, so a module-level import here would close an import cycle
    from repro.core.efficiency import build_table

    key = (spec.workload_names(), spec.servers,
           None if spec.availability is None
           else tuple(sorted(spec.availability.items())))
    if key not in _BUNDLES:
        avail = None if spec.availability is None else dict(spec.availability)
        # fast path: a bundle differing only in pool sizes reuses the
        # profiled tuples (EfficiencyTable.with_availability) — per-region
        # pool overrides in geo scenarios hit this instead of build_table
        if avail is not None:
            for k2, (t2, r2, p2, s2) in _BUNDLES.items():
                if k2[:2] == key[:2]:
                    _BUNDLES[key] = (t2.with_availability(avail), r2, p2, s2)
                    return _BUNDLES[key]
        profiles = {n: paper_profile(n) for n in spec.workload_names()}
        servers = None if spec.servers is None \
            else {s: SERVER_TYPES[s] for s in spec.servers}
        table, records = build_table(profiles, servers, avail,
                                     verbose=verbose)
        _BUNDLES[key] = (table, records, profiles, servers)
    return _BUNDLES[key]


# colocation tables, memoized like _BUNDLES (the admissible cells depend
# only on the workload set and the server pool, not on availability)
_COLOC_TABLES: dict[tuple, Any] = {}


def _coloc_table(spec: ScenarioSpec, profiles: dict, servers: dict | None):
    from repro.core.colocation import build_colocation_table

    key = (tuple(sorted(spec.workload_names())),
           None if spec.servers is None else tuple(sorted(spec.servers)))
    if key not in _COLOC_TABLES:
        _COLOC_TABLES[key] = build_colocation_table(
            profiles, servers if servers is not None else dict(SERVER_TYPES))
    return _COLOC_TABLES[key]


@dataclasses.dataclass
class CompiledScenario:
    """A spec resolved to a :class:`DayInputs` bundle plus runtime config.

    The day's data lives in ``inputs`` (what ``simulate_cluster_day``
    consumes); ``table``/``traces``/... stay available as read-through
    properties for call sites that inspect the compiled day.
    """

    spec: ScenarioSpec
    inputs: DayInputs
    config: RuntimeConfig

    @property
    def table(self) -> EfficiencyTable:
        return self.inputs.table

    @property
    def records(self) -> dict:
        return self.inputs.records

    @property
    def profiles(self) -> dict:
        return self.inputs.profiles

    @property
    def servers(self) -> dict | None:
        return self.inputs.servers

    @property
    def traces(self) -> np.ndarray:          # [M, T] with events applied
        return self.inputs.traces

    @property
    def overprovision(self) -> float:
        return self.inputs.overprovision

    @property
    def transitions(self) -> TransitionConfig:
        return self.inputs.transitions

    @property
    def failures(self) -> list[tuple[int, int, float]]:
        return self.inputs.failures

    def run(self, policy: str | None = None) -> DayResult:
        """Serve the day (``simulate_cluster_day``) under ``policy``
        (default: the spec's declared policy)."""
        return simulate_cluster_day(
            self.inputs, policy=policy or self.spec.policy,
            config=self.config)


def compile_scenario(spec: ScenarioSpec, verbose: bool = False):
    """Resolve ``spec``: profile the topology (cached), lay the per-workload
    diurnal traces, derive the over-provision rate R from the base curves
    (unless declared), then apply the event timeline in order (traces,
    failure list, runtime overrides).  Returns a :class:`CompiledScenario`
    whose ``inputs`` is the :class:`DayInputs` bundle — or, for a spec with
    ``regions``, a :class:`repro.serving.geo.CompiledGeoScenario` holding
    one post-spill ``DayInputs`` per region."""
    if spec.regions is not None:
        # deferred: repro.serving.geo imports this module
        from repro.serving.geo import compile_geo_scenario

        return compile_geo_scenario(spec, verbose=verbose)
    table, records, profiles, servers = _bundle(spec, verbose=verbose)
    coloc = _coloc_table(spec, profiles, servers) if spec.colocation \
        else None
    cap = table.fleet_capacity()
    traces = np.stack([
        diurnal_trace(w.load_frac * cap[m], n_steps=spec.n_steps,
                      valley_frac=w.valley_frac, peak_hour=w.peak_hour,
                      shoulder_hour=w.shoulder_hour, jitter=w.jitter,
                      seed=w.trace_seed)
        for m, w in enumerate(spec.workloads)
    ])
    over = spec.overprovision if spec.overprovision is not None \
        else max(load_increment_rate(tr) for tr in traces)
    comp = CompiledScenario(
        spec=spec,
        inputs=DayInputs(
            table=table, records=records, profiles=profiles, traces=traces,
            servers=servers, overprovision=float(over),
            transitions=TransitionConfig(**spec.transitions),
            failures=[], seed=spec.seed, colocation=coloc),
        config=RuntimeConfig())
    runtime = dict(spec.runtime)
    for ev in spec.events:
        EVENT_TYPES[ev.kind].apply(comp, runtime, ev.params)
    comp.config = RuntimeConfig(**runtime)
    return comp


def run_scenario(spec: ScenarioSpec, policy: str | None = None,
                 verbose: bool = False):
    """Compile and serve ``spec`` in one call.  Returns a
    :class:`DayResult` (single-DC) or a geo day result (spec with
    ``regions``)."""
    return compile_scenario(spec, verbose=verbose).run(policy=policy)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, ScenarioSpec] = {}


def register(spec: ScenarioSpec, replace: bool = False) -> ScenarioSpec:
    """Register ``spec`` in the zoo.  Registered scenarios are picked up by
    the scenario-matrix test suite and the bench's ``scenarios`` record
    automatically — registration *is* the test plan."""
    if spec.name in _REGISTRY and not replace:
        raise ScenarioError(
            f"scenario {spec.name!r} already registered "
            "(pass replace=True to overwrite)")
    _REGISTRY[spec.name] = spec
    return spec


def registry() -> tuple[str, ...]:
    """Sorted names of every registered scenario."""
    return tuple(sorted(_REGISTRY))


def get_scenario(name: str) -> ScenarioSpec:
    if name not in _REGISTRY:
        raise ScenarioError(f"unknown scenario {name!r}; registered: "
                            f"{', '.join(sorted(_REGISTRY))}")
    return _REGISTRY[name]


def full_scale(spec: ScenarioSpec, n_steps: int = 96,
               load_frac: float | None = None) -> ScenarioSpec:
    """Lift a smoke-scale spec to the full paper zoo: all six workloads
    (trace seeds 0..5, the benchmark convention), the full server pool and
    default availability, a ``n_steps``-interval day.  Interval-indexed
    event fields are rescaled proportionally; per-workload curve-shape
    overrides of the smoke spec are not carried (the full zoo uses the
    synchronized-peak defaults)."""
    frac = load_frac if load_frac is not None \
        else spec.workloads[0].load_frac
    scale = n_steps / spec.n_steps
    events = []
    for ev in spec.events:
        p = dict(ev.params)
        for f in EVENT_TYPES[ev.kind].interval_fields:
            p[f] = max(int(round(p[f] * scale)), 1)
        events.append(Event(ev.kind, p))
    return dataclasses.replace(
        spec,
        workloads=tuple(WorkloadSpec(name=n, load_frac=frac, trace_seed=i)
                        for i, n in enumerate(PAPER_MODELS)),
        servers=None, availability=None, n_steps=n_steps,
        events=tuple(events))


# ---------------------------------------------------------------------------
# the registered zoo
# ---------------------------------------------------------------------------

# The reduced topology every scenario is registered (and matrix-tested) at:
# 2 workloads x 3 server types, a 24-interval day — the same cell the
# benches' --smoke modes and the tests' cluster fixtures profile, so the
# persistent profile cache is shared across all of them.
SMOKE_WORKLOADS = ("dlrm-rmc1", "dlrm-rmc3")
SMOKE_SERVERS = ("T2", "T3", "T7")
SMOKE_AVAILABILITY = {"T2": 70, "T3": 15, "T7": 5}
SMOKE_STEPS = 24

# Peak load per workload = 9% of its fleet-wide best-case capacity (the
# highest point where the heterogeneity-oblivious baseline is still
# feasible, so all three provisioning policies stay comparable).
COMPARISON_FRAC = 0.09


def _smoke_spec(name: str, description: str, **kw) -> ScenarioSpec:
    base: dict[str, Any] = dict(
        workloads=tuple(
            WorkloadSpec(n, load_frac=COMPARISON_FRAC, trace_seed=i)
            for i, n in enumerate(SMOKE_WORKLOADS)),
        servers=SMOKE_SERVERS,
        availability=dict(SMOKE_AVAILABILITY),
        n_steps=SMOKE_STEPS,
    )
    base.update(kw)
    return ScenarioSpec(name=name, description=description, **base)


register(_smoke_spec(
    "baseline_day",
    "the hand-wired benchmark/example day: synchronized diurnal peaks at "
    "the comparison fraction, no events (bit-exact re-declaration, pinned "
    "by tests/test_scenarios.py)"))

register(_smoke_spec(
    "failure_day",
    "baseline day + the benchmark's seeded failure schedule: each server "
    "type loses a machine w.p. 1% per interval, mid-window (bit-exact "
    "re-declaration of the bench's fault-tolerance record)",
    events=(Event.create("random_failures", fail_prob=0.01, seed=7),)))

register(_smoke_spec(
    "flash_crowd",
    "evening flash crowd: every workload's offered load surges 1.35x over "
    "the four peak intervals, unforeseen by the over-provision rate",
    events=(Event.create("load_surge", start=18, end=22, factor=1.35),)))

register(_smoke_spec(
    "phase_shifted",
    "phase-shifted regions (the geo-distributed substrate): the second "
    "workload peaks 12h out of phase, de-synchronizing the fleet peak",
    workloads=(
        WorkloadSpec(SMOKE_WORKLOADS[0], load_frac=COMPARISON_FRAC,
                     trace_seed=0),
        WorkloadSpec(SMOKE_WORKLOADS[1], load_frac=COMPARISON_FRAC,
                     trace_seed=1, peak_hour=8.0, shoulder_hour=23.0),
    )))

register(_smoke_spec(
    "model_push_midpeak",
    "model push mid-peak: the second workload serves only a 2% canary "
    "trickle until it is pushed at interval 18 (the evening peak), "
    "ramping in over 3 intervals; explicit headroom since R cannot be "
    "derived from a ramp-from-canary curve",
    overprovision=0.25,
    events=(Event.create("model_push", workload=SMOKE_WORKLOADS[1],
                         at=18, ramp=3),)))

register(_smoke_spec(
    "hedge_storm",
    "straggler storm under aggressive hedging: p90 * 1.2 hedge threshold "
    "(vs the default p99 * 2) while a 1.25x surge rides the peak — many "
    "duplicates contending in live queues",
    events=(Event.create("hedge_storm", start=17, end=21, factor=1.25,
                         hedge_quantile=0.9, hedge_factor=1.2),)))

# The geo zoo: three regions whose evening peaks sit 7 h apart, each an
# instance of the smoke topology, joined by a metro-scale link triangle.
# RTTs stay inside the tightest workload SLA (dlrm-rmc1, 20 ms) so spill
# is SLA-feasible; the rtt-budget gate in repro.serving.geo is what keeps
# longer links out of a workload's spill set.
#
# Geo regions run hotter than the single-DC comparison fraction: the
# follow-the-sun power win needs each region's peak in the convex part of
# the power-vs-load curve (the efficient T7/T3 pools exhausted, marginal
# load on T2 at ~3x the W/QPS), which on the smoke topology starts around
# 28% of fleet capacity — at COMPARISON_FRAC provisioning is linear in
# load and spilling cannot move power at all.
GEO_FRAC = 0.32

GEO_REGIONS = (
    RegionSpec("us-east", phase_hours=0.0),
    RegionSpec("eu-west", phase_hours=-7.0, trace_seed_offset=100),
    RegionSpec("ap-south", phase_hours=7.0, trace_seed_offset=200),
)
GEO_LINKS = (
    LinkSpec("us-east", "eu-west", rtt_ms=9.0, capacity_frac=0.5),
    LinkSpec("eu-west", "ap-south", rtt_ms=12.0, capacity_frac=0.5),
    LinkSpec("ap-south", "us-east", rtt_ms=6.0, capacity_frac=0.5),
)

GEO_WORKLOADS = tuple(
    WorkloadSpec(n, load_frac=GEO_FRAC, trace_seed=i)
    for i, n in enumerate(SMOKE_WORKLOADS))

register(_smoke_spec(
    "geo_3region",
    "three phase-shifted regions (evening peaks 7 h apart) joined by a "
    "link triangle: follow-the-sun spill flattens each region's served "
    "load, de-synchronizing the global fleet peak (vs per-region-isolated "
    "serving, the bench's geo_day comparison)",
    workloads=GEO_WORKLOADS, regions=GEO_REGIONS, links=GEO_LINKS))

register(_smoke_spec(
    "geo_partition",
    "geo_3region + a network partition: eu-west loses both its links over "
    "its local evening peak (intervals [11, 15) on the shared day clock), "
    "forcing local-only serving during the window",
    workloads=GEO_WORKLOADS, regions=GEO_REGIONS, links=GEO_LINKS,
    events=(Event.create("region_partition", region="eu-west",
                         start=11, end=15),)))

register(_smoke_spec(
    "geo_drain",
    "geo_3region + a whole-DC evacuation: ap-south drains over 2 "
    "intervals from interval 10 (its local valley); its load force-spills "
    "over the surviving links while the receiving regions provision "
    "make-before-break",
    workloads=GEO_WORKLOADS, regions=GEO_REGIONS, links=GEO_LINKS,
    events=(Event.create("region_drain", region="ap-south",
                         at=10, ramp=2),)))

register(_smoke_spec(
    "geo_hetero_pools",
    "geo_3region over heterogeneous per-region fleets: us-east keeps the "
    "full smoke pool, eu-west is a CPU-only site (no T7 accelerators), "
    "ap-south is an accelerator-dense edge site — spill decisions must "
    "respect each region's own efficiency table",
    workloads=GEO_WORKLOADS,
    regions=(
        RegionSpec("us-east", phase_hours=0.0),
        RegionSpec("eu-west", phase_hours=-7.0, trace_seed_offset=100,
                   servers=("T2", "T3"),
                   availability={"T2": 70, "T3": 25}),
        RegionSpec("ap-south", phase_hours=7.0, trace_seed_offset=200,
                   servers=("T3", "T7"),
                   availability={"T3": 15, "T7": 12}),
    ),
    links=GEO_LINKS))

# The co-location pair: a merge fires when two tenants' integer-rounding
# slack fits one shared machine, so these days run at fractions where the
# peak interval is merge-feasible (pinned by the bench's colo_day record:
# co-located Hercules beats single-tenant Hercules on peak provisioned
# power with every tenant meeting its SLA in every interval).

register(_smoke_spec(
    "colo_complements",
    "sparse-heavy + dense-heavy complements share machines: the "
    "interference-aware packer merges a gather-bound RMC1 machine with a "
    "compute-bound RMC3 machine wherever both tenants' dilated residual "
    "loads fit one server inside their SLAs",
    workloads=(
        WorkloadSpec(SMOKE_WORKLOADS[0], load_frac=0.07, trace_seed=0),
        WorkloadSpec(SMOKE_WORKLOADS[1], load_frac=0.07, trace_seed=1),
    ),
    colocation=True))

register(_smoke_spec(
    "colo_recsys_lm",
    "recommendation + LM-decode sharing accelerator hosts: the "
    "per-generation LM SLA is accel-only feasible, so every merge packs "
    "the token stream beside RMC1 on a T7 (engine/HBM slot sharing, "
    "measured-interference dilation)",
    workloads=(
        WorkloadSpec(SMOKE_WORKLOADS[0], load_frac=0.05, trace_seed=0),
        WorkloadSpec("llama3.2-3b-decode", load_frac=0.05, trace_seed=1),
    ),
    colocation=True))
