"""deepseek-67b [dense]: 95L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=102400 — llama-arch [arXiv:2401.02954; hf]."""
import dataclasses

import jax.numpy as jnp

from repro.common.types import ArchKind
from repro.configs.shapes import LM_SHAPES
from repro.models.transformer import LMConfig

ARCH_ID = "deepseek-67b"
KIND = ArchKind.LM_DENSE
SHAPES = LM_SHAPES

FULL = LMConfig(
    name=ARCH_ID,
    # §Perf optimized defaults (baseline in artifacts/roofline/*baseline*):
    # int8 KV cache (2x decode bytes). Chunked attention kept OFF for
    # this arch: the HLO cost model (blind to VMEM residency) measures
    # it as a net memory regression here — see EXPERIMENTS.md §Perf.
    kv_quant="int8",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab=102400,
    head_dim=128,
    rope_theta=10_000.0,
)

SMOKE = LMConfig(
    name=ARCH_ID + "-smoke",
    n_layers=3,
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    d_ff=256,
    vocab=512,
    head_dim=16,
    rope_theta=10_000.0,
    dtype=jnp.float32,
)
