"""llama3.2-3b [dense]: 28L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=128256 — small llama3 [hf:meta-llama/Llama-3.2-3B; unverified].
Llama 3.2 ties input/output embeddings; rope theta 500k."""
import dataclasses

import jax.numpy as jnp

from repro.common.types import ArchKind
from repro.configs.shapes import LM_SHAPES
from repro.models.transformer import LMConfig

ARCH_ID = "llama3.2-3b"
KIND = ArchKind.LM_DENSE
SHAPES = LM_SHAPES

FULL = LMConfig(
    name=ARCH_ID,
    # §Perf optimized defaults (baseline numbers in
    # artifacts/roofline/*baseline*): flash-style chunked attention
    # for Tq>1, int8 KV cache for decode residency.
    attn_impl="chunked",
    kv_quant="int8",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab=128256,
    head_dim=128,
    rope_theta=500_000.0,
    tie_embeddings=True,
)

SMOKE = LMConfig(
    name=ARCH_ID + "-smoke",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    vocab=512,
    head_dim=32,
    rope_theta=500_000.0,
    tie_embeddings=True,
    dtype=jnp.float32,
)
