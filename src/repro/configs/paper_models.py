"""Paper Table I: the six production recommendation models.

Two scales per model: PROD (production embedding-table sizes; what the
CPU/NMP servers host) and SMALL (the reduced tables the paper uses on
16 GB accelerators — "only the smaller versions ... are used" §III-B).
SLA targets from Fig. 15: RMC1 20ms, RMC2 50ms, RMC3 50ms, DIN 50ms,
DIEN 100ms, MT-WnD 100ms.
"""
from __future__ import annotations

from repro.core.workload import ModelProfile, profile_recsys
from repro.models.embedding import EmbeddingConfig
from repro.models.recsys_base import RecsysConfig

SLA_MS = {
    "dlrm-rmc1": 20.0,
    "dlrm-rmc2": 50.0,
    "dlrm-rmc3": 50.0,
    "din": 50.0,
    "dien": 100.0,
    "mt-wnd": 100.0,
}


def _dlrm(name: str, n_tables: int, rows: int, pooling: int, bottom, top,
          dim: int = 32) -> RecsysConfig:
    return RecsysConfig(
        name=name,
        embedding=EmbeddingConfig(
            vocab_sizes=(rows,) * n_tables, dim=dim, pooling=(pooling,) * n_tables
        ),
        n_dense=13,
        bottom_mlp=bottom,
        top_mlp=top,
        interaction="dot",
    )


def rmc1(prod: bool = True) -> RecsysConfig:
    # ~10 tables, 1M-5M rows, 20-160 lookups, bottom 256-128-32, top 256-64-1
    rows = 2_500_000 if prod else 1_000_000
    return _dlrm("dlrm-rmc1", 10, rows, 80, (256, 128, 32), (256, 64))


def rmc2(prod: bool = True) -> RecsysConfig:
    # ~100 tables (memory-dominated), smaller per-table pooling
    rows = 2_500_000 if prod else 1_000_000
    n = 100 if prod else 40
    return _dlrm("dlrm-rmc2", n, rows, 80, (256, 128, 32), (512, 128))


def rmc3(prod: bool = True) -> RecsysConfig:
    # 10 tables of 10-20M rows, 20-50 lookups, wide bottom FC (compute-heavy)
    rows = 15_000_000 if prod else 1_000_000
    return _dlrm("dlrm-rmc3", 10, rows, 30, (2560, 512, 32), (512, 128))


def mt_wnd(prod: bool = True, n_tasks: int = 5) -> RecsysConfig:
    # 26 one-hot tables, N multi-task towers of 1024-512-256
    rows = 20_000_000 if prod else 1_000_000
    return RecsysConfig(
        name="mt-wnd",
        embedding=EmbeddingConfig(
            vocab_sizes=(rows,) * 26, dim=32, pooling=(1,) * 26
        ),
        n_dense=13,
        top_mlp=(1024, 512, 256),
        interaction="concat",
        n_tasks=n_tasks,
    )


def din(prod: bool = True) -> RecsysConfig:
    # 3 tables (item/user/context), behaviour seq up to 100-1000
    item_rows = 600_000_000 if prod else 1_000_000
    return RecsysConfig(
        name="din",
        embedding=EmbeddingConfig(
            vocab_sizes=(item_rows, 1_000_000, 100_000),
            dim=18,
            pooling=(1, 1, 1),
            qr_features=(0,) if prod else (),
        ),
        seq_len=200,
        attn_mlp=(80, 40),
        top_mlp=(200, 80),
        interaction="target-attn",
    )


def dien(prod: bool = True) -> RecsysConfig:
    import dataclasses

    return dataclasses.replace(din(prod), name="dien", use_gru=True)


PAPER_MODELS = {
    "dlrm-rmc1": rmc1,
    "dlrm-rmc2": rmc2,
    "dlrm-rmc3": rmc3,
    "mt-wnd": mt_wnd,
    "din": din,
    "dien": dien,
}

# LM-decode serving workloads (ModelProfile builders, not RecsysConfigs):
# token-granular decode streams that share accelerator hosts with the
# recommendation fleet in the co-location scenarios.  Kept out of
# PAPER_MODELS so the paper-scale sweeps (and the headline power-saving
# record) iterate exactly Table I; the config import is deferred because
# the config modules pull in jax at module scope.
LM_CONTEXT = 1024
# One "query" is a full 64-1024-token generation (the query-size sample
# counts decode tokens), so the SLA is per-generation; at 1 s only the
# accelerator hosts are feasible — the LM stream is accel-bound by SLA.
LM_SLA_MS = {"llama3.2-3b-decode": 1000.0}


def _lm_decode_profile(name: str) -> ModelProfile:
    import dataclasses

    from repro.configs import llama3_2_3b
    from repro.core.workload import profile_lm_decode

    cfg = {"llama3.2-3b-decode": llama3_2_3b.FULL}[name]
    # the profile carries the serving-workload name, not the arch id, so
    # efficiency-table rows and profile-cache keys line up with the
    # scenario's workload list
    cfg = dataclasses.replace(cfg, name=name)
    return profile_lm_decode(cfg, LM_CONTEXT, LM_SLA_MS[name])


# Every workload the serving stack can schedule: the six paper models plus
# the LM-decode streams.  Scenario validation accepts exactly these names.
SERVING_MODELS = dict(PAPER_MODELS)
SERVING_MODELS["llama3.2-3b-decode"] = _lm_decode_profile


def paper_profile(name: str, prod: bool = True) -> ModelProfile:
    if name in LM_SLA_MS:
        return _lm_decode_profile(name)
    cfg = PAPER_MODELS[name](prod)
    return profile_recsys(cfg, SLA_MS[name])
