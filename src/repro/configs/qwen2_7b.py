"""qwen2-7b [dense]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064 — GQA, QKV bias [arXiv:2407.10671; hf]."""
import jax.numpy as jnp

from repro.common.types import ArchKind
from repro.configs.shapes import LM_SHAPES
from repro.models.transformer import LMConfig

ARCH_ID = "qwen2-7b"
KIND = ArchKind.LM_DENSE
SHAPES = LM_SHAPES

FULL = LMConfig(
    name=ARCH_ID,
    # §Perf optimized defaults (baseline in artifacts/roofline/*baseline*):
    # int8 KV cache (2x decode bytes). Chunked attention kept OFF for
    # this arch: the HLO cost model (blind to VMEM residency) measures
    # it as a net memory regression here — see EXPERIMENTS.md §Perf.
    kv_quant="int8",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab=152064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
)

SMOKE = LMConfig(
    name=ARCH_ID + "-smoke",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    vocab=512,
    head_dim=32,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    dtype=jnp.float32,
)
