"""Architecture registry: --arch <id> -> config module.

Each arch module exposes:
  ARCH_ID   : str
  KIND      : ArchKind
  FULL      : the exact assigned configuration
  SMOKE     : reduced same-family config for CPU smoke tests
  SHAPES    : tuple[ShapeSpec, ...] — the assigned input-shape cells
"""
from __future__ import annotations

import importlib

ARCH_IDS = (
    "qwen2-7b",
    "llama3.2-3b",
    "deepseek-67b",
    "qwen2-moe-a2.7b",
    "olmoe-1b-7b",
    "graphsage-reddit",
    "wide-deep",
    "mind",
    "din",
    "dlrm-rm2",
)

_MODULES = {
    "qwen2-7b": "qwen2_7b",
    "llama3.2-3b": "llama3_2_3b",
    "deepseek-67b": "deepseek_67b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "graphsage-reddit": "graphsage_reddit",
    "wide-deep": "wide_deep",
    "mind": "mind_arch",
    "din": "din_arch",
    "dlrm-rm2": "dlrm_rm2",
}


def get_arch(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def list_archs() -> tuple[str, ...]:
    return ARCH_IDS
