"""Architecture configs.

- ``paper_models``: the six models of paper Table I (DLRM-RMC1/2/3, MT-WnD,
  DIN, DIEN) at production and small scale, used by the Hercules benchmarks.
- one module per assigned architecture (``--arch <id>``), each exposing
  ``FULL`` (exact assigned dims), ``SMOKE`` (reduced same-family config) and
  ``SHAPES`` (the assigned input-shape cells).
"""
from repro.configs.registry import get_arch, list_archs

__all__ = ["get_arch", "list_archs"]
