"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=151936, MoE 60e top-4 — 4 shared + 60 routed top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]. The 4 shared experts are fused into one
SwiGLU of width 4x1408 = 5632 (hf shared_expert_intermediate_size)."""
import dataclasses

import jax.numpy as jnp

from repro.common.types import ArchKind
from repro.configs.shapes import LM_SHAPES
from repro.models.layers import MoEConfig
from repro.models.transformer import LMConfig

ARCH_ID = "qwen2-moe-a2.7b"
KIND = ArchKind.LM_MOE
SHAPES = LM_SHAPES

FULL = LMConfig(
    name=ARCH_ID,
    # §Perf optimized defaults (baseline in artifacts/roofline/*baseline*):
    # int8 KV cache (2x decode bytes). Chunked attention kept OFF for
    # this arch: the HLO cost model (blind to VMEM residency) measures
    # it as a net memory regression here — see EXPERIMENTS.md §Perf.
    kv_quant="int8",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=151936,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    moe=MoEConfig(
        d_model=2048,
        d_ff=1408,
        n_experts=60,
        top_k=4,
        n_shared=4,
        shared_d_ff=5632,
    ),
)

SMOKE = LMConfig(
    name=ARCH_ID + "-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=32,
    vocab=512,
    head_dim=16,
    qkv_bias=True,
    moe=MoEConfig(d_model=64, d_ff=32, n_experts=6, top_k=2, n_shared=1,
                  shared_d_ff=64),
    dtype=jnp.float32,
)
