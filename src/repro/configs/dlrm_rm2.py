"""dlrm-rm2 [recsys]: n_dense=13 n_sparse=26 embed_dim=64
bot_mlp=13-512-256-64 top_mlp=512-512-256-1 interaction=dot
[arXiv:1906.00091; paper].

Multi-hot pooling 64 per table (DLRM-class production lookups 20-160);
26 tables x 5M rows x 64 = 33 GB f32 -> row-wise sharded. This is the
arch most representative of the paper's technique (SparseNet-dominated)."""
import jax.numpy as jnp

from repro.common.types import ArchKind
from repro.configs.shapes import RECSYS_SHAPES
from repro.models.embedding import EmbeddingConfig
from repro.models.recsys_base import RecsysConfig

ARCH_ID = "dlrm-rm2"
KIND = ArchKind.RECSYS
SHAPES = RECSYS_SHAPES
SLA_MS = 50.0

FULL = RecsysConfig(
    name=ARCH_ID,
    embedding=EmbeddingConfig(
        vocab_sizes=(5_000_000,) * 26, dim=64, pooling=(64,) * 26,
        dtype=jnp.bfloat16,  # §Perf iteration: bf16 tables halve the
        # gather traffic and the Psum/gradient all-reduce wire bytes
        # (row-wise AdaGrad keeps an f32 accumulator per row).
    ),
    n_dense=13,
    bottom_mlp=(512, 256, 64),
    top_mlp=(512, 512, 256),
    interaction="dot",
    dtype=jnp.bfloat16,
)

SMOKE = RecsysConfig(
    name=ARCH_ID + "-smoke",
    embedding=EmbeddingConfig(vocab_sizes=(1000,) * 4, dim=16, pooling=(8,) * 4),
    n_dense=13,
    bottom_mlp=(32, 16),
    top_mlp=(64, 32),
    interaction="dot",
)
