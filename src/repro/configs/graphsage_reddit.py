"""graphsage-reddit [gnn]: n_layers=2 d_hidden=128 aggregator=mean
sample_sizes=25-10 [arXiv:1706.02216; paper].

The four shape cells change the execution mode (and d_feat/n_classes):
full_graph_sm is cora-scale (d_feat 1433, 7 classes), minibatch_lg is
reddit (602 feats, 41 classes, fanout 15-10 per the shape), ogb_products
is full-batch at 2.45M nodes (100 feats, 47 classes), molecule is
graph-classification over packed small graphs."""
import jax.numpy as jnp

from repro.common.types import ArchKind
from repro.configs.shapes import GNN_SHAPES
from repro.models.gnn import GNNConfig

ARCH_ID = "graphsage-reddit"
KIND = ArchKind.GNN
SHAPES = GNN_SHAPES

FULL = GNNConfig(
    name=ARCH_ID,
    d_feat=602,
    d_hidden=128,
    n_layers=2,
    n_classes=41,
    aggregator="mean",
    fanout=(25, 10),
    mode="mini",
)

# per-shape variants (mode/d_feat/classes depend on the dataset cell)
SHAPE_CONFIGS = {
    "full_graph_sm": GNNConfig(
        name=ARCH_ID, d_feat=1433, d_hidden=128, n_layers=2, n_classes=7,
        aggregator="mean", mode="full"),
    "minibatch_lg": GNNConfig(
        name=ARCH_ID, d_feat=602, d_hidden=128, n_layers=2, n_classes=41,
        aggregator="mean", fanout=(15, 10), mode="mini"),
    "ogb_products": GNNConfig(
        name=ARCH_ID, d_feat=100, d_hidden=128, n_layers=2, n_classes=47,
        aggregator="mean", mode="full"),
    "molecule": GNNConfig(
        name=ARCH_ID, d_feat=64, d_hidden=128, n_layers=2, n_classes=2,
        aggregator="mean", mode="batched", readout="graph"),
}

SMOKE = GNNConfig(
    name=ARCH_ID + "-smoke", d_feat=16, d_hidden=32, n_layers=2, n_classes=5,
    aggregator="mean", fanout=(5, 3), mode="mini")
