"""Assigned input-shape cells per architecture family."""
from __future__ import annotations

from repro.common.types import ShapeSpec

LM_SHAPES = (
    ShapeSpec("train_4k", "train", {"seq_len": 4096, "global_batch": 256}),
    ShapeSpec("prefill_32k", "prefill", {"seq_len": 32768, "global_batch": 32}),
    ShapeSpec("decode_32k", "decode", {"seq_len": 32768, "global_batch": 128}),
    ShapeSpec("long_500k", "decode", {"seq_len": 524288, "global_batch": 1}),
)

GNN_SHAPES = (
    ShapeSpec("full_graph_sm", "train",
              {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433}),
    ShapeSpec("minibatch_lg", "train",
              {"n_nodes": 232965, "n_edges": 114615892, "batch_nodes": 1024,
               "fanout": (15, 10), "d_feat": 602}),
    ShapeSpec("ogb_products", "train",
              {"n_nodes": 2449029, "n_edges": 61859140, "d_feat": 100}),
    ShapeSpec("molecule", "train",
              {"n_nodes": 30, "n_edges": 64, "batch": 128, "d_feat": 64}),
)

RECSYS_SHAPES = (
    ShapeSpec("train_batch", "train", {"batch": 65536}),
    ShapeSpec("serve_p99", "serve", {"batch": 512}),
    ShapeSpec("serve_bulk", "serve", {"batch": 262144}),
    ShapeSpec("retrieval_cand", "serve", {"batch": 1, "n_candidates": 1_000_000}),
)


def shapes_for(kind: str):
    return {"lm": LM_SHAPES, "gnn": GNN_SHAPES, "recsys": RECSYS_SHAPES}[kind]
