"""mind [recsys]: embed_dim=64 n_interests=4 capsule_iters=3
interaction=multi-interest [arXiv:1904.08030; unverified].

Retrieval model: item table at 10M ids; user history length 64. The
retrieval_cand shape scores one user's 4 interests against 1e6 candidates
with a single [K, D] x [D, N] matmul."""
import jax.numpy as jnp

from repro.common.types import ArchKind
from repro.configs.shapes import RECSYS_SHAPES
from repro.models.embedding import EmbeddingConfig
from repro.models.recsys_base import RecsysConfig

ARCH_ID = "mind"
KIND = ArchKind.RECSYS
SHAPES = RECSYS_SHAPES
SLA_MS = 50.0

FULL = RecsysConfig(
    name=ARCH_ID,
    embedding=EmbeddingConfig(
        vocab_sizes=(10_000_000, 1_000_000), dim=64, pooling=(1, 1)
    ),
    seq_len=64,
    n_interests=4,
    capsule_iters=3,
    interaction="multi-interest",
)

SMOKE = RecsysConfig(
    name=ARCH_ID + "-smoke",
    embedding=EmbeddingConfig(vocab_sizes=(10_000, 1_000), dim=16, pooling=(1, 1)),
    seq_len=12,
    n_interests=4,
    capsule_iters=3,
    interaction="multi-interest",
)
