"""din [recsys]: embed_dim=18 seq_len=100 attn_mlp=80-40 mlp=200-80
interaction=target-attn [arXiv:1706.06978; paper].

Three tables per the DIN paper (goods/user/context); the goods table at
Alibaba scale (600M ids) uses the quotient-remainder trick in the FULL
config so its physical storage stays shardable (~(600M/65536 + 65536) rows).
"""
import jax.numpy as jnp

from repro.common.types import ArchKind
from repro.configs.shapes import RECSYS_SHAPES
from repro.models.embedding import EmbeddingConfig
from repro.models.recsys_base import RecsysConfig

ARCH_ID = "din"
KIND = ArchKind.RECSYS
SHAPES = RECSYS_SHAPES
SLA_MS = 50.0

FULL = RecsysConfig(
    name=ARCH_ID,
    embedding=EmbeddingConfig(
        vocab_sizes=(600_000_000, 1_000_000, 100_000),
        dim=18,
        pooling=(1, 1, 1),
        qr_features=(0,),
        qr_buckets=65536,
    ),
    seq_len=100,
    attn_mlp=(80, 40),
    top_mlp=(200, 80),
    interaction="target-attn",
)

SMOKE = RecsysConfig(
    name=ARCH_ID + "-smoke",
    embedding=EmbeddingConfig(
        vocab_sizes=(10_000, 1_000, 100), dim=18, pooling=(1, 1, 1)
    ),
    seq_len=10,
    attn_mlp=(80, 40),
    top_mlp=(200, 80),
    interaction="target-attn",
)
