"""wide-deep [recsys]: n_sparse=40 embed_dim=32 mlp=1024-512-256
interaction=concat [arXiv:1606.07792; paper].

Classic Wide&Deep uses one-hot categorical features (pooling=1); tables at
production scale (2M rows each -> 2.56 GB at f32, sharded row-wise over the
model axis)."""
import jax.numpy as jnp

from repro.common.types import ArchKind
from repro.configs.shapes import RECSYS_SHAPES
from repro.models.embedding import EmbeddingConfig
from repro.models.recsys_base import RecsysConfig

ARCH_ID = "wide-deep"
KIND = ArchKind.RECSYS
SHAPES = RECSYS_SHAPES
SLA_MS = 50.0

FULL = RecsysConfig(
    name=ARCH_ID,
    embedding=EmbeddingConfig(
        vocab_sizes=(2_000_000,) * 40, dim=32, pooling=(1,) * 40
    ),
    n_dense=13,
    top_mlp=(1024, 512, 256),
    interaction="concat",
)

SMOKE = RecsysConfig(
    name=ARCH_ID + "-smoke",
    embedding=EmbeddingConfig(vocab_sizes=(1000,) * 6, dim=8, pooling=(1,) * 6),
    n_dense=13,
    top_mlp=(64, 32),
    interaction="concat",
)
