"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (GQA kv=16) d_ff=1024
vocab=50304, MoE 64e top-8 — 64 experts top-8 [arXiv:2409.02060]."""
import dataclasses

import jax.numpy as jnp

from repro.common.types import ArchKind
from repro.configs.shapes import LM_SHAPES
from repro.models.layers import MoEConfig
from repro.models.transformer import LMConfig

ARCH_ID = "olmoe-1b-7b"
KIND = ArchKind.LM_MOE
SHAPES = LM_SHAPES

FULL = LMConfig(
    name=ARCH_ID,
    # §Perf optimized defaults (baseline in artifacts/roofline/*baseline*):
    # int8 KV cache (2x decode bytes). Chunked attention kept OFF for
    # this arch: the HLO cost model (blind to VMEM residency) measures
    # it as a net memory regression here — see EXPERIMENTS.md §Perf.
    kv_quant="int8",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab=50304,
    head_dim=128,
    rope_theta=10_000.0,
    moe=MoEConfig(d_model=2048, d_ff=1024, n_experts=64, top_k=8),
)

SMOKE = LMConfig(
    name=ARCH_ID + "-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=32,
    vocab=512,
    head_dim=16,
    moe=MoEConfig(d_model=64, d_ff=32, n_experts=8, top_k=2),
    dtype=jnp.float32,
)
