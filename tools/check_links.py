#!/usr/bin/env python
"""Relative-link checker for the docs tree (CI gate).

Usage: python tools/check_links.py README.md docs [more files/dirs...]

Scans markdown files for inline links/images ``[text](target)`` and fails
if a relative target does not resolve on disk (anchors are stripped;
absolute URLs and mailto/anchor-only links are skipped).
"""
from __future__ import annotations

import pathlib
import re
import sys

LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")
SKIP = ("http://", "https://", "mailto:", "#")


def md_files(args: list[str]) -> list[pathlib.Path]:
    out: list[pathlib.Path] = []
    for a in args:
        p = pathlib.Path(a)
        if p.is_dir():
            out.extend(sorted(p.rglob("*.md")))
        elif p.exists():
            out.append(p)
        else:
            print(f"check_links: no such file or directory: {a}")
            sys.exit(2)
    return out


def main(args: list[str]) -> int:
    bad: list[str] = []
    n_links = 0
    for f in md_files(args or ["README.md", "docs"]):
        for m in LINK.finditer(f.read_text()):
            target = m.group(1)
            if target.startswith(SKIP):
                continue
            n_links += 1
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if not (f.parent / rel).exists():
                bad.append(f"{f}: broken link -> {target}")
    for b in bad:
        print(b)
    print(f"check_links: {n_links} relative links, {len(bad)} broken")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
