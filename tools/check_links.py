#!/usr/bin/env python
"""Docs-tree checker for the docs CI gate.

Usage: python tools/check_links.py README.md docs [more files/dirs...]

Three checks, all against the working tree:

- **links**: scans markdown files for inline links/images
  ``[text](target)`` and fails if a relative target does not resolve on
  disk (anchors are stripped; absolute URLs and mailto/anchor-only links
  are skipped).
- **architecture staleness**: every module under ``src/repro/serving/``
  and ``src/repro/core/`` must appear (by name) in
  ``docs/ARCHITECTURE.md``'s module map — a new serving/core module
  cannot land undocumented.
- **docs index**: every ``docs/*.md`` file must be linked from the
  ``docs/README.md`` landing page, so the reading order stays complete.
"""
from __future__ import annotations

import pathlib
import re
import sys

LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")
SKIP = ("http://", "https://", "mailto:", "#")

# Packages whose every module must be named in docs/ARCHITECTURE.md.
DOCUMENTED_PACKAGES = ("src/repro/serving", "src/repro/core")


def md_files(args: list[str]) -> list[pathlib.Path]:
    out: list[pathlib.Path] = []
    for a in args:
        p = pathlib.Path(a)
        if p.is_dir():
            out.extend(sorted(p.rglob("*.md")))
        elif p.exists():
            out.append(p)
        else:
            print(f"check_links: no such file or directory: {a}")
            sys.exit(2)
    return out


def check_links(files: list[pathlib.Path]) -> tuple[int, list[str]]:
    bad: list[str] = []
    n_links = 0
    for f in files:
        for m in LINK.finditer(f.read_text()):
            target = m.group(1)
            if target.startswith(SKIP):
                continue
            n_links += 1
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if not (f.parent / rel).exists():
                bad.append(f"{f}: broken link -> {target}")
    return n_links, bad


def check_architecture(root: pathlib.Path) -> list[str]:
    """Every serving/core module must appear in ARCHITECTURE.md's map."""
    arch = root / "docs" / "ARCHITECTURE.md"
    if not arch.exists():
        return [f"{arch}: missing (architecture staleness check)"]
    text = arch.read_text()
    bad: list[str] = []
    for pkg in DOCUMENTED_PACKAGES:
        for mod in sorted((root / pkg).glob("*.py")):
            stem = mod.stem
            if stem == "__init__":
                continue
            # match "core/colocation.py" or the bare module name
            short = f"{pathlib.Path(pkg).name}/{stem}"
            if short not in text and stem not in text:
                bad.append(
                    f"{arch}: stale module map -> {mod.relative_to(root)} "
                    f"not mentioned")
    return bad


def check_docs_index(root: pathlib.Path) -> list[str]:
    """Every docs/*.md must be linked from the docs/README.md index."""
    index = root / "docs" / "README.md"
    if not index.exists():
        return [f"{index}: missing (docs index check)"]
    linked = {m.group(1).split("#", 1)[0]
              for m in LINK.finditer(index.read_text())}
    bad: list[str] = []
    for doc in sorted((root / "docs").glob("*.md")):
        if doc.name == "README.md":
            continue
        if doc.name not in linked:
            bad.append(f"{index}: docs index missing link -> {doc.name}")
    return bad


def main(args: list[str]) -> int:
    n_links, bad = check_links(md_files(args or ["README.md", "docs"]))
    root = pathlib.Path(__file__).resolve().parent.parent
    bad += check_architecture(root)
    bad += check_docs_index(root)
    for b in bad:
        print(b)
    print(f"check_links: {n_links} relative links, {len(bad)} problems")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
