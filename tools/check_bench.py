#!/usr/bin/env python
"""Bench-regression gate (CI `bench-gate` job).

Compares freshly-run smoke benchmark outputs against checked-in baselines
with explicit tolerances, and validates the invariants behind the repo's
headline claims, so a PR that quietly regresses the serving stack fails in
CI rather than in the next full bench regeneration:

- ``--smoke-json`` (from ``benchmarks/bench_cluster.py --smoke``) vs
  ``--baseline`` (``benchmarks/baselines/BENCH_cluster_smoke.json``):
  hercules must stay feasible, meet every workload's SLA in every
  measured interval, and beat greedy on peak provisioned power; power and
  attainment metrics must stay within tolerance of the baseline.  The
  simulation is seeded + CRN, so these numbers are deterministic — the
  tolerances absorb float-library drift, not noise.  The same record
  carries the event-ordered core gates: kernel speedup floors over the
  scalar sweep (bitwise-checked by the bench before timing) and the
  full-interval event-core day staying feasible while simulating strictly
  more of each workload's arrivals than the bridged windows.
- ``--search-csv`` (from ``benchmarks/bench_gradient_search.py --smoke``):
  the gradient search must stay near-optimal and meaningfully cheaper
  than exhaustive.  Wall-clock ratios on shared CI runners are noisy, so
  the speedup floor is deliberately loose — the 10-minute job timeout is
  the real wall-clock budget.
- ``--full-json`` (the checked-in ``BENCH_cluster.json``): consistency of
  the committed full-run record — the savings claim is validated at query
  granularity and the SLA-over-the-day series is present and clean.
- ``--budget-seconds`` + ``--timing name=seconds`` (one per smoke bench,
  measured by the CI step around each run): every bench must finish under
  the wall budget, so a silent engine slowdown fails the gate even when
  every metric still matches its baseline.  The budget is loose (~5x the
  measured smoke time) because shared runners are noisy; it exists to
  catch order-of-magnitude regressions, not percent-level drift.

Exit code 0 = all gates green; 1 = regression (each failure is printed).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

# Tolerances (explicit, documented):
POWER_RTOL = 0.02        # relative drift allowed on provisioned power
SAVING_ATOL = 0.02       # absolute drift on the hercules-vs-greedy saving
ATTAIN_ATOL = 0.02       # absolute drop allowed on day-level attainment
INTERVAL_ATTAIN_ATOL = 0.05  # absolute drop on the worst interval
MIN_OPTIMALITY = 0.93    # gradient search vs exhaustive (measured: 95.1%)
MIN_SEARCH_SPEEDUP = 1.5  # gradient vs exhaustive wall-clock (loose)
# event-core kernels vs the scalar sweep at 1e5 jobs (bitwise-checked by
# the bench before timing).  The saturated record is the headline
# (measured: 7.9x); the fleet record is end-to-end incl. packing and
# host<->XLA copies (measured: 3.2x), floored loosely for runner noise.
MIN_EVENT_SAT_SPEEDUP = 5.0
MIN_EVENT_FLEET_SPEEDUP = 2.0
# The scenario zoo the bench must report as registered
# (repro.serving.scenarios): silently dropping one from the registry —
# and with it from the scenario-matrix test suite — fails the gate.
EXPECTED_SCENARIOS = (
    "baseline_day",
    "colo_complements",
    "colo_recsys_lm",
    "failure_day",
    "flash_crowd",
    "geo_3region",
    "geo_drain",
    "geo_hetero_pools",
    "geo_partition",
    "hedge_storm",
    "model_push_midpeak",
    "phase_shifted",
)
# Geo day gates: follow-the-sun must beat per-region-isolated on global
# peak provisioned power by actually spilling load, with every origin
# region's SLA met in every interval (spilled queries judged with their
# link RTT added).  The wall budget is loose — the geo day is two
# smoke-sized serving runs; it catches order-of-magnitude regressions.
MIN_GEO_POWER_WIN = 0.0
MAX_GEO_WALL_S = 300.0
# Co-location day gates: interference-aware multi-tenant packing must
# beat the single-tenant Hercules packing of the same inputs on peak
# provisioned power, by actually provisioning shared machines, with every
# tenant meeting its SLA in every measured interval (the dilated duration
# tables make an SLA-blind win impossible to fake).
MIN_COLO_POWER_WIN = 0.0
MAX_COLO_WALL_S = 300.0

_failures: list[str] = []


def check(ok: bool, what: str, detail: str = "") -> None:
    mark = "ok  " if ok else "FAIL"
    print(f"[{mark}] {what}" + (f"  ({detail})" if detail else ""))
    if not ok:
        _failures.append(what)


def _load(path: str) -> dict:
    return json.loads(pathlib.Path(path).read_text())


# ---------------------------------------------------------------------------
# cluster smoke vs baseline
# ---------------------------------------------------------------------------


def check_cluster_smoke(smoke_path: str, baseline_path: str) -> None:
    got = _load(smoke_path)
    base = _load(baseline_path)

    h = got["policies"]["hercules"]
    g = got["policies"]["greedy"]
    check(h["feasible"], "hercules smoke day feasible")
    check(h["all_meet_sla"], "hercules meets every workload SLA (day level)")
    check(g["all_meet_sla"], "greedy meets every workload SLA (day level)")
    check(got["savings"]["validated_at_query_granularity"],
          "savings validated at query granularity")
    check(got["savings"]["hercules_all_intervals_meet_sla"],
          "hercules meets SLA in every measured interval (Fig. 8b gate)")
    check(h["peak_power_w"] < g["peak_power_w"],
          "hercules beats greedy on peak provisioned power",
          f"{h['peak_power_w']:.0f}W vs {g['peak_power_w']:.0f}W")

    s_got = got["savings"]["hercules_vs_greedy_power_peak"]
    s_base = base["savings"]["hercules_vs_greedy_power_peak"]
    check(abs(s_got - s_base) <= SAVING_ATOL,
          "peak power saving within tolerance of baseline",
          f"got {s_got:.3f}, baseline {s_base:.3f}, atol {SAVING_ATOL}")

    for pol in ("greedy", "hercules"):
        p_got = got["policies"][pol]["peak_power_w"]
        p_base = base["policies"][pol]["peak_power_w"]
        check(abs(p_got - p_base) <= POWER_RTOL * p_base,
              f"{pol} peak power within {POWER_RTOL:.0%} of baseline",
              f"got {p_got:.0f}W, baseline {p_base:.0f}W")
        for name, w_base in base["policies"][pol]["workloads"].items():
            w_got = got["policies"][pol]["workloads"][name]
            check(w_got["sla_attainment"] >=
                  w_base["sla_attainment"] - ATTAIN_ATOL,
                  f"{pol}/{name} day attainment no worse than baseline",
                  f"got {w_got['sla_attainment']:.4f}, "
                  f"baseline {w_base['sla_attainment']:.4f}")
    for name, s in got["policies"]["hercules"]["sla_over_day"].items():
        vals = [a for a in s["sla_attainment"] if a is not None]
        base_s = base["policies"]["hercules"]["sla_over_day"][name]
        base_vals = [a for a in base_s["sla_attainment"] if a is not None]
        check(len(vals) > 0 and
              min(vals) >= min(base_vals) - INTERVAL_ATTAIN_ATOL,
              f"hercules/{name} worst-interval attainment within tolerance",
              f"got {min(vals):.4f}, baseline {min(base_vals):.4f}")

    check_event_core(got)
    check_scenario_registry(got)
    check_geo(got)
    check_colo(got)


def check_geo(got: dict) -> None:
    """Geo-day gates: the 3-region follow-the-sun run must beat the
    per-region-isolated baseline on global peak provisioned power via a
    non-trivial spill, while staying feasible with every origin region's
    workloads meeting SLA in every interval — spilled queries carry their
    inter-region link RTT, so a win bought by blowing the tail of spilled
    traffic cannot pass."""
    geo = got.get("geo_day")
    check(geo is not None, "bench emits a geo_day record")
    if geo is None:
        return
    fs, iso = geo["follow_sun"], geo["isolated"]
    check(fs["feasible"], "geo follow-the-sun day feasible")
    check(fs["all_meet_sla"],
          "geo follow-the-sun: every origin meets SLA (day level)")
    check(fs["all_intervals_meet_sla"],
          "geo follow-the-sun: every origin meets SLA every interval")
    check(fs["n_spilled"] > 0,
          "geo follow-the-sun actually spills queries across regions",
          f"n_spilled={fs['n_spilled']}")
    win = geo["follow_sun_vs_isolated_power_peak"]
    check(win > MIN_GEO_POWER_WIN,
          "follow-the-sun beats isolated on global peak power",
          f"win={win:.3f} ({fs['peak_power_w']:.0f}W vs "
          f"{iso['peak_power_w']:.0f}W)")
    check(geo["wall_s"] <= MAX_GEO_WALL_S,
          f"geo day within {MAX_GEO_WALL_S:.0f}s wall budget",
          f"took {geo['wall_s']:.1f}s")


def check_colo(got: dict) -> None:
    """Co-location day gates: the recsys+LM co-located day must beat the
    single-tenant packing of the same compiled inputs on peak provisioned
    power by actually provisioning shared machines, while every tenant
    meets its SLA in every measured interval — a win bought by blowing a
    co-resident tenant's tail cannot pass."""
    colo = got.get("colo_day")
    check(colo is not None, "bench emits a colo_day record")
    if colo is None:
        return
    rc, rs = colo["colocated"], colo["single_tenant"]
    check(rc["feasible"], "colo day feasible")
    check(rs["feasible"], "single-tenant comparison day feasible")
    check(rc["all_meet_sla"],
          "colo day: every tenant meets SLA (day level)")
    for name, w in rc["per_workload"].items():
        check(w["interval_sla_met_frac"] == 1.0,
              f"colo day: {name} meets SLA every measured interval",
              f"met_frac={w['interval_sla_met_frac']:.3f}")
    shared = sum(1 for c in colo["co_capacity"] if c > 0)
    check(shared > 0,
          "colo day actually provisions shared machines",
          f"shared-machine intervals={shared}")
    win = colo["colocated_vs_single_power_peak"]
    check(win > MIN_COLO_POWER_WIN,
          "co-located beats single-tenant on peak provisioned power",
          f"win={win:.3f} ({rc['peak_power_w']:.0f}W vs "
          f"{rs['peak_power_w']:.0f}W)")
    check(colo["wall_s"] <= MAX_COLO_WALL_S,
          f"colo day within {MAX_COLO_WALL_S:.0f}s wall budget",
          f"took {colo['wall_s']:.1f}s")


def check_scenario_registry(got: dict) -> None:
    """The bench records the registered scenario zoo; every expected
    scenario must still be there (the matrix suite parametrizes over the
    registry, so a dropped registration silently sheds test coverage)."""
    reg = got.get("scenarios", {}).get("registered")
    check(reg is not None, "bench emits the registered scenario zoo")
    if reg is None:
        return
    missing = [n for n in EXPECTED_SCENARIOS if n not in reg]
    check(not missing, "every expected scenario is registered",
          f"registered={reg}" + (f", missing={missing}" if missing else ""))


def check_event_core(got: dict) -> None:
    """Event-ordered core gates: kernel speedups over the scalar sweep
    (the bench asserts bitwise equality before timing, so these rows
    cannot be won by a wrong kernel) and the full-interval hercules day."""
    ec = got.get("event_core")
    check(ec is not None, "bench emits an event_core record")
    if ec is None:
        return
    sat = ec["kernels"]["saturated"]
    check(sat["speedup"] >= MIN_EVENT_SAT_SPEEDUP,
          f"event core saturated kernel >= {MIN_EVENT_SAT_SPEEDUP:.0f}x "
          f"vs sweep at n={sat['n_jobs']}", f"got {sat['speedup']:.1f}x")
    fl = ec["kernels"]["fleet"]
    check(fl["speedup"] >= MIN_EVENT_FLEET_SPEEDUP,
          f"event core fleet solver >= {MIN_EVENT_FLEET_SPEEDUP:.0f}x vs "
          f"per-stream sweep ({fl['n_streams']} streams)",
          f"got {fl['speedup']:.1f}x (jax={fl['jax']})")
    day = ec["day"]
    check(day["feasible"] and day["all_meet_sla"],
          "event-core hercules day feasible and meets every SLA")
    for name, w in day["workloads"].items():
        check(w["n_queries"] > w["n_queries_bridged_run"],
              f"event-core day simulates more of {name}'s arrivals than "
              "the bridged run",
              f"{w['n_queries']} vs {w['n_queries_bridged_run']}")


# ---------------------------------------------------------------------------
# gradient-search smoke CSV
# ---------------------------------------------------------------------------


def _parse_derived(field: str) -> dict[str, str]:
    return dict(kv.split("=", 1) for kv in field.split(";") if "=" in kv)


def check_search_csv(csv_path: str) -> None:
    rows = [ln.strip() for ln in
            pathlib.Path(csv_path).read_text().splitlines()
            if ln.startswith("alg1_")]
    check(len(rows) > 0, "search smoke CSV has alg1_* rows", csv_path)
    for ln in rows:
        name, _, derived = ln.split(",", 2)
        kv = _parse_derived(derived)
        opt = float(kv["optimality"].rstrip("%")) / 100.0
        speedup = float(kv["search_speedup"].rstrip("x"))
        check(opt >= MIN_OPTIMALITY,
              f"{name}: gradient search optimality >= "
              f"{MIN_OPTIMALITY:.0%}", f"got {opt:.1%}")
        check(speedup >= MIN_SEARCH_SPEEDUP,
              f"{name}: search speedup >= {MIN_SEARCH_SPEEDUP}x vs "
              "exhaustive", f"got {speedup:.1f}x")


# ---------------------------------------------------------------------------
# wall-clock budgets
# ---------------------------------------------------------------------------


def check_wall_budgets(budget_s: float, timings: list[str]) -> None:
    check(len(timings) > 0, "wall budget given with at least one --timing")
    for t in timings:
        name, _, secs = t.partition("=")
        try:
            wall = float(secs)
        except ValueError:
            check(False, f"{name}: unparsable --timing value", repr(secs))
            continue
        check(wall <= budget_s,
              f"{name}: wall clock within {budget_s:.0f}s budget",
              f"took {wall:.0f}s")


# ---------------------------------------------------------------------------
# committed full-run record consistency
# ---------------------------------------------------------------------------


def check_full_record(full_path: str) -> None:
    full = _load(full_path)
    check(full["savings"]["validated_at_query_granularity"],
          "committed BENCH_cluster.json: savings validated")
    check(full["savings"]["hercules_vs_greedy_power_peak"] > 0.0,
          "committed BENCH_cluster.json: positive peak power saving",
          f"{full['savings']['hercules_vs_greedy_power_peak']:.3f}")
    check(full["savings"].get("hercules_all_intervals_meet_sla", False),
          "committed BENCH_cluster.json: SLA met over the whole day")
    n_steps = full["n_steps"]
    for pol, p in full["policies"].items():
        sod = p.get("sla_over_day", {})
        check(set(sod) == set(p["workloads"]),
              f"committed record: {pol} has a per-workload SLA series")
        for name, s in sod.items():
            check(len(s["sla_attainment"]) == n_steps,
                  f"committed record: {pol}/{name} series spans the day",
                  f"{len(s['sla_attainment'])} vs {n_steps} intervals")
    check_event_core(full)
    check_geo(full)
    check_colo(full)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke-json", help="fresh bench_cluster --smoke output")
    ap.add_argument("--baseline",
                    default="benchmarks/baselines/BENCH_cluster_smoke.json",
                    help="checked-in smoke baseline to compare against")
    ap.add_argument("--search-csv",
                    help="fresh bench_gradient_search --smoke CSV")
    ap.add_argument("--full-json",
                    help="committed BENCH_cluster.json to sanity-check")
    ap.add_argument("--budget-seconds", type=float,
                    help="per-bench wall-clock budget asserted over every "
                         "--timing")
    ap.add_argument("--timing", action="append", default=[],
                    metavar="NAME=SECONDS",
                    help="measured wall clock of one smoke bench "
                         "(repeatable; requires --budget-seconds)")
    args = ap.parse_args()
    if not (args.smoke_json or args.search_csv or args.full_json
            or args.budget_seconds):
        ap.error("nothing to check: pass --smoke-json, --search-csv, "
                 "--full-json and/or --budget-seconds")
    if args.timing and args.budget_seconds is None:
        ap.error("--timing requires --budget-seconds")
    if args.smoke_json:
        check_cluster_smoke(args.smoke_json, args.baseline)
    if args.search_csv:
        check_search_csv(args.search_csv)
    if args.full_json:
        check_full_record(args.full_json)
    if args.budget_seconds is not None:
        check_wall_budgets(args.budget_seconds, args.timing)
    if _failures:
        print(f"\n{len(_failures)} bench gate(s) FAILED:")
        for f in _failures:
            print(f"  - {f}")
        return 1
    print("\nall bench gates green")
    return 0


if __name__ == "__main__":
    sys.exit(main())
