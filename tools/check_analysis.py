#!/usr/bin/env python
"""Static-analysis baseline gate (CI ``static-analysis`` job).

Diffs a fresh ``python -m repro.analysis --json`` report against the
checked-in baseline (``tools/analysis_baseline.json``) so a PR that
introduces a *new* finding fails even when the baseline is non-empty —
a grandfathered finding must never camouflage a fresh one.

Findings are fingerprinted as ``(file, rule, message)`` — line numbers are
deliberately excluded so unrelated edits shifting a grandfathered finding
up or down don't churn the baseline.  Semantics:

- a report finding whose fingerprint is not in the baseline: **new** ->
  exit 1 (fix it or suppress it with a justified ``# repro: ignore[rule]``);
- a baseline entry with no matching report finding: **stale** -> exit 1
  (the debt was paid; shrink the baseline with ``--update`` so it can't
  regress silently).

``--update`` rewrites the baseline from the current report.  The baseline
starts — and should stay — empty; it exists so an unavoidable future
finding (e.g. a rule tightened ahead of a planned refactor) can be landed
without turning the gate off.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys


def fingerprint(finding: dict) -> tuple:
    return (finding["file"], finding["rule"], finding["message"])


def load_baseline(path: pathlib.Path) -> list[dict]:
    if not path.exists():
        return []
    data = json.loads(path.read_text())
    return data["findings"] if isinstance(data, dict) else data


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument(
        "--report", required=True,
        help="JSON report from python -m repro.analysis --json",
    )
    parser.add_argument(
        "--baseline", default="tools/analysis_baseline.json",
        help="checked-in baseline of grandfathered findings",
    )
    parser.add_argument(
        "--update", action="store_true",
        help="rewrite the baseline from the current report and exit 0",
    )
    args = parser.parse_args(argv)

    report_path = pathlib.Path(args.report)
    baseline_path = pathlib.Path(args.baseline)
    report = json.loads(report_path.read_text())
    findings = report.get("findings", []) + report.get("errors", [])

    if args.update:
        baseline_path.write_text(
            json.dumps({"findings": findings}, indent=2, sort_keys=True)
            + "\n"
        )
        print(f"baseline updated: {len(findings)} finding(s)")
        return 0

    baseline = load_baseline(baseline_path)
    base_fps = {fingerprint(f) for f in baseline}
    seen_fps = {fingerprint(f) for f in findings}

    new = [f for f in findings if fingerprint(f) not in base_fps]
    stale = [f for f in baseline if fingerprint(f) not in seen_fps]

    for f in new:
        print(
            f"NEW: {f['file']}:{f.get('line', '?')}: {f['rule']}: "
            f"{f['message']}"
        )
    for f in stale:
        print(
            f"STALE baseline entry (fixed — run --update): "
            f"{f['file']}: {f['rule']}"
        )

    n_grandfathered = len(findings) - len(new)
    print(
        f"check_analysis: {len(new)} new, {n_grandfathered} grandfathered, "
        f"{len(stale)} stale (baseline: {len(base_fps)})"
    )
    return 1 if new or stale else 0


if __name__ == "__main__":
    sys.exit(main())
