"""End-to-end training driver: a ~100M-parameter DLRM on the synthetic
click-log pipeline for a few hundred steps, with fault-tolerant
checkpointing (kill it mid-run and re-invoke: it resumes).

Run:  PYTHONPATH=src python examples/train_dlrm.py [--steps 300]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.clicklog import ClickLogGenerator
from repro.launch.steps import CellProgram, build_cell
from repro.models.embedding import EmbeddingConfig
from repro.models.recsys_base import RecsysConfig, binary_ce
from repro.models import dlrm
from repro.train import optimizer as opt_lib
from repro.train.trainer import Trainer, TrainerConfig


def make_model():
    """~100M params: dominated by 8 x 400k x 32 embedding tables."""
    return RecsysConfig(
        name="dlrm-100m",
        embedding=EmbeddingConfig(vocab_sizes=(400_000,) * 8, dim=32,
                                  pooling=(16,) * 8),
        n_dense=13,
        bottom_mlp=(256, 128, 32),
        top_mlp=(256, 128),
        interaction="dot",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=1024)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_dlrm_ckpt")
    args = ap.parse_args()

    cfg = make_model()
    opt = opt_lib.rowwise_adagrad(lr=0.02)

    def step(state, batch):
        def loss_fn(params):
            return binary_ce(dlrm.apply(params, batch, cfg), batch["label"])

        loss, grads = jax.value_and_grad(loss_fn)(state["params"])
        params, opt_state = opt.update(state["params"], grads, state["opt"])
        return {"params": params, "opt": opt_state}, {"loss": loss}

    def init_state(key):
        params = dlrm.init(key, cfg)
        n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
        print(f"model: {n/1e6:.1f}M parameters")
        return {"params": params, "opt": opt.init(params)}

    gen = ClickLogGenerator(cfg, seed=0)

    def batches():
        while True:
            b = gen.batch(args.batch)
            yield jax.tree.map(jnp.asarray, b)

    trainer = Trainer(jax.jit(step), init_state, batches(),
                      TrainerConfig(total_steps=args.steps, ckpt_every=50,
                                    ckpt_dir=args.ckpt_dir, log_every=20))
    state, hist = trainer.run(jax.random.PRNGKey(0))
    print("step  loss")
    for h in hist:
        print(f"{h['step']:5d}  {h['loss']:.4f}  ({h['step_time_s']*1e3:.0f} ms)")
    assert hist[-1]["loss"] < hist[0]["loss"], "loss did not improve"
    print("final loss improved over initial — OK")


if __name__ == "__main__":
    main()
