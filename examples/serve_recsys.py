"""Serving driver: batched-request inference through the Hercules-chosen
task schedule, with the query router's hedging + failover in front.

Serves the small DLRM with REAL JAX execution of fused batches while the
discrete-event layer handles arrivals/fusion — the same split the paper's
prototype uses (real kernels; trace-driven load).

Run:  PYTHONPATH=src python examples/serve_recsys.py [--seconds 5]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_models import paper_profile
from repro.core.devices import SERVER_TYPES
from repro.core.gradient_search import gradient_search
from repro.data.clicklog import ClickLogGenerator
from repro.models import dlrm
from repro.models.embedding import EmbeddingConfig
from repro.models.recsys_base import RecsysConfig
from repro.serving.router import QueryRouter, ServerSlot


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--seconds", type=float, default=5.0)
    ap.add_argument("--qps", type=float, default=60.0)
    args = ap.parse_args()

    # the servable model (small tables so this host executes for real)
    cfg = RecsysConfig(
        name="dlrm-serve",
        embedding=EmbeddingConfig(vocab_sizes=(100_000,) * 8, dim=32,
                                  pooling=(16,) * 8),
        n_dense=13, bottom_mlp=(256, 128, 32), top_mlp=(256, 128),
        interaction="dot",
    )
    params = dlrm.init(jax.random.PRNGKey(0), cfg)
    apply_jit = jax.jit(lambda p, b: dlrm.apply(p, b, cfg))
    gen = ClickLogGenerator(cfg, seed=1)

    # offline stage: pick the schedule for this workload on this "server"
    prof = paper_profile("dlrm-rmc1")
    res = gradient_search(prof, SERVER_TYPES["T2"],
                          gen.query_sizes(300), o_grid=(1, 2))
    d = res.sched.batch
    print(f"hercules schedule: plan={res.placement.plan} d={d} "
          f"m={res.sched.m} o={res.sched.o}")

    router = QueryRouter([ServerSlot("local", res.qps)])

    # online stage: Poisson arrivals, fuse up to d items per launch
    rng = np.random.default_rng(0)
    t_end = time.time() + args.seconds
    lat, served, items = [], 0, 0
    warm = gen.batch(d, with_labels=False)
    apply_jit(params, jax.tree.map(jnp.asarray, warm))  # compile
    while time.time() < t_end:
        q = int(gen.query_sizes(1)[0])
        t0 = time.time()
        for start in range(0, q, d):
            n = min(d, q - start)
            batch = gen.batch(d, with_labels=False)  # fused launch (padded)
            scores = apply_jit(params, jax.tree.map(jnp.asarray, batch))
            scores.block_until_ready()
        dt = time.time() - t0
        router.observe_latency(dt)
        lat.append(dt)
        served += 1
        items += q
        gap = rng.exponential(1.0 / args.qps)
        time.sleep(max(0.0, gap - dt))
    lat_ms = np.array(lat) * 1e3
    print(f"served {served} queries ({items} items) in {args.seconds:.0f}s")
    print(f"latency p50={np.percentile(lat_ms, 50):.1f}ms "
          f"p95={np.percentile(lat_ms, 95):.1f}ms "
          f"p99={np.percentile(lat_ms, 99):.1f}ms")


if __name__ == "__main__":
    main()
