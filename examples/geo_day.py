"""Geo-distributed example: the registered 3-region day served twice —
follow-the-sun (spill load across regions over the inter-region network,
re-provision each region against its post-spill load) vs per-region-isolated
Hercules — and the global fleet peak-power win that de-synchronizing the
regional peaks buys.

The topology is a declaration: ``geo_3region`` puts the same smoke fleet in
us-east / eu-west / ap-south with phase-shifted diurnal curves (see
``repro.serving.scenarios`` and docs/geo_serving.md).  SLA is judged at the
*origin* region — every query spilled from region A and served in region B
carries the A->B link RTT in its served latency.

Run:  PYTHONPATH=src python examples/geo_day.py [--scenario NAME]

``--scenario geo_partition`` severs the eu-west links mid-day;
``--scenario geo_drain`` evacuates ap-south with make-before-break power
accounting (try it with both modes: isolated has nowhere to put the
evacuated load and reports it lost).
"""
import argparse

from repro.serving.scenarios import compile_scenario, get_scenario


def main(scenario: str = "geo_3region"):
    comp = compile_scenario(get_scenario(scenario), verbose=True)
    net = comp.network
    print(f"\nscenario: {scenario}")
    print("regions:", ", ".join(comp.region_names))
    print("links (directed):")
    for (i, j) in net.pairs():
        a, b = net.regions[i], net.regions[j]
        print(f"  {a:>8} -> {b:<8}  rtt={net.rtt_ms[(i, j)]:4.1f}ms  "
              f"cap={net.cap_qps[(i, j)]:,.0f} qps")

    out = {mode: comp.run(mode=mode)
           for mode in ("follow_sun", "isolated")}

    fs, iso = out["follow_sun"], out["isolated"]
    print(f"\n{'mode':<12} {'peak(kW)':>9} {'avg(kW)':>9} {'feasible':>8} "
          f"{'sla':>5} {'every-intv':>10} {'spilled':>8} {'lost qps':>9}")
    for mode, r in out.items():
        print(f"{mode:<12} {r.peak_power_w/1e3:9.1f} {r.avg_power_w/1e3:9.1f}"
              f" {str(r.feasible):>8} {str(r.all_meet_sla):>5} "
              f"{str(r.all_intervals_meet_sla):>10} {r.n_spilled:8d} "
              f"{r.lost_qps_mean:9.1f}")
    win = 1.0 - fs.peak_power_w / iso.peak_power_w
    print(f"\nfollow-the-sun vs isolated global peak power: {win:+.1%}")

    # Where the win comes from: each region's provisioned peak under both
    # modes — post-spill curves flatten every region's local peak.
    print(f"\n{'region':<10} {'iso peak(kW)':>13} {'fs peak(kW)':>12}")
    for name in fs.region_names:
        print(f"{name:<10} {iso.regions[name].peak_power_w/1e3:13.1f} "
              f"{fs.regions[name].peak_power_w/1e3:12.1f}")

    # Origin-view SLA: the numbers that must hold for the win to count —
    # spilled queries are judged with their link RTT added.
    print("\norigin-attributed SLA (follow-the-sun):")
    print(f"{'origin':<10} {'workload':<12} {'sla':>6} {'p99(ms)':>8} "
          f"{'attain':>7} {'spilled':>8}")
    for rname in fs.region_names:
        for wname, w in fs.origin[rname].items():
            print(f"{rname:<10} {wname:<12} {w['sla_ms']:6.0f} "
                  f"{w['p99_ms']:8.2f} {w['sla_attainment']:7.4f} "
                  f"{w['n_spilled']:8d}")
    if fs.events:
        print("\nevents:")
        for e in fs.events:
            print("  ", e)

    # the claims this example exists to demonstrate
    assert fs.feasible and fs.all_meet_sla and fs.all_intervals_meet_sla
    assert fs.lost_qps_mean == 0.0      # follow-the-sun loses nothing
    if scenario == "geo_3region":
        assert win > 0.0, "follow-the-sun must beat isolated on peak power"
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="geo_3region",
                    choices=["geo_3region", "geo_partition", "geo_drain"],
                    help="registered geo scenario to serve")
    main(**vars(ap.parse_args()))
