"""Cluster-scale example: a full day of heterogeneity-aware online serving —
stateful provisioning with hysteresis and transition delays, routed Poisson
query streams served continuously in time (per-slot backlog carries across
provisioning intervals, hedges ride live queues), and node failures
injected mid-day (elastic re-provisioning through the router's health
tracking plus achieved-tail feedback into the hysteresis decision).

The day itself is a declaration: the registered ``failure_day`` scenario
(see ``repro.serving.scenarios`` and docs/scenarios.md) customized with a
harsher failure schedule, lifted to the full paper zoo by ``full_scale``
unless ``--smoke``.

Run:  PYTHONPATH=src python examples/cluster_day.py [--smoke] [--event-core]

``--smoke`` keeps the scenario's registered reduced topology (2 workloads
x 3 server types, short day) so CI can run the full pipeline in seconds.
``--event-core`` re-serves the same day through the batched event-ordered
core (``runtime={"event_core": True}``: whole intervals simulated query by
query, hedges admitted in global event order) and prints the exact p99
next to the bridged approximation's.
"""
import argparse
import dataclasses

from repro.serving.scenarios import (
    Event,
    compile_scenario,
    full_scale,
    get_scenario,
)


def main(smoke: bool = False, event_core: bool = False):
    # The registered failure day uses the benchmark's gentle 1% schedule;
    # this example stresses harder: 2% per server type per interval.
    day = dataclasses.replace(
        get_scenario("failure_day"),
        events=(Event.create("random_failures", fail_prob=0.02, seed=0),))
    if not smoke:
        day = full_scale(day, n_steps=96)
    n_steps = day.n_steps

    # Profiled (workload, server) cells persist under artifacts/profiles/;
    # the first run searches every cell (fast engine), reruns replay from
    # disk (see docs/ARCHITECTURE.md "Offline profiling").
    comp = compile_scenario(day, verbose=True)
    out = comp.run()           # a typed DayResult (attributes, not keys)

    print("\nt     power(kW)  servers  churn")
    for t in range(n_steps):
        if t % max(n_steps // 12, 1) == 0 or out.churn[t]:
            print(f"{t:3d}   {out.power[t]/1e3:8.1f}  "
                  f"{out.capacity[t]:7d}  {out.churn[t]:5d}")
    print("\nevents:")
    for e in out.events:
        print("  ", e)
    print(f"\nday feasible={out.feasible}  "
          f"peak_power={out.peak_power_w/1e3:.1f}kW  "
          f"resolves={out.resolves} holds={out.holds} "
          f"tail_resolves={out.tail_resolves} "
          f"churn={out.total_churn}")
    print(f"{'workload':<12} {'sla':>6} {'p99(ms)':>8} {'attain':>7} "
          f"{'intv_ok':>7} {'hedged':>6} {'retried':>7}")
    for w, d in out.per_workload.items():
        print(f"{w:<12} {d['sla_ms']:6.0f} {d['p99_ms']:8.2f} "
              f"{d['sla_attainment']:7.4f} {d['interval_sla_met_frac']:7.3f} "
              f"{d['n_hedged']:6d} {d['n_retried']:7d}")

    # SLA over the day (Fig. 8b view): worst interval per workload, and the
    # carried-backlog peak — where the continuous-time semantics bite
    print("\nSLA over the day (per-interval series):")
    for w, s in out.series["per_workload"].items():
        idx = [t for t, a in enumerate(s["sla_attainment"]) if a is not None]
        worst_t = min(idx, key=lambda t: s["sla_attainment"][t])
        print(f"  {w:<12} worst interval t={worst_t}: "
              f"attain={s['sla_attainment'][worst_t]:.4f} "
              f"p99={s['p99_ms'][worst_t]:.2f}ms  "
              f"peak_backlog={max(s['backlog_s']):.3f}s")
    assert out.feasible, "day must stay feasible through failures"

    if event_core:
        # Exact vs bridged: the same day with every interval simulated to
        # its boundary (up to the per-interval query cap) instead of a
        # 1500-query window bridged by stationarity.
        cap = 20_000 if smoke else 200_000
        exact = compile_scenario(dataclasses.replace(
            day, runtime={"event_core": True,
                          "event_core_queries": cap})).run()
        assert exact.feasible
        print(f"\nevent core (exact, <= {cap} queries/interval) vs "
              "bridged windows:")
        print(f"{'workload':<12} {'queries':>10} {'(bridged)':>10} "
              f"{'p99 exact':>10} {'(bridged)':>10} {'delta':>8}")
        for w, d in exact.per_workload.items():
            b = out.per_workload[w]
            delta = d["p99_ms"] - b["p99_ms"]
            print(f"{w:<12} {d['n_queries']:>10d} {b['n_queries']:>10d} "
                  f"{d['p99_ms']:>10.2f} {b['p99_ms']:>10.2f} "
                  f"{delta:>+8.2f}")
        capped = {
            w: sum(s["bridged"])
            for w, s in exact.series["per_workload"].items()
            if any(s["bridged"])
        }
        print("  intervals still capped:", capped if capped else "none — "
              "every interval fully simulated")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced table + short day (CI)")
    ap.add_argument("--event-core", action="store_true",
                    help="also serve the day exactly (batched "
                         "event-ordered core) and print exact-vs-bridged "
                         "p99 deltas")
    main(**vars(ap.parse_args()))
