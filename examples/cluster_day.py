"""Cluster-scale example: a full day of heterogeneity-aware provisioning
with node failures injected mid-day (elastic re-provisioning).

Run:  PYTHONPATH=src python examples/cluster_day.py
"""
import numpy as np

from repro.configs.paper_models import PAPER_MODELS, paper_profile
from repro.core.cluster import EfficiencyTable, provision_hercules
from repro.core.efficiency import build_table
from repro.serving.diurnal import diurnal_trace, load_increment_rate


def main():
    profiles = {n: paper_profile(n) for n in PAPER_MODELS}
    # Profiled (workload, server) cells persist under artifacts/profiles/;
    # the first run searches every cell (fast engine), reruns replay from
    # disk (see README "Offline profiling" for the key schema).
    table, _ = build_table(profiles, verbose=True)
    M = len(table.workloads)
    cap = (table.avail[:, None] * table.qps).sum(axis=0)
    traces = np.stack([diurnal_trace(0.15 * cap[m], seed=m, n_steps=96)
                       for m in range(M)])
    R = max(load_increment_rate(t) for t in traces)

    avail = table.avail.copy()
    rng = np.random.default_rng(0)
    print("t     power(kW)  servers  event")
    for t in range(96):
        # inject failures: each active server type loses a machine w.p. 2%
        event = ""
        fail = rng.random(len(avail)) < 0.02
        if fail.any():
            avail = np.maximum(avail - fail.astype(np.int64), 0)
            event = "failure: " + ",".join(
                np.asarray(table.servers)[fail])
        tbl = EfficiencyTable(table.servers, table.workloads, table.qps,
                              table.power, avail)
        r = provision_hercules(tbl, traces[:, t], overprovision=R)
        if t % 8 == 0 or event:
            print(f"{t:3d}   {r.provisioned_power_w/1e3:8.1f}  {r.capacity:7d}  "
                  f"{event if r.feasible else event + ' INFEASIBLE'}")
    print("day completed; surviving pool:",
          dict(zip(table.servers, avail.tolist())))


if __name__ == "__main__":
    main()
