"""Quickstart: the paper's two-stage flow on one workload in ~a minute.

1. Offline profiling — HW-aware partition + Algorithm-1 gradient search for
   DLRM-RMC1 on a CPU server and on a CPU+GPU server.
2. Online serving — provision a diurnal day on a small heterogeneous
   cluster with the NH / greedy / Hercules policies and compare power.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs.paper_models import paper_profile
from repro.core.cluster import EfficiencyTable, provision_day
from repro.core.devices import SERVER_TYPES
from repro.core.gradient_search import gradient_search
from repro.serving.diurnal import diurnal_trace, load_increment_rate


def main():
    rng = np.random.default_rng(0)
    sizes = np.clip(rng.lognormal(np.log(64), 1.1, 400).astype(np.int64), 1, 1024)

    # ---- stage 1: offline profiling -------------------------------------
    # every evaluation below runs the vectorized simulator engine with one
    # shared CRN cache per search (engine="reference" replays the original
    # per-sub-query heap loops ~10x slower, bit-for-bit compatible results)
    print("== offline profiling (Algorithm 1) ==")
    prof = paper_profile("dlrm-rmc1")
    tuples = {}
    for server in ("T2", "T3", "T7"):
        dev = SERVER_TYPES[server]
        res = gradient_search(prof, dev, sizes, o_grid=(1, 2))
        s = res.sched
        tuples[server] = (res.qps, dev.peak_power_w)
        print(f"  {server:3s}: QPS={res.qps:8.0f}  plan={res.placement.plan:10s} "
              f"m={s.m:2d} d={s.batch:4d} o={s.o}  "
              f"explored {res.evals}/{res.space_size} configs")

    # ---- stage 2: online provisioning -----------------------------------
    print("\n== online provisioning (diurnal day, Eq. 1-3) ==")
    servers = list(tuples)
    qps = np.array([[tuples[s][0]] for s in servers])
    power = np.array([[tuples[s][1]] for s in servers])
    table = EfficiencyTable(tuple(servers), ("dlrm-rmc1",), qps, power,
                            np.array([70, 15, 5]))
    peak = 0.3 * (table.avail[:, None] * qps).sum()
    traces = diurnal_trace(peak, seed=1, n_steps=96)[None]
    R = load_increment_rate(traces[0])
    for pol in ("nh", "greedy", "hercules"):
        r = provision_day(table, traces, policy=pol, overprovision=R)
        print(f"  {pol:9s}: peak {r['peak_power_w']/1e3:6.1f} kW   "
              f"avg {r['avg_power_w']/1e3:6.1f} kW   "
              f"peak servers {r['peak_capacity']:3d}")


if __name__ == "__main__":
    main()
