"""Geo-distributed serving (`repro.serving.geo`): spill-plan conservation
and limits, origin attribution with the link RTT added exactly once,
partition/drain event semantics, the follow-the-sun power win over the
per-region-isolated baseline, spec serialization, and the deprecated
simulate_cluster_day kwarg shim reproducing the typed path bitwise."""
import json
import pathlib
import tempfile

import numpy as np
import pytest

from repro.core import profile_cache
from repro.serving import scenarios as sc
from repro.serving.cluster_runtime import simulate_cluster_day
from repro.serving.geo import GeoConfig, plan_spill
from repro.serving.router import split_stream_by_share
from repro.serving.scenarios import (
    ScenarioSpec,
    compile_scenario,
    get_scenario,
)


@pytest.fixture(scope="module", autouse=True)
def hermetic_profiles():
    mp = pytest.MonkeyPatch()
    tmp = pathlib.Path(tempfile.mkdtemp())
    mp.setattr(profile_cache, "PROFILE_DIR", tmp)
    mp.setattr(sc, "_BUNDLES", {})
    yield
    mp.undo()


@pytest.fixture(scope="module")
def geo3():
    return compile_scenario(get_scenario("geo_3region"))


@pytest.fixture(scope="module")
def fs(geo3):
    return geo3.run(mode="follow_sun")


@pytest.fixture(scope="module")
def iso(geo3):
    return geo3.run(mode="isolated")


def _loads(comp):
    names = comp.region_names
    return np.stack([np.asarray(comp.days[n].traces, float) for n in names])


def _flows(comp, plan):
    """[R, M, T] planned outflow / inflow from a spill plan."""
    loads = _loads(comp)
    R, M, T = loads.shape
    out = np.zeros((R, M, T))
    inc = np.zeros((R, M, T))
    for t, sp in enumerate(plan):
        for (i, j), s in sorted(sp.items()):
            out[i, :, t] += s
            inc[j, :, t] += s
    return loads, out, inc


class TestSpillPlan:
    def test_conserves_and_respects_limits(self, geo3):
        """No region ships more than its offered load, no link carries more
        than its capacity, every spilled workload fits the RTT budget, and
        globally served == offered (nothing lost without a drain)."""
        plan, events, ok = plan_spill(geo3)
        assert ok, events
        loads, out, inc = _flows(geo3, plan)
        net = geo3.network
        days = [geo3.days[n] for n in geo3.region_names]
        slas = np.array([days[0].profiles[w].sla_ms
                         for w in days[0].table.workloads])
        for t, sp in enumerate(plan):
            for (i, j), s in sp.items():
                assert (s >= 0.0).all(), (t, (i, j))
                # RTT budget: spill only where rtt <= 0.5 * SLA
                spilled = s > 0.0
                assert (net.rtt_ms[(i, j)] <=
                        GeoConfig().rtt_budget_frac * slas[spilled]).all(), \
                    (t, (i, j), s)
                assert float(s.sum()) <= net.cap_qps[(i, j)] + 1e-6, (t, i, j)
            # per-origin: outflow never exceeds offered load
            assert (out[:, :, t] <= loads[:, :, t] + 1e-6).all(), t
        # conservation: served == offered globally, per (workload, interval)
        served = loads - out + inc
        np.testing.assert_allclose(served.sum(axis=0), loads.sum(axis=0),
                                   rtol=1e-9, atol=1e-6)
        assert float(out.sum()) > 0.0     # the plan actually spills

    def test_rmc1_never_crosses_the_long_link(self, geo3):
        """dlrm-rmc1 (20 ms SLA, 10 ms budget) must not spill over the
        12 ms eu-west<->ap-south link in either direction."""
        names = list(geo3.region_names)
        eu, ap = names.index("eu-west"), names.index("ap-south")
        m1 = list(geo3.days[names[0]].table.workloads).index("dlrm-rmc1")
        plan, _, _ = plan_spill(geo3)
        for t, sp in enumerate(plan):
            for p in ((eu, ap), (ap, eu)):
                if p in sp:
                    assert sp[p][m1] == 0.0, (t, p)

    def test_greedy_placement_also_conserves(self, geo3):
        plan, events, ok = plan_spill(geo3, GeoConfig(placement="greedy"))
        assert ok, events
        loads, out, inc = _flows(geo3, plan)
        np.testing.assert_allclose((loads - out + inc).sum(axis=0),
                                   loads.sum(axis=0), rtol=1e-9, atol=1e-6)

    def test_unknown_placement_rejected(self, geo3):
        with pytest.raises(ValueError, match="placement"):
            plan_spill(geo3, GeoConfig(placement="magic"))


class TestOriginAttribution:
    def test_rtt_added_exactly_once(self, geo3, fs):
        """Recompute one origin's attributed latency pool independently
        from the plan + each destination's measured stream: local shares
        carry no RTT, remote shares carry exactly one link RTT.  The
        result's origin percentiles must match bit for bit."""
        names = list(fs.region_names)
        R = len(names)
        plan, _, _ = plan_spill(geo3)
        loads, out_, inc = _flows(geo3, plan)
        _, M, T = loads.shape
        served = loads - out_ + inc
        served[served < 1e-6] = 0.0     # mirror simulate_geo_day's clamp
        wl = geo3.days[names[0]].table.workloads
        i0 = 0                                     # origin under test
        for m, wname in enumerate(wl):
            pool = []
            n_spilled = 0
            for j in range(R):
                lats = fs.regions[names[j]].latencies
                for t in range(T):
                    lat = None if lats is None else lats[m][t]
                    if lat is None or len(lat) == 0:
                        continue
                    shares = np.zeros(R)
                    shares[j] = max(
                        float(served[j, m, t] - inc[j, m, t]), 0.0)
                    for (i, j2), s in plan[t].items():
                        if j2 == j:
                            shares[i] += s[m]
                    if shares.sum() <= 0.0:
                        shares[j] = 1.0
                    assign = split_stream_by_share(
                        len(lat), shares, seq=(j * M + m) * T + t)
                    sel = lat[assign == i0]
                    if len(sel) == 0:
                        continue
                    if i0 != j:
                        rtt_s = geo3.network.rtt_ms[(i0, j)] / 1e3
                        sel = sel + rtt_s
                        n_spilled += len(sel)
                        # one RTT is a hard floor on a spilled latency
                        assert float(sel.min()) >= rtt_s
                    pool.append(sel)
            lat_ms = np.concatenate(pool) * 1e3
            got = fs.origin[names[i0]][wname]
            assert got["n_spilled"] == n_spilled
            assert got["p99_ms"] == float(np.percentile(lat_ms, 99))
            assert got["n_queries"] == len(lat_ms)

    def test_every_origin_measured(self, fs):
        for rname in fs.region_names:
            for w in fs.origin[rname].values():
                assert w["n_queries"] > 0
                assert np.isfinite(w["p99_ms"])


class TestFollowTheSun:
    def test_beats_isolated_on_global_peak_power(self, fs, iso):
        """The headline: phase-shifted peaks + spill de-synchronize the
        global fleet peak — strictly less provisioned peak power than
        per-region-isolated Hercules, with every SLA met."""
        assert fs.feasible and iso.feasible
        assert fs.peak_power_w < iso.peak_power_w
        assert fs.all_meet_sla and fs.all_intervals_meet_sla
        assert fs.n_spilled > 0 and iso.n_spilled == 0
        assert fs.lost_qps_mean == 0.0

    def test_isolated_shares_region_days(self, fs, iso):
        """Both modes provision from the same base-curve over-provision
        rate; isolated regions see exactly the offered load."""
        assert fs.region_names == iso.region_names
        for name in iso.region_names:
            assert iso.regions[name].feasible

    def test_to_dict_json_safe(self, fs):
        d = json.loads(json.dumps(fs.to_dict()))
        assert d["mode"] == "follow_sun"
        assert len(d["power_w"]) == len(fs.power)
        assert d["peak_power_w"] == fs.peak_power_w


class TestGeoEvents:
    def test_partition_forces_local_only(self):
        """During the partition window no planned flow touches the severed
        region in either direction."""
        comp = compile_scenario(get_scenario("geo_partition"))
        assert comp.partitions, "geo_partition must register a partition"
        (rname, start, end) = comp.partitions[0]
        sev = list(comp.region_names).index(rname)
        plan, _, ok = plan_spill(comp)
        assert ok
        for t in range(start, end):
            for (i, j) in plan[t]:
                assert sev not in (i, j), (t, (i, j))
        # outside the window the region participates again
        participates = [
            t for t, sp in enumerate(plan)
            if any(sev in p for p in sp)
        ]
        assert any(t < start or t >= end for t in participates)

    def test_drain_evacuates_make_before_break(self):
        """geo_drain: follow-the-sun places the evacuated load on the
        surviving regions (nothing lost, SLAs met); isolated has nowhere
        to put it and reports the load lost."""
        comp = compile_scenario(get_scenario("geo_drain"))
        assert comp.drains
        (rname, at, ramp) = comp.drains[0]
        fs_d = comp.run(mode="follow_sun")
        assert fs_d.feasible and fs_d.all_meet_sla
        assert fs_d.lost_qps_mean == 0.0
        assert fs_d.n_spilled > 0
        # the drained region's fleet ramps to zero load after the window
        drained = fs_d.regions[rname]
        assert drained.capacity[-1] < drained.capacity[0]
        iso_d = comp.run(mode="isolated")
        assert iso_d.lost_qps_mean > 0.0
        assert not iso_d.feasible


class TestGeoSpecSerialization:
    @pytest.mark.parametrize(
        "name", ["geo_3region", "geo_partition", "geo_drain"])
    def test_round_trip(self, name):
        spec = get_scenario(name)
        back = ScenarioSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
        assert back == spec
        assert back.regions == spec.regions
        assert back.links == spec.links


class TestDeprecatedKwargShim:
    def test_old_signature_warns_and_matches_bitwise(self):
        """The pre-DayInputs call shape still works, warns, and reproduces
        the typed path bit for bit on the golden baseline_day."""
        comp = compile_scenario(get_scenario("baseline_day"))
        inp = comp.inputs
        new = simulate_cluster_day(inp, policy="hercules")
        with pytest.warns(DeprecationWarning, match="DayInputs"):
            old = simulate_cluster_day(
                inp.table, inp.records, inp.profiles, inp.traces,
                policy="hercules", servers=inp.servers,
                overprovision=inp.overprovision,
                transitions=inp.transitions, failures=inp.failures,
                seed=inp.seed)
        a, b = old.to_dict(), new.to_dict()
        assert a.keys() == b.keys()

        def eq(x, y):
            if isinstance(x, dict):
                assert x.keys() == y.keys()
                for k in x:
                    eq(x[k], y[k])
            elif isinstance(x, np.ndarray) or isinstance(y, np.ndarray):
                assert np.array_equal(x, y)
            elif isinstance(x, (list, tuple)):
                assert len(x) == len(y)
                for xx, yy in zip(x, y):
                    eq(xx, yy)
            else:
                assert x == y

        eq(a, b)


class TestWithAvailability:
    def test_rebinds_pool_without_reprofiling(self, geo3):
        table = geo3.days["us-east"].table
        new = {s: 1 for s in table.servers}
        t2 = table.with_availability(new)
        assert (t2.avail == 1).all()
        assert np.array_equal(t2.qps, table.qps)
        assert np.array_equal(t2.power, table.power)
        assert (table.avail != 1).any()    # original untouched

    def test_missing_type_rejected(self, geo3):
        table = geo3.days["us-east"].table
        with pytest.raises(KeyError, match=table.servers[0]):
            table.with_availability({})
