"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches see
the single real CPU device; multi-device tests spawn via the mesh8 fixture
module (tests/test_distributed.py sets the flag at import, isolated by
running in its own process when needed)."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
