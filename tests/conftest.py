"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches see
the single real CPU device; multi-device tests spawn via the mesh8 fixture
module (tests/test_distributed.py sets the flag at import, isolated by
running in its own process when needed)."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _engine_stats_reset():
    """Path-mix counters in repro.serving.engine / event_core are module
    globals; reset them around every test so mix assertions cannot be
    contaminated by test order."""
    try:
        from repro.serving import engine
    except ImportError:  # collection of non-serving subsets without src
        yield
        return
    engine.stats_reset()
    yield
    engine.stats_reset()


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
