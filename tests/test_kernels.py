"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret mode on CPU, per the validation contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.embedding_bag import hot_embedding_bag, hot_embedding_bag_ref
from repro.kernels.flash_attention import attention_ref, flash_attention, flash_decode
from repro.kernels.flash_attention.flash_decode import lse_combine


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,P,D,H", [(64, 8, 32, 200), (96, 1, 16, 64),
                                     (128, 24, 64, 500)])
def test_embedding_bag_kernel_sweep(B, P, D, H, dtype):
    key = jax.random.PRNGKey(B + P)
    table = jax.random.normal(key, (H, D), dtype)
    ids = jax.random.randint(jax.random.PRNGKey(1), (B, P), -1, H)
    out = hot_embedding_bag(table, ids, tile_b=32)
    ref = hot_embedding_bag_ref(table, ids)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


def test_embedding_bag_kernel_pads_batch():
    table = jax.random.normal(jax.random.PRNGKey(0), (50, 8))
    ids = jax.random.randint(jax.random.PRNGKey(1), (37, 4), -1, 50)
    out = hot_embedding_bag(table, ids, tile_b=16)
    assert out.shape == (37, 8)
    ref = hot_embedding_bag_ref(table, ids)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("Tq,H,KVH,hd,bq,bk", [
    (128, 4, 4, 32, 64, 64),    # MHA
    (256, 8, 2, 64, 128, 128),  # GQA 4:1
    (128, 8, 1, 32, 128, 64),   # MQA
])
def test_flash_attention_sweep(Tq, H, KVH, hd, bq, bk, dtype):
    B = 2
    q = jax.random.normal(jax.random.PRNGKey(0), (B, Tq, H, hd), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, Tq, KVH, hd), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, Tq, KVH, hd), dtype)
    out = flash_attention(q, k, v, causal=True, bq=bq, bk=bk)
    ref = attention_ref(q, k, v, causal=True)
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


def test_flash_attention_noncausal():
    B, T, H, hd = 1, 128, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (B, T, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, T, H, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, T, H, hd))
    out = flash_attention(q, k, v, causal=False, bq=64, bk=64)
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("S,kv_len,bk", [(512, 512, 128), (1024, 700, 256),
                                         (256, 1, 128)])
def test_flash_decode_sweep(S, kv_len, bk):
    B, H, KVH, hd = 2, 8, 2, 32
    q = jax.random.normal(jax.random.PRNGKey(0), (B, 1, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KVH, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KVH, hd))
    out = flash_decode(q, k, v, kv_len=kv_len, bk=bk)
    ref = attention_ref(q, k, v, causal=False, kv_len=kv_len)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_flash_decode_offset_shard_merge():
    """Per-shard slices with a GLOBAL kv_len + their base offset merge to
    the full-cache answer — the repro.dist.decode contract, single-device."""
    from repro.kernels.flash_attention.flash_decode import flash_decode_partials

    B, S, H, KVH, hd = 2, 512, 8, 2, 32
    kv_len = 300                               # ends mid-slice 2 of 4
    q = jax.random.normal(jax.random.PRNGKey(0), (B, 1, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KVH, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KVH, hd))
    parts = [
        flash_decode_partials(q, k[:, i:i + 128], v[:, i:i + 128],
                              kv_len=kv_len, kv_offset=i, bk=64,
                              interpret=True)
        for i in range(0, S, 128)
    ]
    m, l, o = (jnp.stack([p[j] for p in parts]) for j in range(3))
    from repro.kernels.flash_attention.flash_decode import lse_combine
    _, l_c, o_c = lse_combine(m, l, o, axis=0)
    out = (o_c / jnp.maximum(l_c, 1e-30)).reshape(B, 1, H, hd)
    ref = attention_ref(q, k, v, causal=False, kv_len=kv_len)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)
    full = flash_decode(q, k, v, kv_len=kv_len, bk=64)
    np.testing.assert_allclose(out, full, rtol=1e-6, atol=1e-6)


def test_flash_decode_offset_empty_slice():
    """A slice entirely past kv_len yields an exactly-empty partial
    (l = 0, o = 0) instead of relying on the merge to suppress junk."""
    from repro.kernels.flash_attention.flash_decode import flash_decode_partials

    B, H, KVH, hd = 1, 4, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (B, 1, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, 128, KVH, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, 128, KVH, hd))
    m, l, o = flash_decode_partials(q, k, v, kv_len=200, kv_offset=256, bk=64,
                                    interpret=True)
    assert np.all(np.asarray(l) == 0.0)
    assert np.all(np.asarray(o) == 0.0)


def test_lse_combine_associativity():
    """Hierarchical merge == flat merge (the distributed-decode invariant)."""
    rng = np.random.default_rng(0)
    m = jnp.asarray(rng.normal(size=(4, 2, 1)).astype(np.float32))
    l = jnp.asarray(rng.uniform(0.5, 2.0, (4, 2, 1)).astype(np.float32))
    o = jnp.asarray(rng.normal(size=(4, 2, 8)).astype(np.float32))
    # flat
    _, l_f, o_f = lse_combine(m, l, o, axis=0)
    # pairwise then merge
    m1, l1, o1 = lse_combine(m[:2], l[:2], o[:2], axis=0)
    m2, l2, o2 = lse_combine(m[2:], l[2:], o[2:], axis=0)
    mm = jnp.stack([m1, m2])
    ll = jnp.stack([l1, l2])
    oo = jnp.stack([o1, o2])
    _, l_h, o_h = lse_combine(mm, ll, oo, axis=0)
    np.testing.assert_allclose(o_f / l_f, o_h / l_h, rtol=1e-5)
