"""Spec-tree construction invariants (host-only, no device mesh needed).

Pins the ``opt_spec_tree`` structural-divergence contract: mirrored
optimizer sub-trees inherit parameter specs exactly; a diverged sub-tree
replicates with a :class:`ShardingFallbackWarning` naming the diverging
paths (the silent fallback was a ROADMAP carried gap — a replicated Adam
state for a model-sharded table costs full-table memory on every chip),
and ``strict=True`` raises instead.
"""
import warnings

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.common.types import ArchKind
from repro.dist.sharding import (
    ShardingFallbackWarning,
    opt_spec_tree,
    param_spec_tree,
)


def _params():
    return {
        "table": jnp.zeros((16, 8)),
        "mlp": {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))},
    }


def _specs(params):
    return param_spec_tree(ArchKind.RECSYS, params)


def test_mirrored_sub_tree_inherits_param_specs():
    params = _params()
    specs = _specs(params)
    opt = {"m": params, "v": params, "step": jnp.zeros(())}
    with warnings.catch_warnings():
        warnings.simplefilter("error", ShardingFallbackWarning)
        out = opt_spec_tree(ArchKind.RECSYS, opt, specs)
    assert out["m"]["table"] == P("model", None)
    assert out["v"]["table"] == P("model", None)
    assert out["m"]["mlp"]["w"] == P(None, None)
    assert out["step"] == P()


def test_row_accumulator_rank_mismatch_replicates_leaf_only():
    # a [rows] accumulator against a rank-2 spec replicates that leaf but
    # keeps the others sharded (positional-spec contract)
    params = _params()
    specs = _specs(params)
    opt = {
        "acc": {
            "table": jnp.zeros((16,)),
            "mlp": {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))},
        }
    }
    with warnings.catch_warnings():
        warnings.simplefilter("error", ShardingFallbackWarning)
        out = opt_spec_tree(ArchKind.RECSYS, opt, specs)
    assert out["acc"]["table"] == P(None)
    assert out["acc"]["mlp"]["w"] == P(None, None)


def test_diverged_sub_tree_warns_with_paths():
    params = _params()
    specs = _specs(params)
    diverged = dict(params, extra=jnp.zeros((2, 2)))
    opt = {"m": diverged}
    with pytest.warns(ShardingFallbackWarning) as rec:
        out = opt_spec_tree(ArchKind.RECSYS, opt, specs)
    msg = str(rec.list[0].message)
    assert '"m"' in msg
    assert "'extra'" in msg           # the diverging subtree path is named
    assert "4 leaves" in msg and "3" in msg
    # conservative fallback: everything in the diverged sub-tree replicated
    assert all(
        s == P(*([None] * 2)) or s == P(None)
        for s in jax.tree_util.tree_leaves(
            out["m"], is_leaf=lambda x: isinstance(x, P)
        )
    )


def test_diverged_sub_tree_strict_raises():
    params = _params()
    specs = _specs(params)
    opt = {"m": dict(params, extra=jnp.zeros((2, 2)))}
    with pytest.raises(ValueError, match='sub-tree "m"'):
        opt_spec_tree(ArchKind.RECSYS, opt, specs, strict=True)


def test_matching_tree_never_warns_strict():
    params = _params()
    specs = _specs(params)
    opt = {"m": params, "v": params, "step": jnp.zeros(()), "none": {}}
    out = opt_spec_tree(ArchKind.RECSYS, opt, specs, strict=True)
    assert out["none"] == {}
    assert out["m"]["table"] == P("model", None)
