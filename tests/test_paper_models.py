"""The paper's six models execute as real (reduced-scale) JAX models."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.clicklog import ClickLogGenerator
from repro.models import din as din_lib
from repro.models import dlrm as dlrm_lib
from repro.models import widedeep as wnd_lib
from repro.models.embedding import EmbeddingConfig
from repro.models.recsys_base import RecsysConfig, binary_ce

KEY = jax.random.PRNGKey(0)


def _small(cfg: RecsysConfig) -> RecsysConfig:
    emb = dataclasses.replace(
        cfg.embedding,
        vocab_sizes=tuple(min(v, 1000) for v in cfg.embedding.vocab_sizes),
        qr_features=(),
        row_pad=8,
    )
    return dataclasses.replace(cfg, embedding=emb,
                               seq_len=min(cfg.seq_len, 12) if cfg.seq_len else 0)


MODELS = {
    "dlrm-rmc1": (dlrm_lib, "rmc1"),
    "dlrm-rmc2": (dlrm_lib, "rmc2"),
    "dlrm-rmc3": (dlrm_lib, "rmc3"),
    "mt-wnd": (wnd_lib, "mt_wnd"),
    "din": (din_lib, "din"),
    "dien": (din_lib, "dien"),
}


@pytest.mark.parametrize("name", list(MODELS))
def test_paper_model_forward_and_grad(name):
    import repro.configs.paper_models as pm

    lib, factory = MODELS[name]
    cfg = _small(getattr(pm, factory)(prod=False))
    params = lib.init(KEY, cfg)
    gen = ClickLogGenerator(cfg, seed=0)
    batch = jax.tree.map(jnp.asarray, gen.batch(8))
    out = lib.apply(params, batch, cfg)
    assert out.shape[0] == 8
    assert bool(jnp.isfinite(out).all())

    def loss_fn(p):
        return binary_ce(lib.apply(p, batch, cfg), batch["label"])

    g = jax.grad(loss_fn)(params)
    assert all(bool(jnp.isfinite(t).all()) for t in jax.tree.leaves(g))


def test_dien_gru_differs_from_din():
    """The AUGRU path must actually change the prediction."""
    import repro.configs.paper_models as pm

    din_cfg = _small(pm.din(prod=False))
    dien_cfg = dataclasses.replace(din_cfg, use_gru=True)
    p = din_lib.init(KEY, dien_cfg)  # superset params (has gru)
    gen = ClickLogGenerator(din_cfg, seed=0)
    batch = jax.tree.map(jnp.asarray, gen.batch(4))
    a = din_lib.apply(p, batch, din_cfg)
    b = din_lib.apply(p, batch, dien_cfg)
    assert not np.allclose(np.asarray(a), np.asarray(b))
