"""EmbeddingBag substrate: unit + hypothesis property tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # dev-only dep (requirements-dev.txt): skip ONLY the
    # property tests, keep the plain assertions running
    def given(*a, **k):
        return lambda f: pytest.mark.skip(reason="hypothesis not installed")(f)

    def settings(*a, **k):
        return lambda f: f

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

from repro.models.embedding import (
    EmbeddingConfig,
    HotColdLayout,
    embedding_bag_hot_cold,
    embedding_bag_local,
    embedding_bag_ragged,
    init_embedding,
    make_hot_cold_layout,
    split_hot_cold,
)


def _cfg(vocabs=(50, 100, 30), dim=8, pooling=(4, 2, 1), **kw):
    return EmbeddingConfig(vocab_sizes=vocabs, dim=dim, pooling=pooling,
                           row_pad=8, **kw)


def _ref_bag(table_np, ids, cfg):
    """Numpy oracle for the combined-table multi-hot bag."""
    B, F, P = ids.shape
    out = np.zeros((B, F, cfg.dim), np.float64)
    offs = cfg.row_offsets
    counts = np.zeros((B, F), np.int64)
    for b in range(B):
        for f in range(F):
            for p in range(P):
                i = ids[b, f, p]
                if i >= 0:
                    out[b, f] += table_np[offs[f] + i]
                    counts[b, f] += 1
    if cfg.combine == "mean":
        out = out / np.maximum(counts, 1)[..., None]
    return out


def test_matches_numpy_oracle(rng):
    cfg = _cfg()
    params = init_embedding(jax.random.PRNGKey(0), cfg)
    ids = rng.integers(-1, 30, (6, 3, 4)).astype(np.int32)
    got = embedding_bag_local(params, jnp.asarray(ids), cfg)
    want = _ref_bag(np.asarray(params["table"]), ids, cfg)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_mean_combine(rng):
    cfg = _cfg(combine="mean")
    params = init_embedding(jax.random.PRNGKey(0), cfg)
    ids = rng.integers(-1, 30, (4, 3, 4)).astype(np.int32)
    got = embedding_bag_local(params, jnp.asarray(ids), cfg)
    want = _ref_bag(np.asarray(params["table"]), ids, cfg)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    batch=st.integers(1, 8),
    pooling=st.integers(1, 6),
    dim=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_padding_invariance(batch, pooling, dim, seed):
    """Appending -1 padding never changes the pooled result."""
    cfg = EmbeddingConfig(vocab_sizes=(40,), dim=dim, pooling=(pooling,),
                          row_pad=8)
    cfg_wide = EmbeddingConfig(vocab_sizes=(40,), dim=dim,
                               pooling=(pooling + 3,), row_pad=8)
    params = init_embedding(jax.random.PRNGKey(seed), cfg)
    r = np.random.default_rng(seed)
    ids = r.integers(0, 40, (batch, 1, pooling)).astype(np.int32)
    ids_padded = np.concatenate(
        [ids, np.full((batch, 1, 3), -1, np.int32)], axis=-1
    )
    a = embedding_bag_local(params, jnp.asarray(ids), cfg)
    b = embedding_bag_local(params, jnp.asarray(ids_padded), cfg_wide)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), hot_rows=st.integers(0, 40))
def test_property_hot_cold_partition_exact(seed, hot_rows):
    """hot + cold partial sums == unpartitioned bag for any split point."""
    cfg = _cfg(vocabs=(40, 40), pooling=(3, 2))
    params = init_embedding(jax.random.PRNGKey(seed), cfg)
    layout = HotColdLayout(cfg=cfg, hot_rows=(hot_rows, max(40 - hot_rows, 0)))
    split = split_hot_cold(params, layout)
    r = np.random.default_rng(seed)
    ids = r.integers(-1, 40, (5, 2, 3)).astype(np.int32)
    hot, cold = embedding_bag_hot_cold(split, jnp.asarray(ids), layout)
    want = embedding_bag_local(params, jnp.asarray(ids), cfg)
    np.testing.assert_allclose(np.asarray(hot) + np.asarray(cold), want,
                               rtol=1e-5, atol=1e-5)


def test_hot_layout_capacity_budget():
    cfg = _cfg()
    layout = make_hot_cold_layout(cfg, capacity_rows=60)
    assert sum(layout.hot_rows) <= 60
    assert all(h <= v for h, v in zip(layout.hot_rows, cfg.vocab_sizes))


def test_ragged_bag_matches_segments(rng):
    table = jnp.asarray(rng.normal(size=(30, 4)).astype(np.float32))
    ids = jnp.asarray([0, 1, 2, 5, 5, 7], jnp.int32)
    seg = jnp.asarray([0, 0, 1, 1, 2, 2], jnp.int32)
    out = embedding_bag_ragged(table, ids, seg, 3)
    want = np.stack([
        np.asarray(table)[[0, 1]].sum(0),
        np.asarray(table)[[2, 5]].sum(0),
        np.asarray(table)[[5, 7]].sum(0),
    ])
    np.testing.assert_allclose(out, want, rtol=1e-6)


def test_qr_compression_storage():
    cfg = EmbeddingConfig(vocab_sizes=(1_000_000, 100), dim=4,
                          pooling=(1, 1), qr_features=(0,), qr_buckets=1024,
                          row_pad=8)
    # storage ~ 1e6/1024 + 1024 + 100 rows, not 1e6
    assert cfg.total_rows < 4000
    params = init_embedding(jax.random.PRNGKey(0), cfg)
    ids = jnp.asarray([[[123456], [7]]], jnp.int32)
    out = embedding_bag_local(params, ids, cfg)
    assert out.shape == (1, 2, 4)
    assert bool(jnp.isfinite(out).all())


def test_grad_only_touches_looked_up_rows():
    cfg = _cfg(vocabs=(20,), pooling=(2,))
    params = init_embedding(jax.random.PRNGKey(0), cfg)
    ids = jnp.asarray([[[3, 5]]], jnp.int32)

    g = jax.grad(lambda p: embedding_bag_local(p, ids, cfg).sum())(params)
    gt = np.asarray(g["table"])
    touched = set(np.nonzero(np.abs(gt).sum(1))[0].tolist())
    assert touched == {3, 5}
