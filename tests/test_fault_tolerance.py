"""Checkpoint/restart, elastic re-provisioning, straggler hedging."""
import numpy as np
import pytest

from repro.core.cluster import EfficiencyTable, provision_hercules
from repro.launch.steps import build_cell
from repro.serving.router import QueryRouter, ServerSlot
from repro.train.checkpoint import CheckpointManager
from repro.train.trainer import Trainer, TrainerConfig

import jax
import jax.numpy as jnp

KEY = jax.random.PRNGKey(0)


def _batches(cell, n=10_000):
    r = np.random.default_rng(0)

    def mk(spec):
        if spec.dtype == jnp.int32:
            return jnp.asarray(r.integers(0, 2, spec.shape), jnp.int32)
        if spec.dtype == jnp.bool_:
            return jnp.ones(spec.shape, bool)
        return jnp.asarray(r.normal(size=spec.shape), spec.dtype)

    while True:
        yield jax.tree.map(mk, cell.batch_specs)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        state = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones(4)}}
        mgr.save(7, state, blocking=True)
        assert mgr.latest_step() == 7
        out = mgr.restore(7, jax.tree.map(jnp.zeros_like, state))
        np.testing.assert_allclose(out["a"], state["a"])
        np.testing.assert_allclose(out["b"]["c"], state["b"]["c"])

    def test_gc_keeps_max(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), max_to_keep=2)
        s = {"x": jnp.zeros(2)}
        for i in (1, 2, 3, 4):
            mgr.save(i, s, blocking=True)
        assert mgr.all_steps() == [3, 4]

    def test_crash_restart_resumes(self, tmp_path):
        cell = build_cell("dlrm-rm2", "train_batch", mesh=None)
        step = cell.jitted()
        cfg = TrainerConfig(total_steps=12, ckpt_every=5,
                            ckpt_dir=str(tmp_path), log_every=1)
        t = Trainer(step, cell.init_state, _batches(cell), cfg)
        with pytest.raises(RuntimeError, match="injected crash"):
            t.run(KEY, crash_at=8)
        # restart: resumes from step 5, finishes
        t2 = Trainer(step, cell.init_state, _batches(cell), cfg)
        state, hist = t2.run(KEY)
        assert t2.ckpt.latest_step() == 12
        assert hist[0]["step"] == 6  # resumed after step-5 commit


class TestElasticProvisioning:
    def test_reprovision_after_failures(self):
        qps = np.array([[2000.0, 1500.0], [9000.0, 8000.0]])
        power = np.array([[175.0, 175.0], [475.0, 475.0]])
        avail = np.array([50, 10])
        t = EfficiencyTable(("cpu", "accel"), ("a", "b"), qps, power, avail)
        load = np.array([40_000.0, 30_000.0])
        r1 = provision_hercules(t, load)
        assert r1.feasible
        # 8 accel servers die -> re-provision on surviving pool
        t2 = EfficiencyTable(t.servers, t.workloads, qps, power,
                             np.array([50, 2]))
        r2 = provision_hercules(t2, load)
        assert r2.feasible
        assert (r2.alloc.sum(axis=1) <= t2.avail).all()
        assert r2.alloc[0].sum() > r1.alloc[0].sum()  # shifted to CPUs

    def test_infeasible_detected(self):
        qps = np.array([[100.0]])
        t = EfficiencyTable(("cpu",), ("a",), qps, np.array([[100.0]]),
                            np.array([2]))
        r = provision_hercules(t, np.array([10_000.0]))
        assert not r.feasible


class TestRouter:
    def test_failover_reroutes(self):
        slots = [ServerSlot("a", 100.0), ServerSlot("b", 90.0)]
        router = QueryRouter(slots, seed=0)
        # one server dies mid-query: the retry lands on the survivor
        died = {"n": 0}

        def service(slot):
            return 0.01

        lat, attempts = router.dispatch(service, fail_prob=0.5)
        assert np.isfinite(lat) or sum(s.healthy for s in slots) < 2
        # with every server failing, the router drains the pool then raises
        slots2 = [ServerSlot("a", 100.0), ServerSlot("b", 90.0)]
        router2 = QueryRouter(slots2, seed=0)
        with pytest.raises(RuntimeError):
            for _ in range(10):
                router2.dispatch(service, fail_prob=1.0)
        assert not any(s.healthy for s in slots2)

    def test_hedging_reduces_tail(self):
        r = np.random.default_rng(0)
        slots = [ServerSlot("a", 100.0), ServerSlot("b", 100.0)]
        router = QueryRouter(slots, hedge_quantile=0.9, hedge_factor=1.5,
                             seed=0)

        def service(slot):
            return 0.010 if r.random() > 0.05 else 0.200  # 5% stragglers

        lats = [router.dispatch(service)[0] for _ in range(500)]
        hedged_p99 = float(np.quantile(lats, 0.99))
        # without hedging p99 would be ~0.2; hedging brings most retries home
        assert hedged_p99 <= 0.2

    def test_all_dead_raises(self):
        router = QueryRouter([ServerSlot("a", 1.0, healthy=False)])
        with pytest.raises(RuntimeError):
            router.pick()
