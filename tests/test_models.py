"""Per-arch smoke tests (reduced configs, one step on CPU, shapes + finite)
plus model-level unit tests (MoE dispatch exactness, decode==forward)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_arch, list_archs
from repro.launch.steps import build_cell
from repro.models.layers import MoEConfig, apply_moe_dense, apply_swiglu, init_moe
from repro.dist.moe import moe_apply_grouped
from repro.models.transformer import (
    LMConfig,
    decode_step,
    forward,
    init,
    init_kv_cache,
    lm_loss,
    prefill,
)

KEY = jax.random.PRNGKey(0)


def make_batch(cell, seed=0):
    r = np.random.default_rng(seed)

    def mk(spec):
        if spec.dtype == jnp.int32:
            return jnp.asarray(r.integers(0, 2, spec.shape), jnp.int32)
        if spec.dtype == jnp.bool_:
            return jnp.ones(spec.shape, bool)
        return jnp.asarray(r.normal(size=spec.shape), spec.dtype)

    return jax.tree.map(mk, cell.batch_specs)


ALL_CELLS = [(a, s.name) for a in list_archs() for s in get_arch(a).SHAPES]


@pytest.mark.parametrize("arch_id,shape", ALL_CELLS,
                         ids=[f"{a}-{s}" for a, s in ALL_CELLS])
def test_smoke_cell(arch_id, shape):
    """Reduced config, one real step: output shapes + no NaNs."""
    cell = build_cell(arch_id, shape, mesh=None)
    state = cell.init_state(KEY)
    out = cell.run(state, make_batch(cell))
    for leaf in jax.tree.leaves(out):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            assert bool(jnp.isfinite(leaf).all()), f"NaN in {arch_id}/{shape}"
    if cell.shape.step == "train":
        assert float(out[1]["loss"]) > 0


def test_smoke_train_loss_decreases():
    """A few steps on the dlrm smoke config actually learn."""
    cell = build_cell("dlrm-rm2", "train_batch", mesh=None)
    state = cell.init_state(KEY)
    losses = []
    step = cell.jitted()
    for i in range(8):
        batch = make_batch(cell, seed=0)  # same batch: loss must fall
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


class TestLM:
    CFG = LMConfig(name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                   d_ff=96, vocab=128, head_dim=16, dtype=jnp.float32)

    def test_prefill_decode_match_forward(self):
        p = init(KEY, self.CFG)
        toks = jax.random.randint(KEY, (2, 10), 0, 128)
        cache = init_kv_cache(self.CFG, 2, 12)
        last, cache = prefill(p, toks, cache, self.CFG)
        full, _, _ = forward(p, toks, self.CFG)
        np.testing.assert_allclose(last, full[:, -1], rtol=1e-4, atol=1e-4)
        nxt = jnp.argmax(last, -1)[:, None]
        dec, _ = decode_step(p, nxt, cache, 10, self.CFG)
        full2, _, _ = forward(p, jnp.concatenate([toks, nxt], 1), self.CFG)
        np.testing.assert_allclose(dec, full2[:, -1], rtol=1e-4, atol=1e-4)

    def test_chunked_attention_equals_naive(self):
        cfg_c = dataclasses.replace(self.CFG, attn_impl="chunked", attn_chunk=4)
        p = init(KEY, self.CFG)
        toks = jax.random.randint(KEY, (2, 16), 0, 128)
        a, _, _ = forward(p, toks, self.CFG)
        b, _, _ = forward(p, toks, cfg_c)
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)

    def test_unrolled_equals_scan(self):
        cfg_u = dataclasses.replace(self.CFG, unroll_layers=True)
        p = init(KEY, self.CFG)
        toks = jax.random.randint(KEY, (2, 8), 0, 128)
        a, _, _ = forward(p, toks, self.CFG)
        b, _, _ = forward(p, toks, cfg_u)
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)

    def test_int8_kv_close_to_exact(self):
        cfg_q = dataclasses.replace(self.CFG, kv_quant="int8")
        p = init(KEY, self.CFG)
        toks = jax.random.randint(KEY, (2, 10), 0, 128)
        cache = init_kv_cache(cfg_q, 2, 10)
        last_q, _ = prefill(p, toks, cache, cfg_q)
        full, _, _ = forward(p, toks, self.CFG)
        rel = float(jnp.abs(last_q - full[:, -1]).max()) / (
            float(jnp.abs(full[:, -1]).max()) + 1e-9)
        assert rel < 0.05

    def test_loss_grad_finite(self):
        p = init(KEY, self.CFG)
        toks = jax.random.randint(KEY, (2, 10), 0, 128)
        g = jax.grad(lm_loss)(p, {"tokens": toks}, self.CFG)
        assert all(bool(jnp.isfinite(t).all()) for t in jax.tree.leaves(g))


class TestMoE:
    CFG = MoEConfig(d_model=32, d_ff=16, n_experts=6, top_k=2, n_shared=1,
                    shared_d_ff=48, capacity_factor=8.0, pad_to=4)

    def test_grouped_matches_dense(self):
        p = init_moe(KEY, self.CFG)
        x = jax.random.normal(jax.random.PRNGKey(1), (48, 32))
        want, _ = apply_moe_dense(p, x, self.CFG)
        got, _ = moe_apply_grouped(p, x, self.CFG)
        got = got + apply_swiglu(p["shared"], x)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_expert_partials_sum_to_full(self):
        p = init_moe(KEY, self.CFG)
        x = jax.random.normal(jax.random.PRNGKey(1), (32, 32))
        full, _ = moe_apply_grouped(p, x, self.CFG, capacity=64)
        lo, _ = moe_apply_grouped(p, x, self.CFG, e_start=0, e_count=4,
                                  capacity=64)
        hi, _ = moe_apply_grouped(p, x, self.CFG, e_start=4, e_count=4,
                                  capacity=64)
        np.testing.assert_allclose(lo + hi, full, rtol=1e-4, atol=1e-5)

    def test_capacity_drops_are_bounded(self):
        """With tiny capacity, output is a damped version, never NaN."""
        p = init_moe(KEY, self.CFG)
        x = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
        out, _ = moe_apply_grouped(p, x, self.CFG, capacity=8)
        assert bool(jnp.isfinite(out).all())
