"""Interference-aware multi-tenant co-location (`repro.core.colocation`).

Four layers, mirroring the docs/colocation.md contract:

- **interference model** — `colocation_dilation` is exactly 1.0 for an
  empty co-set, monotone non-decreasing in every pressure component
  (adding a tenant never shortens durations), and `derated_device` never
  makes a shared resource faster.
- **packing** — with an empty `ColocationTable` the merge pass is the
  identity (single-tenant packings reproduce the base allocation
  bitwise); on a synthetic table the greedy merge applies exactly when
  the utilization budget admits it and strictly reduces power; SLA /
  accel-slot admission rejects inadmissible pairs.
- **single-tenant bitwise** — a day served with an empty colocation
  table is bit-identical to the same day served with `colocation=None`.
- **online** — the registered co-located day beats the same inputs
  served single-tenant on peak provisioned power with every tenant's
  per-interval SLA met; per-tenant SLA attribution stays conserved
  through a mid-window shared-machine failure (the tenant with surviving
  slots re-routes and loses nothing; a tenant whose only slot died is
  reported honestly, not silently dropped).
"""
import dataclasses
import pathlib
import tempfile

import numpy as np
import pytest

from repro.core import perfmodel, profile_cache
from repro.core.cluster import (
    EfficiencyTable,
    StatefulProvisioner,
    provision_hercules,
)
from repro.core.colocation import (
    ColoCell,
    ColocationTable,
    CoMachine,
    build_colocation_table,
    co_served,
    pack_colocated,
)
from repro.core.devices import SERVER_TYPES
from repro.core.efficiency import derated_device
from repro.configs.paper_models import paper_profile
from repro.serving import scenarios as sc
from repro.serving.cluster_runtime import simulate_cluster_day
from repro.serving.router import QueryRouter, ServerSlot
from repro.serving.scenarios import compile_scenario, get_scenario


@pytest.fixture(scope="module", autouse=True)
def hermetic_profiles():
    """Profile into a throwaway cache and empty memos (same contract as
    tests/test_scenarios.py)."""
    mp = pytest.MonkeyPatch()
    tmp = pathlib.Path(tempfile.mkdtemp())
    mp.setattr(profile_cache, "PROFILE_DIR", tmp)
    mp.setattr(sc, "_BUNDLES", {})
    mp.setattr(sc, "_COLOC_TABLES", {})
    yield
    mp.undo()


def _assert_day_equal(a, b, path=""):
    """Recursive bitwise equality over simulate_cluster_day outputs."""
    if isinstance(a, dict):
        assert isinstance(b, dict) and a.keys() == b.keys(), path
        for k in a:
            _assert_day_equal(a[k], b[k], f"{path}.{k}")
    elif isinstance(a, np.ndarray) or isinstance(b, np.ndarray):
        assert np.array_equal(a, b), path
    elif isinstance(a, (list, tuple)):
        assert isinstance(b, (list, tuple)) and len(a) == len(b), path
        for i, (x, y) in enumerate(zip(a, b)):
            _assert_day_equal(x, y, f"{path}[{i}]")
    elif isinstance(a, float) and isinstance(b, float) \
            and np.isnan(a) and np.isnan(b):
        pass
    else:
        assert a == b, (path, a, b)


# ---------------------------------------------------------------------------
# interference model (pure analytic — no profiling)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_profiles():
    return (paper_profile("dlrm-rmc1", prod=False),
            paper_profile("dlrm-rmc3", prod=False))


class TestInterferenceModel:
    def test_empty_co_set_is_exactly_one(self, small_profiles):
        for dev in (SERVER_TYPES["T2"], SERVER_TYPES["T7"]):
            for p in small_profiles:
                assert perfmodel.colocation_dilation(p, dev, []) == 1.0

    def test_monotone_in_co_tenant_pressure(self, small_profiles):
        """Adding a tenant / raising its rate never shortens durations."""
        victim, other = small_profiles
        for dev in (SERVER_TYPES["T2"], SERVER_TYPES["T7"]):
            last = 1.0
            for qps in (0.0, 10.0, 100.0, 1000.0, 10000.0):
                p = perfmodel.tenant_pressure(other, dev, qps, 40.0)
                d = perfmodel.colocation_dilation(victim, dev, [p])
                assert d >= last, (dev.name, qps)
                last = d
            p = perfmodel.tenant_pressure(other, dev, 100.0, 40.0)
            one = perfmodel.colocation_dilation(victim, dev, [p])
            two = perfmodel.colocation_dilation(victim, dev, [p, p])
            assert 1.0 <= one <= two

    def test_sensitivity_is_a_distribution(self, small_profiles):
        for p in small_profiles:
            s = perfmodel.resource_sensitivity(p, SERVER_TYPES["T2"])
            assert set(s) == set(perfmodel.PRESSURE_RESOURCES)
            assert all(v >= 0.0 for v in s.values())
            assert sum(s.values()) == pytest.approx(1.0)

    def test_derated_device_never_faster(self, small_profiles):
        _, other = small_profiles
        for name in ("T2", "T7"):
            dev = SERVER_TYPES[name]
            p = perfmodel.tenant_pressure(other, dev, 1000.0, 40.0)
            d = derated_device(dev, [p])
            assert d.mem.bw_gbs <= dev.mem.bw_gbs
            assert d.mem.bw_gbs * d.mem.gather_eff <= \
                dev.mem.bw_gbs * dev.mem.gather_eff + 1e-9
            if dev.accel is not None:
                assert d.accel.peak_gflops <= dev.accel.peak_gflops
                assert d.accel.hbm_gbs <= dev.accel.hbm_gbs
                assert d.accel.link_gbs <= dev.accel.link_gbs
            # empty co-set: the device is untouched
            assert derated_device(dev, []) == dev


# ---------------------------------------------------------------------------
# packing (synthetic table — no profiling)
# ---------------------------------------------------------------------------


def _toy_table() -> EfficiencyTable:
    return EfficiencyTable(
        servers=("A", "B"), workloads=("w1", "w2"),
        qps=np.array([[100.0, 80.0], [90.0, 120.0]]),
        power=np.array([[200.0, 200.0], [300.0, 300.0]]),
        avail=np.array([4, 4]))


def _toy_cell() -> ColoCell:
    # both tenants admissible on a shared A machine at dilated rates
    return ColoCell(server="A", tenants=("w1", "w2"), qps=(60.0, 50.0),
                    p95_ms=(15.0, 40.0), dilation=(100 / 60, 80 / 50),
                    power_w=200.0)


class TestPacking:
    def test_empty_table_is_identity_bitwise(self):
        table = _toy_table()
        load = np.array([150.0, 130.0])
        base = provision_hercules(table, load)
        assert base.feasible
        packed = pack_colocated(table, ColocationTable(cells=()), load, base)
        assert packed.merges == 0 and packed.co_machines == ()
        assert np.array_equal(packed.alloc, base.alloc)
        assert packed.provisioned_power_w == base.provisioned_power_w
        assert packed.capacity == base.capacity

    def test_merge_applies_and_strictly_saves_power(self):
        table = _toy_table()
        coloc = ColocationTable(cells=(_toy_cell(),))
        load = np.array([20.0, 15.0])
        base = provision_hercules(table, load)
        packed = pack_colocated(table, coloc, load, base)
        assert packed.merges == 1 and len(packed.co_machines) == 1
        c = packed.co_machines[0]
        assert c.server == "A" and c.tenants == ("w1", "w2")
        # the shared machine carries each tenant's residual need and the
        # fleet still covers the load
        total = (packed.alloc * table.qps).sum(axis=0) + \
            co_served(packed.co_machines, table.workloads)
        assert (total >= load - 1e-9).all()
        assert packed.provisioned_power_w < base.provisioned_power_w
        assert packed.feasible

    def test_merge_respects_utilization_budget(self):
        """A pair whose dilated fractional loads exceed COLOC_PACK_UTIL
        is not merged."""
        table = _toy_table()
        coloc = ColocationTable(cells=(_toy_cell(),))
        load = np.array([30.0, 25.0])   # 30/60 + 25/50 = 1.0 > 0.85
        base = provision_hercules(table, load)
        packed = pack_colocated(table, coloc, load, base)
        assert packed.merges == 0
        assert np.array_equal(packed.alloc, base.alloc)

    def test_infeasible_base_passes_through(self):
        table = _toy_table()
        load = np.array([1e9, 1e9])
        base = provision_hercules(table, load)
        assert not base.feasible
        packed = pack_colocated(table, ColocationTable(cells=(_toy_cell(),)),
                                load, base)
        assert not packed.feasible and packed.merges == 0

    def test_provisioner_shared_machine_failure_victimizes_all_tenants(self):
        """fail() on a type hosting a shared machine yields the CoMachine
        (one victim entry for every tenant packed on it) and the next
        step re-solves on the survivors."""
        table = _toy_table()
        coloc = ColocationTable(cells=(_toy_cell(),))
        prov = StatefulProvisioner(table, "hercules", overprovision=0.05,
                                   colocation=coloc)
        step = prov.step(np.array([20.0, 15.0]))
        assert len(step.coalloc) == 1 and step.coalloc[0].server == "A"
        # shrink the pool so the failure draw must hit a serving machine;
        # shared machines are victimized first (deterministic)
        prov.avail[0] = 1
        victims = prov.fail(0)
        assert len(victims) == 1 and isinstance(victims[0], CoMachine)
        assert victims[0].tenants == ("w1", "w2")
        assert prov.coalloc == ()
        after = prov.step(np.array([20.0, 15.0]))
        assert after.feasible
        # type A is gone; both workloads must be served solo on B
        assert after.alloc[0].sum() == 0 and after.alloc[1].sum() >= 2


# ---------------------------------------------------------------------------
# admission (profiled smoke cells, hermetic cache)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def complements():
    return compile_scenario(get_scenario("colo_complements"))


@pytest.fixture(scope="module")
def recsys_lm():
    comp = compile_scenario(get_scenario("colo_recsys_lm"))
    rc = comp.run()
    rs = simulate_cluster_day(
        dataclasses.replace(comp.inputs, colocation=None),
        policy=comp.spec.policy, config=comp.config)
    return comp, rc, rs


class TestAdmission:
    def test_cells_meet_each_tenants_sla(self, complements):
        coloc = complements.inputs.colocation
        profiles = complements.inputs.profiles
        assert coloc.cells, "no admissible packing in the complements zoo"
        for cell in coloc.cells:
            assert cell.tenants == tuple(sorted(cell.tenants))
            for name, p95, dil, qps in zip(cell.tenants, cell.p95_ms,
                                           cell.dilation, cell.qps):
                assert p95 <= profiles[name].sla_ms
                assert dil >= 1.0      # co-location never speeds a tenant up
                assert qps > 0.0

    def test_sla_breach_is_rejected_with_reason(self, recsys_lm):
        """The LM stream's 1 s per-generation SLA is accel-only feasible:
        every CPU-host pairing is rejected, naming the breaching tenant."""
        comp, _, _ = recsys_lm
        coloc = comp.inputs.colocation
        assert all(c.server == "T7" for c in coloc.cells)
        cpu_rejects = [r for r in coloc.rejected if r[0] in ("T2", "T3")]
        assert cpu_rejects
        for server, tenants, reason in cpu_rejects:
            assert "llama3.2-3b-decode" in tenants
            assert "SLA" in reason

    def test_accel_without_free_slot_rejects(self, complements):
        dev = SERVER_TYPES["T7"]
        capped = dataclasses.replace(
            dev, accel=dataclasses.replace(dev.accel, max_colocate=1))
        coloc = build_colocation_table(
            complements.inputs.profiles, {"T7": capped}, use_cache=False)
        assert coloc.cells == ()
        assert all(r[2] == "no co-location slot" for r in coloc.rejected)
        assert len(coloc.rejected) == 1


# ---------------------------------------------------------------------------
# single-tenant days stay bitwise identical
# ---------------------------------------------------------------------------


class TestSingleTenantBitwise:
    def test_empty_table_day_equals_colocation_none(self):
        comp = compile_scenario(get_scenario("baseline_day"))
        r_none = comp.run()
        r_empty = simulate_cluster_day(
            dataclasses.replace(comp.inputs,
                                colocation=ColocationTable(cells=())),
            policy=comp.spec.policy, config=comp.config)
        _assert_day_equal(r_none.to_dict(), r_empty.to_dict())
        # the colocation-aware day reports (all-zero) shared capacity; the
        # plain day reports none; the JSON shape is unchanged either way
        assert r_none.co_capacity is None
        assert r_empty.co_capacity is not None
        assert (r_empty.co_capacity == 0).all()
        assert "co_capacity" not in r_none.to_dict()
        assert "co_capacity" not in r_empty.to_dict()


# ---------------------------------------------------------------------------
# the online co-located day
# ---------------------------------------------------------------------------


class TestColocatedDay:
    def test_beats_single_tenant_on_peak_power(self, recsys_lm):
        _, rc, rs = recsys_lm
        assert rc.feasible and rs.feasible
        assert rc.peak_power_w < rs.peak_power_w

    def test_full_sla_attainment_per_tenant(self, recsys_lm):
        _, rc, _ = recsys_lm
        assert rc.all_meet_sla
        for name, w in rc.per_workload.items():
            assert w["interval_sla_met_frac"] == 1.0, name

    def test_shared_machines_actually_serve(self, recsys_lm):
        _, rc, rs = recsys_lm
        assert rc.co_capacity is not None and int(rc.co_capacity.sum()) > 0
        assert rs.co_capacity is None


# ---------------------------------------------------------------------------
# per-tenant attribution through a mid-window shared-machine failure
# ---------------------------------------------------------------------------


class TestSharedMachineFailure:
    def test_router_attribution_conserves_and_fails_all_tenant_views(self):
        shared = ("c", "T7", ("a", "b"))
        slots = [
            ServerSlot("T2", 10.0),
            ServerSlot("T2", 10.0),
            ServerSlot("T7", 5.0, machine=shared + (0,)),
        ]
        router = QueryRouter(slots)
        arrivals = np.linspace(0.0, 10.0, 200)
        assigned = router.assign_stream(arrivals)
        latency = np.full(200, 0.01)
        latency[::7] = 2.0
        attr = router.sla_attribution(assigned, latency, sla_s=1.0)
        assert sum(g["n_queries"] for g in attr.values()) == 200
        assert sum(g["n_met"] for g in attr.values()) == \
            int((latency <= 1.0).sum())
        assert set(attr) <= {None, shared + (0,)}
        hit = router.mark_machine_failed(shared)
        assert hit == [slots[2]] and not slots[2].healthy
        assert slots[0].healthy and slots[1].healthy

    def test_mid_window_shared_failure_day(self):
        """A shared machine dies mid-window: every tenant on it is
        victimized.  The tenant with surviving slots re-routes — its
        query count is conserved and retried queries are reported; the
        tenant whose *only* slot died is reported honestly (documented
        no-healthy-slot semantics), not silently dropped."""
        base = get_scenario("colo_recsys_lm")
        # seed=1 makes the provisioner's (seeded) failure draw hit a
        # serving T7 machine; shared machines are then victimized first
        spec = dataclasses.replace(base, name="colo_recsys_lm_failure",
                                   seed=1)
        comp = compile_scenario(spec)
        clean = comp.run()
        assert clean.feasible and int(clean.co_capacity[:3].sum()) == 3
        t7 = comp.inputs.table.servers.index("T7")
        failed = simulate_cluster_day(
            dataclasses.replace(comp.inputs, failures=[(2, t7, 0.5)]),
            policy=comp.spec.policy, config=comp.config)
        shared_events = [e for e in failed.events if "shared" in e]
        assert shared_events, failed.events
        assert "dlrm-rmc1" in shared_events[0]
        assert "llama3.2-3b-decode" in shared_events[0]

        def total(r, name):
            return sum(n for n in r.series["per_workload"][name]["n_queries"]
                       if n)

        # rmc1 has CPU slots too: conserved through the re-route, with
        # retried queries attributed to it
        assert total(failed, "dlrm-rmc1") == total(clean, "dlrm-rmc1")
        assert failed.per_workload["dlrm-rmc1"]["n_retried"] > 0
        # the LM stream ran only on the failed shared machine: the day is
        # honestly infeasible and the loss is visible in its query count
        assert not failed.feasible
        assert total(failed, "llama3.2-3b-decode") < \
            total(clean, "llama3.2-3b-decode")
