"""Hypothesis property tests on the MoE dispatch and serving invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev-only dependency (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.dist.moe import dispatch_indices, expert_capacity
from repro.models.layers import MoEConfig
from repro.serving.simulator import _split_queries


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(1, 64),
    k=st.integers(1, 4),
    E=st.integers(2, 16),
    cap=st.integers(1, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_dispatch_slots_are_consistent(n, k, E, cap, seed):
    """Every kept (token, k) assignment owns exactly one slot; slot buffers
    point back at the right token; per-expert occupancy <= capacity."""
    k = min(k, E)
    r = np.random.default_rng(seed)
    topk = jnp.asarray(r.integers(0, E, (n, k)), jnp.int32)
    buf_token, buf_valid, slot_of = jax.jit(
        dispatch_indices, static_argnums=(1, 2, 3, 4)
    )(topk, E, cap, 0, E)
    buf_token, buf_valid, slot_of = map(np.asarray, (buf_token, buf_valid, slot_of))

    # occupancy per expert never exceeds capacity (by construction of the
    # buffer layout e*cap + rank, rank < cap)
    occupancy = buf_valid.reshape(E, cap).sum(axis=1)
    assert (occupancy <= cap).all()

    # every non-dropped assignment maps to a valid slot holding its token
    for t in range(n):
        for j in range(k):
            s = slot_of[t, j]
            if s >= 0:
                assert buf_valid[s]
                assert buf_token[s] == t
    # slots are not shared between assignments
    used = slot_of[slot_of >= 0]
    assert len(used) == len(np.unique(used))
    # total kept == total occupied
    assert buf_valid.sum() == (slot_of >= 0).sum()


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 128),
    E=st.integers(2, 64),
    k=st.integers(1, 8),
    cf=st.floats(1.0, 4.0),
)
def test_capacity_is_sufficient_for_uniform_routing(n, E, k, cf):
    k = min(k, E)
    cfg = MoEConfig(d_model=8, d_ff=8, n_experts=E, top_k=k, capacity_factor=cf)
    cap = expert_capacity(n, cfg)
    assert cap * E >= n * k  # enough slots for every assignment in aggregate
    assert cap % 8 == 0


@settings(max_examples=40, deadline=None)
@given(
    sizes=st.lists(st.integers(1, 500), min_size=1, max_size=50),
    d=st.integers(1, 256),
)
def test_split_queries_conserves_items(sizes, d):
    sizes = np.asarray(sizes, np.int64)
    arrivals = np.arange(len(sizes), dtype=np.float64)
    sub_a, sub_s, qid = _split_queries(sizes, arrivals, d)
    assert sub_s.sum() == sizes.sum()              # no items lost
    assert (sub_s >= 1).all() and (sub_s <= d).all()
    # per-query reassembly
    for i, s in enumerate(sizes):
        assert sub_s[qid == i].sum() == s
        assert (sub_a[qid == i] == arrivals[i]).all()
