"""Every module under src/repro must import cleanly.

Cheap regression guard against missing-module / import-graph breakage (the
seed shipped with the entire ``repro.dist`` package absent, which took 6 of
10 test modules down at collection).  Importing a module must also not leak
environment mutations into this process (``repro.launch.dryrun`` sets
XLA_FLAGS at import by design — it must stay contained to a subprocess-style
entry point, so the environment is snapshotted and restored around each
import).
"""
import importlib
import os
import pathlib

import pytest

import repro

# repro is a namespace package (no top-level __init__), so __file__ is None
SRC_ROOT = pathlib.Path(next(iter(repro.__path__)))


def _all_modules():
    mods = []
    for py in SRC_ROOT.rglob("*.py"):
        rel = py.relative_to(SRC_ROOT.parent).with_suffix("")
        parts = list(rel.parts)
        if parts[-1] == "__init__":
            parts = parts[:-1]
        mods.append(".".join(parts))
    return sorted(set(mods))


ALL_MODULES = _all_modules()


def test_module_walk_finds_the_tree():
    # sanity: the walk sees the package layout, including the dist layer
    assert "repro.dist.logical" in ALL_MODULES
    assert "repro.core.workload" in ALL_MODULES
    assert len(ALL_MODULES) > 40


@pytest.mark.parametrize("name", ALL_MODULES)
def test_module_imports_cleanly(name):
    env_before = dict(os.environ)
    try:
        importlib.import_module(name)
    finally:
        # modules that mutate the environment at import (dryrun's XLA_FLAGS
        # pin) must not poison later tests' subprocesses
        os.environ.clear()
        os.environ.update(env_before)
