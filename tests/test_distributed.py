"""Multi-device equivalence tests (8 fake CPU devices).

XLA pins the device count at first init, so each test runs in a fresh
subprocess with --xla_force_host_platform_device_count=8; the parent
pytest process keeps its single real device (per the dry-run contract)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str):
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import warnings; warnings.filterwarnings("ignore")
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.dist import logical
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        """
    ) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=360)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "PASS" in r.stdout, r.stdout


def test_sharded_embedding_matches_local():
    run_sub("""
    from repro.models.embedding import EmbeddingConfig, init_embedding, \\
        embedding_bag_local, embedding_bag
    cfg = EmbeddingConfig(vocab_sizes=(100, 300, 50), dim=8,
                          pooling=(4, 2, 1), row_pad=8)
    p = init_embedding(jax.random.PRNGKey(0), cfg)
    ids = jnp.asarray(np.random.default_rng(0).integers(-1, 50, (16, 3, 4)),
                      jnp.int32)
    ref = embedding_bag_local(p, ids, cfg)
    with logical.axis_rules(mesh, {"batch": "data", "model": "model"}):
        p_sh = jax.device_put(p, {"table": NamedSharding(mesh, P("model", None))})
        out = jax.jit(lambda p, i: embedding_bag(p, i, cfg))(p_sh, ids)
        g_sh = jax.jit(jax.grad(lambda p: (embedding_bag(p, ids, cfg)**2).sum()))(p_sh)
    g = jax.grad(lambda p: (embedding_bag_local(p, ids, cfg)**2).sum())(p)
    assert np.allclose(ref, np.asarray(out), rtol=1e-5, atol=1e-6)
    assert np.allclose(np.asarray(g["table"]), np.asarray(g_sh["table"]),
                       rtol=1e-5, atol=1e-6)
    print("PASS")
    """)


def test_moe_ep_matches_dense():
    run_sub("""
    from repro.models.layers import MoEConfig, init_moe, apply_moe_dense
    from repro.dist.moe import moe_apply
    cfg = MoEConfig(d_model=32, d_ff=16, n_experts=6, top_k=2, n_shared=1,
                    shared_d_ff=64, capacity_factor=8.0, pad_to=4)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
    want, _ = apply_moe_dense(p, x, cfg)
    with logical.axis_rules(mesh, {"batch": "data", "model": "model"}):
        out, _ = jax.jit(lambda p, x: moe_apply(p, x, cfg))(p, x)
    assert np.allclose(want, np.asarray(out), rtol=1e-4, atol=1e-5)
    print("PASS")
    """)


def test_vocab_sharded_ce_matches_local():
    run_sub("""
    from repro.dist.loss import ce_loss
    logits = jax.random.normal(jax.random.PRNGKey(0), (8, 16, 64))
    targets = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, 64)
    ref = float(ce_loss(logits, targets))
    g_ref = jax.grad(lambda l: ce_loss(l, targets))(logits)
    with logical.axis_rules(mesh, {"batch": "data", "model": "model",
                                   "vocab": "model"}):
        lg = jax.device_put(logits, NamedSharding(mesh, P("data", None, "model")))
        out = float(jax.jit(ce_loss)(lg, targets))
        g_sh = jax.jit(jax.grad(lambda l: ce_loss(l, targets)))(lg)
    assert abs(ref - out) < 1e-5
    assert np.allclose(np.asarray(g_sh), np.asarray(g_ref), rtol=1e-4,
                       atol=1e-6)
    print("PASS")
    """)


def test_gnn_vertex_partition_matches_local():
    run_sub("""
    from repro.models.gnn import GNNConfig, init, apply_full, softmax_ce
    from repro.dist.gnn import apply_full_sharded
    cfg = GNNConfig(name="t", d_feat=8, d_hidden=16, n_classes=4)
    p = init(jax.random.PRNGKey(0), cfg)
    N, E = 64, 256
    r = np.random.default_rng(0)
    feats = jnp.asarray(r.normal(size=(N, 8)).astype(np.float32))
    edges = jnp.asarray(r.integers(0, N, (2, E)), jnp.int32)
    labels = jnp.asarray(r.integers(0, 4, N), jnp.int32)
    mask = jnp.ones((N,), bool)
    ref = softmax_ce(apply_full(p, feats, edges, cfg), labels, mask)
    loss = jax.jit(lambda p, f, e, l, m: apply_full_sharded(
        p, f, e, l, m, cfg, mesh, N))(p, feats, edges, labels, mask)
    assert abs(float(ref) - float(loss)) < 1e-4, (float(ref), float(loss))
    print("PASS")
    """)


def test_multipod_2x2x2_matches_local():
    """Multi-pod ("pod", "data", "model") cells lower in the dry-run; this
    pins their numerics: sharded embedding (fwd + grad) and vocab-parallel
    CE under a 2x2x2 fake-device mesh with batch mapped to ("pod", "data")
    must match the single-device reference."""
    run_sub("""
    from repro.models.embedding import EmbeddingConfig, init_embedding, \\
        embedding_bag_local, embedding_bag
    from repro.dist.loss import ce_loss
    mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    rules = {"batch": ("pod", "data"), "model": "model", "vocab": "model"}

    cfg = EmbeddingConfig(vocab_sizes=(100, 300, 50), dim=8,
                          pooling=(4, 2, 1), row_pad=8)
    p = init_embedding(jax.random.PRNGKey(0), cfg)
    ids = jnp.asarray(np.random.default_rng(0).integers(-1, 50, (16, 3, 4)),
                      jnp.int32)
    ref = embedding_bag_local(p, ids, cfg)
    g = jax.grad(lambda p: (embedding_bag_local(p, ids, cfg)**2).sum())(p)
    with logical.axis_rules(mesh3, rules):
        p_sh = jax.device_put(p, {"table": NamedSharding(mesh3, P("model", None))})
        out = jax.jit(lambda p, i: embedding_bag(p, i, cfg))(p_sh, ids)
        g_sh = jax.jit(jax.grad(lambda p: (embedding_bag(p, ids, cfg)**2).sum()))(p_sh)
    assert np.allclose(ref, np.asarray(out), rtol=1e-5, atol=1e-6)
    assert np.allclose(np.asarray(g["table"]), np.asarray(g_sh["table"]),
                       rtol=1e-5, atol=1e-6)

    logits = jax.random.normal(jax.random.PRNGKey(0), (8, 16, 64))
    targets = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, 64)
    ref_ce = float(ce_loss(logits, targets))
    with logical.axis_rules(mesh3, rules):
        lg = jax.device_put(logits, NamedSharding(mesh3, P(("pod", "data"), None, "model")))
        out_ce = float(jax.jit(ce_loss)(lg, targets))
    assert abs(ref_ce - out_ce) < 1e-5, (ref_ce, out_ce)
    print("PASS")
    """)


def test_distributed_flash_decode_matches_local():
    """repro.dist.decode vs the single-device kernel and the dense oracle:
    seq-sharded KV over ("data","model") (long_500k layout, 8 shards) and
    over "model" with batch over "data" (decode_32k layout), GQA groups,
    ragged kv_len landing mid-shard / first shard / past the end."""
    run_sub("""
    from repro.kernels.flash_attention.flash_decode import flash_decode_pallas
    from repro.kernels.flash_attention.ref import attention_ref
    from repro.dist.decode import flash_decode_sharded, decode_attention
    B, S, H, KVH, hd = 2, 1024, 8, 2, 32       # GQA 4:1
    q = jax.random.normal(jax.random.PRNGKey(0), (B, 1, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KVH, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KVH, hd))
    layouts = [dict(seq_axes=("data", "model"), batch_axes=()),
               dict(seq_axes=("model",), batch_axes=("data",))]
    for lay in layouts:
        for kv_len in (S, 700, 130, 1):        # 700/130: mid-shard ragged
            ref = attention_ref(q, k, v, causal=False, kv_len=kv_len)
            loc = flash_decode_pallas(q, k, v, kv_len=kv_len, bk=128,
                                      interpret=True)
            out = jax.jit(lambda q, k, v, kl=kv_len, la=lay:
                          flash_decode_sharded(
                              q, k, v, kv_len=kl, mesh=mesh, bk=128,
                              interpret=True, **la))(q, k, v)
            assert np.allclose(out, loc, rtol=1e-6, atol=1e-6), (lay, kv_len)
            assert np.allclose(out, ref, rtol=1e-5, atol=1e-6), (lay, kv_len)
    # the logical-binding entry point picks the same path
    with logical.axis_rules(mesh, {"batch": "data", "kv_seq": "model"}):
        out = jax.jit(lambda q, k, v: decode_attention(
            q, k, v, kv_len=700, bk=128))(q, k, v)
    ref = attention_ref(q, k, v, causal=False, kv_len=700)
    assert np.allclose(out, ref, rtol=1e-5, atol=1e-6)
    print("PASS")
    """)


def test_decode_cell_seq_sharded_matches_local():
    """End-to-end decode step (prefill -> one-token decode) with the cache
    seq-sharded as the long_500k cell lays it out: the distributed flash
    path must match the single-device naive decode, and build_cell must
    wire decode cells onto it."""
    run_sub("""
    import dataclasses
    from repro.common.types import ArchKind
    from repro.dist.sharding import logical_rules, kv_seq_axes, kv_cache_spec
    from repro.models import transformer as tf_lib
    from repro.launch.steps import build_cell
    from repro.launch.mesh import make_debug_mesh

    cfg = tf_lib.LMConfig(name="t", n_layers=2, d_model=64, n_heads=8,
                          n_kv_heads=2, d_ff=128, vocab=128, head_dim=16,
                          dtype=jnp.float32)
    B, S, pos = 1, 256, 100                    # kv_len=101 splits shard 3
    p = tf_lib.init(jax.random.PRNGKey(0), cfg)
    cache = tf_lib.init_kv_cache(cfg, B, S)
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, pos), 0, cfg.vocab)
    _, cache = tf_lib.prefill(p, tok, cache, cfg)
    nxt = jax.random.randint(jax.random.PRNGKey(2), (B, 1), 0, cfg.vocab)
    ref, ref_cache = tf_lib.decode_step(p, nxt, cache, pos, cfg)

    cfg_f = dataclasses.replace(cfg, decode_impl="flash")
    rules = dict(logical_rules(ArchKind.LM_DENSE))
    rules["kv_seq"] = kv_seq_axes(B)           # ("data", "model")
    rules["batch"] = None
    spec = NamedSharding(mesh, kv_cache_spec(B))
    cache_sh = jax.device_put(cache, {k: spec for k in cache})
    with logical.axis_rules(mesh, rules):
        out, new_cache = jax.jit(lambda p, t, c: tf_lib.decode_step(
            p, t, c, pos, cfg_f))(p, nxt, cache_sh)
    assert np.allclose(np.asarray(out), np.asarray(ref), rtol=1e-4,
                       atol=1e-5), np.abs(np.asarray(out) - np.asarray(ref)).max()
    for key in ref_cache:
        assert np.allclose(np.asarray(new_cache[key]),
                           np.asarray(ref_cache[key]), rtol=1e-5, atol=1e-6)

    # launch wiring: decode cells bind kv_seq and flip to the flash path
    m = make_debug_mesh()
    cell = build_cell("qwen2-7b", "long_500k", mesh=m)
    assert cell.cfg.decode_impl == "flash"
    assert cell.rules["kv_seq"] == ("data", "model")
    assert cell.rules["batch"] is None
    cell32 = build_cell("qwen2-7b", "decode_32k", mesh=m)
    assert cell32.cfg.decode_impl == "flash"
    assert cell32.rules["kv_seq"] == ("model",)
    print("PASS")
    """)


def test_lm_train_step_runs_sharded():
    """End-to-end: tiny LM train step under a (2,4) mesh with the full
    sharding rules — the integration test for the dry-run path, executed
    for real."""
    run_sub("""
    import dataclasses
    from repro.configs.registry import get_arch
    from repro.launch.steps import build_cell
    from repro.launch import mesh as mesh_lib
    arch = get_arch("olmoe-1b-7b")
    cfg = dataclasses.replace(
        arch.SMOKE, n_layers=2)
    m = mesh_lib.make_debug_mesh()
    cell = build_cell("olmoe-1b-7b", "train_4k", mesh=m, cfg_override=cfg)
    # shrink the batch specs for an actual run: rebuild with smoke dims via
    # direct state init + small batch
    state = jax.jit(cell.init_state)(jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab, (16, 32)), jnp.int32)
    with logical.axis_rules(m, cell.rules):
        step = jax.jit(cell.step_fn)
        state, metrics = step(state, {"tokens": toks})
        state, metrics = step(state, {"tokens": toks})
    assert np.isfinite(float(metrics["loss"]))
    print("PASS")
    """)


def test_multipod_lm_train_step_matches_local():
    """Full LM train step on the 2x2x2 ("pod", "data", "model") mesh
    (ROADMAP carried gap: multi-pod was only covered for embedding + CE):
    the sharded step — state laid out by param_spec_tree/opt_spec_tree,
    batch over ("pod", "data") — must match the same step jitted with no
    mesh binding, and the optimizer moment specs must mirror the params."""
    run_sub("""
    import dataclasses
    from jax.sharding import PartitionSpec
    from repro.configs.registry import get_arch
    from repro.launch.steps import build_cell
    arch = get_arch("olmoe-1b-7b")
    cfg = dataclasses.replace(arch.SMOKE, n_layers=2)
    mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cell = build_cell("olmoe-1b-7b", "train_4k", mesh=mesh3,
                      multi_pod=True, cfg_override=cfg)
    assert tuple(cell.rules["batch"]) == ("pod", "data")

    # adam moments inherit the parameter specs leaf-for-leaf (the
    # opt_spec_tree contract the sharding pass audits)
    p_spec = jax.tree.map(lambda s: s.spec, cell.state_shardings["params"])
    m_spec = jax.tree.map(lambda s: s.spec, cell.state_shardings["opt"]["m"])
    assert jax.tree.all(jax.tree.map(
        lambda a, b: a == b, p_spec, m_spec,
        is_leaf=lambda x: isinstance(x, PartitionSpec)))

    state = jax.jit(cell.init_state)(jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab, (16, 32)), jnp.int32)
    batch = {"tokens": toks}
    ref_state, ref_metrics = jax.jit(cell.step_fn)(state, batch)

    state_sh = jax.device_put(state, cell.state_shardings)
    batch_sh = jax.device_put(batch, cell.batch_shardings)
    with logical.axis_rules(mesh3, cell.rules):
        out_state, out_metrics = jax.jit(cell.step_fn)(state_sh, batch_sh)

    assert abs(float(ref_metrics["loss"]) - float(out_metrics["loss"])) < 1e-4
    for name, sub in (("params", out_state["params"]),
                      ("m", out_state["opt"]["m"])):
        ref_sub = ref_state["params"] if name == "params" else ref_state["opt"]["m"]
        flat_ref = jax.tree_util.tree_leaves_with_path(ref_sub)
        flat_out = jax.tree_util.tree_leaves(sub)
        for (path, r), o in zip(flat_ref, flat_out):
            assert np.allclose(np.asarray(r), np.asarray(o), rtol=1e-4,
                               atol=1e-5), (name, jax.tree_util.keystr(path),
                                            np.abs(np.asarray(r) - np.asarray(o)).max())
    print("PASS")
    """)
