"""Hercules core: gradient search, partition, simulator, cluster LP.

Includes the paper's qualitative claims as assertions (Fig. 4/6/8) and
hypothesis property tests on the provisioning invariants."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # dev-only dep (requirements-dev.txt): skip ONLY the
    # property tests, keep the plain assertions running
    def given(*a, **k):
        return lambda f: pytest.mark.skip(reason="hypothesis not installed")(f)

    def settings(*a, **k):
        return lambda f: f

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

from repro.configs.paper_models import paper_profile
from repro.core.baselines import baymax_qps, deeprecsys_qps
from repro.core.cluster import (
    EfficiencyTable,
    provision_greedy,
    provision_hercules,
    provision_nh,
)
from repro.core.devices import SERVER_TYPES
from repro.core.gradient_search import gradient_search
from repro.core.lp import round_and_repair, solve_relaxation
from repro.core.partition import enumerate_placements
from repro.serving.diurnal import diurnal_trace, load_increment_rate
from repro.serving.simulator import SchedConfig, max_sustainable_qps, simulate


def qsizes(n=400, seed=0):
    r = np.random.default_rng(seed)
    return np.clip(r.lognormal(np.log(64), 1.1, n).astype(np.int64), 1, 1024)


SIZES = qsizes()


class TestPartition:
    def test_cpu_plans(self):
        prof = paper_profile("dlrm-rmc1")
        plans = [p.plan for p in enumerate_placements(prof, SERVER_TYPES["T2"])]
        assert plans == ["cpu_model", "cpu_sd"]

    def test_accel_hot_partition_sized_to_capacity(self):
        prof = paper_profile("dlrm-rmc3")  # 19 GB tables > 16 GB V100
        pls = enumerate_placements(prof, SERVER_TYPES["T7"])
        by = {p.plan: p for p in pls}
        assert "accel_hot" in by and 0.0 < by["accel_hot"].hot_frac < 1.0
        assert "accel_full" not in by  # cannot fit whole model (paper §III-B)

    def test_small_model_fits_whole(self):
        prof = paper_profile("dlrm-rmc3", prod=False)
        pls = enumerate_placements(prof, SERVER_TYPES["T7"])
        assert any(p.plan == "accel_full" for p in pls)

    def test_hot_hit_rate_monotone(self):
        prof = paper_profile("dlrm-rmc1")
        rates = [prof.hot_hit_rate(f) for f in (0.0, 0.05, 0.2, 0.5, 1.0)]
        assert rates == sorted(rates)
        assert rates[0] == 0.0 and rates[-1] == 1.0
        assert rates[2] > 0.5  # locality: 20% of rows cover >50% of accesses


class TestSimulator:
    def test_qps_increases_with_capacity(self):
        prof = paper_profile("dlrm-rmc1")
        pl = enumerate_placements(prof, SERVER_TYPES["T2"])[0]
        q1, _ = max_sustainable_qps(pl, SERVER_TYPES["T2"],
                                    SchedConfig(batch=64, m=4, o=2), 20.0, SIZES)
        q2, _ = max_sustainable_qps(pl, SERVER_TYPES["T2"],
                                    SchedConfig(batch=64, m=8, o=2), 20.0, SIZES)
        # more threads trade bandwidth share against parallel slots for a
        # memory-bound model; never catastrophically worse
        assert q2 >= q1 * 0.8

    def test_latency_grows_with_load(self):
        prof = paper_profile("dlrm-rmc1")
        pl = enumerate_placements(prof, SERVER_TYPES["T2"])[0]
        sched = SchedConfig(batch=64, m=10, o=2)
        lo = simulate(pl, SERVER_TYPES["T2"], sched, 200.0, SIZES)
        hi = simulate(pl, SERVER_TYPES["T2"], sched, 1800.0, SIZES)
        assert hi.p95_ms >= lo.p95_ms

    def test_paper_fig4_op_parallelism_beats_flat(self):
        """10x2 beats 20x1 for RMC1 on CPU-T2 (paper: up to 1.35x)."""
        prof = paper_profile("dlrm-rmc1")
        pl = enumerate_placements(prof, SERVER_TYPES["T2"])[0]
        q20, _ = max_sustainable_qps(pl, SERVER_TYPES["T2"],
                                     SchedConfig(batch=64, m=20, o=1), 20.0, SIZES)
        q10, _ = max_sustainable_qps(pl, SERVER_TYPES["T2"],
                                     SchedConfig(batch=64, m=10, o=2), 20.0, SIZES)
        assert q10 > q20 * 1.1

    def test_paper_fig6_fusion_beats_baselines(self):
        """co-location + fusion > Baymax > DeepRecSys on the accelerator."""
        prof = paper_profile("dlrm-rmc3")
        dev = SERVER_TYPES["T7"]
        q_drs, _, _ = deeprecsys_qps(prof, dev, SIZES)
        q_bay, _, _ = baymax_qps(prof, dev, SIZES)
        res = gradient_search(prof, dev, SIZES)
        assert q_bay >= q_drs
        assert res.qps > q_bay

    def test_nmp_accelerates_memory_bound(self):
        prof = paper_profile("dlrm-rmc1")
        r2 = gradient_search(prof, SERVER_TYPES["T2"], SIZES,
                             o_grid=(1, 2))
        r3 = gradient_search(prof, SERVER_TYPES["T3"], SIZES,
                             o_grid=(1, 2))
        assert r3.qps > r2.qps * 1.5  # NMP x2 serves the gather-bound model


class TestGradientSearch:
    def test_explores_fraction_of_space(self):
        prof = paper_profile("dlrm-rmc1")
        res = gradient_search(prof, SERVER_TYPES["T2"], SIZES, o_grid=(1, 2))
        assert 0 < res.evals < res.space_size
        assert res.qps > 0
        assert res.p95_ms <= prof.sla_ms + 1e-6

    def test_respects_power_budget(self):
        prof = paper_profile("dlrm-rmc1")
        res = gradient_search(prof, SERVER_TYPES["T2"], SIZES,
                              power_budget_w=120.0, o_grid=(1,))
        if res.qps > 0:
            assert res.power_w <= 120.0 + 1e-6


def _rand_table(r, H=3, M=2):
    qps = r.uniform(500, 10_000, (H, M))
    power = r.uniform(100, 600, (H, 1)) * np.ones((1, M))
    avail = r.integers(3, 40, H)
    return EfficiencyTable(tuple(f"T{i}" for i in range(H)),
                           tuple(f"w{i}" for i in range(M)),
                           qps, power, avail)


class TestClusterLP:
    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_property_hercules_feasible_and_no_worse(self, seed):
        """LP result satisfies load + capacity and never beats greedy's
        power from below (global optimum <= greedy's cost)."""
        r = np.random.default_rng(seed)
        t = _rand_table(r)
        total_cap = (t.avail[:, None] * t.qps).sum(axis=0)
        load = r.uniform(0.1, 0.6, 2) * total_cap  # feasible region
        rh = provision_hercules(t, load)
        rg = provision_greedy(t, load)
        if not rg.feasible:
            return
        assert rh.feasible
        served = (rh.alloc * t.qps).sum(axis=0)
        assert (served >= load - 1e-6).all()
        assert (rh.alloc.sum(axis=1) <= t.avail).all()
        assert rh.provisioned_power_w <= rg.provisioned_power_w + 1e-6

    def test_paper_fig8_priority_contention(self):
        """When two workloads compete for a scarce best server type and
        their benefit differs, hercules beats greedy (the Fig. 8 case)."""
        qps = np.array([[2500., 1800.],    # T2 CPU
                        [10000., 9500.],   # T3 NMP (scarce)
                        [8000., 2000.]])   # T7 GPU (good for w0 only)
        power = np.array([[175., 175.], [175., 175.], [475., 475.]])
        t = EfficiencyTable(("T2", "T3", "T7"), ("rmc1", "rmc2"),
                            qps, power, np.array([200, 10, 40]))
        load = np.array([100_000.0, 80_000.0])
        rg = provision_greedy(t, load)
        rh = provision_hercules(t, load)
        assert rg.feasible and rh.feasible
        assert rh.provisioned_power_w < rg.provisioned_power_w

    def test_nh_worse_than_greedy(self):
        r = np.random.default_rng(3)
        t = _rand_table(r, H=4, M=2)
        total_cap = (t.avail[:, None] * t.qps).sum(axis=0)
        load = 0.3 * total_cap
        rn = provision_nh(t, load, seed=1)
        rg = provision_greedy(t, load)
        if rn.feasible and rg.feasible:
            assert rg.provisioned_power_w <= rn.provisioned_power_w + 1e-6

    def test_lp_matches_bruteforce_small(self):
        qps = np.array([[10.0, 8.0], [5.0, 9.0]])
        power = np.array([[3.0, 3.0], [2.0, 2.0]])
        t = EfficiencyTable(("A", "B"), ("x", "y"), qps, power,
                            np.array([4, 4]))
        load = np.array([20.0, 18.0])
        r = provision_hercules(t, load)
        # brute force integer search
        best = np.inf
        for a in np.ndindex(5, 5, 5, 5):
            n = np.array(a, float).reshape(2, 2)
            if (n.sum(1) <= t.avail).all() and ((n * qps).sum(0) >= load).all():
                best = min(best, (n * power).sum())
        assert r.feasible
        assert r.provisioned_power_w <= best * 1.15  # near-optimal rounding


class TestDiurnal:
    def test_trace_shape(self):
        tr = diurnal_trace(50_000, seed=0)
        assert tr.max() <= 50_000 * 1.1
        assert tr.min() < 0.55 * tr.max()  # >50% peak-valley fluctuation
        assert 0.0 <= load_increment_rate(tr) <= 1.0
