"""End-to-end behaviour tests for the Hercules system."""
import numpy as np
import pytest

from repro.configs.paper_models import PAPER_MODELS, paper_profile
from repro.core.cluster import EfficiencyTable, provision_day
from repro.core.devices import SERVER_TYPES
from repro.core.gradient_search import gradient_search
from repro.serving.diurnal import diurnal_trace, load_increment_rate


def qsizes(n=300, seed=0):
    r = np.random.default_rng(seed)
    return np.clip(r.lognormal(np.log(64), 1.1, n).astype(np.int64), 1, 1024)


def test_offline_profiling_to_online_provisioning():
    """The paper's two-stage flow end to end on a reduced setup:
    profile 2 workloads x 3 servers -> efficiency table -> provision a
    diurnal day with all three policies -> hercules <= greedy <= nh."""
    sizes = qsizes()
    workloads = ["dlrm-rmc1", "dlrm-rmc3"]
    servers = ["T2", "T3", "T7"]
    qps = np.zeros((3, 2))
    power = np.zeros((3, 2))
    for j, w in enumerate(workloads):
        prof = paper_profile(w)
        for i, s in enumerate(servers):
            res = gradient_search(prof, SERVER_TYPES[s], sizes, o_grid=(1, 2))
            qps[i, j] = res.qps
            power[i, j] = SERVER_TYPES[s].peak_power_w
    assert (qps > 0).all()

    table = EfficiencyTable(tuple(servers), tuple(workloads), qps, power,
                            np.array([70, 15, 5]))
    peak = 0.25 * (table.avail[:, None] * qps).sum(axis=0).min()
    traces = np.stack([diurnal_trace(peak, seed=1, n_steps=48),
                       diurnal_trace(peak, seed=2, n_steps=48)])
    R = load_increment_rate(traces[0])
    out = {}
    for pol in ("nh", "greedy", "hercules"):
        out[pol] = provision_day(table, traces, policy=pol, overprovision=R)
        assert out[pol]["feasible"], pol
    assert out["hercules"]["peak_power_w"] <= out["greedy"]["peak_power_w"] + 1e-6
    assert out["greedy"]["avg_power_w"] <= out["nh"]["avg_power_w"] + 1e-6


def test_paper_models_all_profile():
    for name in PAPER_MODELS:
        prof = paper_profile(name)
        assert prof.sla_ms > 0
        assert len(prof.ops) >= 2
        t = prof.totals()
        assert t["flops"] > 0
        if name in ("dlrm-rmc1", "dlrm-rmc2"):
            # memory-bound on a CPU server (Fig 1): random-gather time at
            # ~4 GB/s/core exceeds compute time at ~77 GFLOP/s
            # (RMC3 is compute-dominated per the paper)
            assert t["gather_bytes"] / 4e9 > t["flops"] / 77e9
