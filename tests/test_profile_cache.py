"""Persistent offline-profiling cache: correctness and the warm-build
speedup contract (a cache-hit table build must be >= 10x faster than the
cold build it replays)."""
import time

import numpy as np
import pytest

from repro.configs.paper_models import paper_profile
from repro.core import profile_cache
from repro.core.baselines import deeprecsys_qps
from repro.core.devices import SERVER_TYPES
from repro.core.efficiency import build_table, profile_pair


def qsizes(n=120, seed=0):
    r = np.random.default_rng(seed)
    return np.clip(r.lognormal(np.log(64), 1.1, n).astype(np.int64), 1, 1024)


@pytest.fixture
def cache_dir(tmp_path, monkeypatch):
    monkeypatch.setattr(profile_cache, "PROFILE_DIR", tmp_path)
    return tmp_path


class TestKeying:
    def test_key_covers_inputs(self):
        prof = paper_profile("dlrm-rmc1")
        dev = SERVER_TYPES["T2"]
        base = profile_cache.pair_key("hercules", prof, dev, qsizes(), seed=0)
        assert profile_cache.pair_key("hercules", prof, dev, qsizes(), seed=0) == base
        assert profile_cache.pair_key("hercules", prof, dev, qsizes(), seed=1) != base
        assert profile_cache.pair_key("baymax", prof, dev, qsizes(), seed=0) != base
        assert profile_cache.pair_key("hercules", prof, dev, qsizes(seed=2),
                                      seed=0) != base
        assert profile_cache.pair_key("hercules", prof,
                                      SERVER_TYPES["T3"], qsizes(), seed=0) != base
        assert profile_cache.pair_key("hercules", prof, dev, qsizes(),
                                      o_grid=(1, 2), seed=0) != base
        assert profile_cache.pair_key("hercules", prof, dev, qsizes(),
                                      seed=0, qps_tol=0.01) != base
        assert profile_cache.pair_key("hercules", prof, dev, qsizes(),
                                      seed=0, engine="reference") != base

    def test_load_rejects_stale_and_corrupt(self, cache_dir):
        p = profile_cache.store("hercules", "w", "s", "k" * 40, {"qps": 1.0})
        assert profile_cache.load("hercules", "w", "s", "k" * 40) == {"qps": 1.0}
        # wrong key (truncated-filename collision) -> miss
        assert profile_cache.load("hercules", "w", "s", "k" * 39 + "x") is None
        p.write_text("{not json")
        assert profile_cache.load("hercules", "w", "s", "k" * 40) is None

    def test_invalidate_subsets(self, cache_dir):
        profile_cache.store("hercules", "w1", "s1", "a" * 40, {})
        profile_cache.store("hercules", "w2", "s1", "b" * 40, {})
        assert profile_cache.invalidate(workload="w1") == 1
        assert profile_cache.invalidate() == 1


class TestWarmBuilds:
    def test_profile_pair_roundtrip(self, cache_dir):
        prof = paper_profile("dlrm-rmc1")
        dev = SERVER_TYPES["T2"]
        qs = qsizes()
        cold = profile_pair(prof, dev, qs, o_grid=(1, 2))
        assert len(list(cache_dir.glob("*.json"))) == 1
        warm = profile_pair(prof, dev, qs, o_grid=(1, 2))
        assert warm == cold  # identical record replayed from disk

    def test_warm_table_build_10x_faster(self, cache_dir):
        profiles = {"dlrm-rmc1": paper_profile("dlrm-rmc1")}
        servers = {"T2": SERVER_TYPES["T2"]}
        avail = {"T2": 10}
        qs = qsizes()
        t0 = time.perf_counter()
        table_cold, rec_cold = build_table(profiles, servers, avail,
                                           query_sizes=qs)
        cold_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        table_warm, rec_warm = build_table(profiles, servers, avail,
                                           query_sizes=qs)
        warm_s = time.perf_counter() - t0
        assert rec_warm == rec_cold
        assert np.array_equal(table_warm.qps, table_cold.qps)
        assert warm_s < cold_s / 10, (cold_s, warm_s)

    def test_baseline_cache_roundtrip(self, cache_dir):
        prof = paper_profile("dlrm-rmc1")
        dev = SERVER_TYPES["T2"]
        qs = qsizes()
        q1, s1, p1 = deeprecsys_qps(prof, dev, qs, use_cache=True)
        q2, s2, p2 = deeprecsys_qps(prof, dev, qs, use_cache=True)
        assert q1 == q2 and s1 == s2 and p1.plan == p2.plan
        assert any("deeprecsys" in f.name for f in cache_dir.glob("*.json"))
