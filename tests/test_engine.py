"""Equivalence tests: vectorized engine + fast simulator paths vs the
retained reference heap loops, across all three plan families, seeds and
edge cases (d=1 queues, single thread, burst arrivals, zero-size queries).
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # dev-only dep (requirements-dev.txt): skip ONLY the
    # property tests, keep the plain assertions running
    def given(*a, **k):
        return lambda f: pytest.mark.skip(reason="hypothesis not installed")(f)

    def settings(*a, **k):
        return lambda f: f

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

from repro.configs.paper_models import paper_profile
from repro.core.devices import SERVER_TYPES
from repro.core.partition import enumerate_placements
from repro.serving.engine import fifo_finish, fifo_finish_state
from repro.serving.simulator import (
    SchedConfig,
    SimCache,
    _sized_queries,
    _split_queries,
    max_sustainable_qps,
    simulate,
    simulate_rates,
)


def qsizes(n=150, seed=0):
    r = np.random.default_rng(seed)
    return np.clip(r.lognormal(np.log(64), 1.1, n).astype(np.int64), 1, 1024)


def _cases():
    """(profile, device, placement, sched) across all plan families."""
    out = []
    p1, d2 = paper_profile("dlrm-rmc1"), SERVER_TYPES["T2"]
    p3, d7 = paper_profile("dlrm-rmc3"), SERVER_TYPES["T7"]
    scheds = {
        "cpu_model": [SchedConfig(64, 10, 2), SchedConfig(32, 1, 1),
                      SchedConfig(1024, 20, 1)],
        "cpu_sd": [SchedConfig(64, 10, 2, sd_sparse=5),
                   SchedConfig(256, 4, 1, sd_sparse=16)],
        "accel": [SchedConfig(256, 4, 1), SchedConfig(64, 1, 2, fuse=False),
                  SchedConfig(1024, 8, 1)],
    }
    for prof, dev in ((p1, d2), (p3, d7)):
        for pl in enumerate_placements(prof, dev):
            for sched in scheds.get(pl.plan, scheds["accel"]):
                out.append((prof, dev, pl, sched))
    return out


CASES = _cases()


class TestFifoFinish:
    def test_matches_reference_across_regimes(self):
        rng = np.random.default_rng(0)
        for trial in range(60):
            n = int(rng.integers(1, 300))
            k = int(rng.integers(1, 12))
            ready = np.sort(rng.exponential(1.0, n).cumsum()
                            * rng.uniform(0.001, 1.0))
            if trial % 3 == 0:  # unsorted ready (the S-D dense stage)
                ready = rng.permutation(ready)
            if trial % 5 == 0:  # constant service times
                dur = np.full(n, float(rng.uniform(0.01, 2.0)))
            else:
                dur = rng.choice(
                    rng.uniform(0.01, 2.0, int(rng.integers(1, 8))), n)
            ref = fifo_finish(ready, dur, k, slow=True)
            fast = fifo_finish(ready, dur, k)
            assert np.allclose(ref, fast, rtol=1e-9, atol=1e-9), (trial, n, k)

    def test_burst_arrivals(self):
        # all jobs arrive at once: k servers drain them in FIFO order
        ready = np.zeros(10)
        dur = np.linspace(0.1, 1.0, 10)
        for k in (1, 3, 10, 20):
            ref = fifo_finish(ready, dur, k, slow=True)
            assert np.allclose(fifo_finish(ready, dur, k), ref,
                               rtol=1e-12, atol=1e-12)

    def test_single_server_is_lindley(self):
        ready = np.array([0.0, 0.1, 0.15, 5.0])
        dur = np.array([1.0, 0.2, 0.2, 0.1])
        want = np.array([1.0, 1.2, 1.4, 5.1])
        assert np.allclose(fifo_finish(ready, dur, 1), want)
        assert np.allclose(fifo_finish(ready, dur, 1, slow=True), want)

    def test_idle_servers_and_empty(self):
        ready = np.array([0.5, 0.6])
        dur = np.array([1.0, 1.0])
        assert np.allclose(fifo_finish(ready, dur, 5), ready + dur)
        assert fifo_finish(np.zeros(0), np.zeros(0), 3).shape == (0,)

    @settings(max_examples=60, deadline=None)
    @given(seed=st.integers(0, 10_000), n=st.integers(1, 120),
           k=st.integers(1, 9), distinct=st.integers(1, 6))
    def test_property_matches_reference(self, seed, n, k, distinct):
        rng = np.random.default_rng(seed)
        ready = rng.exponential(0.3, n).cumsum()
        dur = rng.choice(rng.uniform(0.01, 1.0, distinct), n)
        assert np.allclose(fifo_finish(ready, dur, k),
                           fifo_finish(ready, dur, k, slow=True),
                           rtol=1e-9, atol=1e-9)


class TestCarriedPrefix:
    """Continuous-time windows: splitting a stream at any point and
    carrying the pool's end state (``fifo_finish_state``) into the second
    half must reproduce the unsplit run — backlog conservation at the
    engine level, for every k regime (Lindley closed form, idle pool,
    scalar sweep)."""

    def _roundtrip(self, ready, dur, k, cut):
        whole = fifo_finish(ready, dur, k)
        e1, state = fifo_finish_state(ready[:cut], dur[:cut], k)
        e2, _ = fifo_finish_state(ready[cut:], dur[cut:], k, free0=state)
        np.testing.assert_allclose(np.concatenate([e1, e2]), whole,
                                   rtol=1e-12, atol=1e-12)

    def test_split_equals_whole_across_regimes(self):
        rng = np.random.default_rng(7)
        for trial in range(40):
            n = int(rng.integers(2, 200))
            k = int(rng.integers(1, 10))
            ready = rng.exponential(0.2, n).cumsum()
            dur = rng.uniform(0.05, 1.5, n)
            self._roundtrip(ready, dur, k, int(rng.integers(1, n)))

    def test_free0_none_is_idle_pool(self):
        rng = np.random.default_rng(1)
        ready = rng.exponential(0.2, 50).cumsum()
        dur = rng.uniform(0.05, 1.0, 50)
        for k in (1, 3, 100):
            idle = fifo_finish(ready, dur, k)
            seeded = fifo_finish(ready, dur, k, free0=np.zeros(k))
            np.testing.assert_allclose(seeded, idle, rtol=1e-12, atol=0)

    def test_busy_prefix_delays_first_jobs(self):
        # a server still busy until t=10 cannot start earlier than that
        ready = np.array([0.0, 1.0, 2.0])
        dur = np.ones(3)
        out = fifo_finish(ready, dur, 1, free0=np.array([10.0]))
        assert np.allclose(out, [11.0, 12.0, 13.0])
        ends, state = fifo_finish_state(ready, dur, 2,
                                        free0=np.array([10.0, 0.0]))
        # the idle second server takes jobs while the busy one drains
        assert ends[0] == 1.0 and state.shape == (2,)
        ref = fifo_finish(ready, dur, 2, slow=True,
                          free0=np.array([10.0, 0.0]))
        np.testing.assert_allclose(ends, ref)

    def test_idle_shortcut_state_matches_sweep(self):
        # k >= n with every server free before the first arrival: the
        # vectorized shortcut's ends AND end state must equal the heap's
        from repro.serving.engine import _sweep

        rng = np.random.default_rng(5)
        ready = np.sort(rng.uniform(10.0, 20.0, 6))
        dur = rng.uniform(0.1, 1.0, 6)
        free0 = rng.uniform(0.0, 9.0, 10)
        ends, state = fifo_finish_state(ready, dur, 10, free0=free0)
        ref_ends, ref_state = _sweep(ready, dur, 10, free0,
                                     return_state=True)
        np.testing.assert_allclose(ends, ref_ends, rtol=0, atol=0)
        np.testing.assert_allclose(state, ref_state, rtol=0, atol=0)

    def test_state_matches_reference_heap(self):
        rng = np.random.default_rng(3)
        ready = rng.exponential(0.1, 80).cumsum()
        dur = rng.uniform(0.1, 0.8, 80)
        free0 = rng.uniform(0.0, 5.0, 4)
        fast = fifo_finish(ready, dur, 4, free0=free0)
        slow = fifo_finish(ready, dur, 4, slow=True, free0=free0)
        np.testing.assert_allclose(fast, slow, rtol=1e-12, atol=0)


class TestSimulatorEquivalence:
    @pytest.mark.parametrize(
        "case", CASES,
        ids=[f"{c[2].plan}-m{c[3].m}d{c[3].batch}o{c[3].o}" for c in CASES])
    def test_simulate_fast_matches_reference(self, case):
        prof, dev, pl, sched = case
        for rate in (300.0, 4000.0):
            qs = _sized_queries(qsizes(), rate, prof.sla_ms, 0)
            ref = simulate(pl, dev, sched, rate, qs, 0, engine="reference")
            fast = simulate(pl, dev, sched, rate, qs, 0, engine="fast")
            for f in ("qps", "p50_ms", "p95_ms", "p99_ms", "avg_power_w"):
                a, b = getattr(ref, f), getattr(fast, f)
                assert abs(a - b) <= 1e-6 * max(abs(a), 1e-9), (f, a, b)
            for u in ref.utils:
                assert abs(ref.utils[u] - fast.utils[u]) < 1e-6

    def test_max_sustainable_qps_engines_agree(self):
        sizes = qsizes()
        for prof, dev, pl, sched in CASES[::3]:
            q_ref, _ = max_sustainable_qps(pl, dev, sched, prof.sla_ms, sizes,
                                           engine="reference")
            q_fast, _ = max_sustainable_qps(pl, dev, sched, prof.sla_ms, sizes,
                                            engine="fast")
            assert abs(q_fast - q_ref) <= 1e-6 * max(q_ref, 1e-9)

    def test_simulate_rates_matches_per_rate_simulate(self):
        """The CRN sweep reproduces standalone simulate() at every rate
        (prefix property of the shared gap/size streams)."""
        prof, dev, pl, sched = CASES[0]
        rates = [150.0, 900.0, 2700.0]
        cache = SimCache(qsizes(), 0)
        swept = simulate_rates(pl, dev, sched, rates, prof.sla_ms, qsizes(),
                               seed=0, cache=cache)
        for rate, r in zip(rates, swept):
            qs = _sized_queries(qsizes(), rate, prof.sla_ms, 0)
            solo = simulate(pl, dev, sched, rate, qs, 0)
            assert abs(r.qps - solo.qps) <= 1e-9 * solo.qps
            assert abs(r.p95_ms - solo.p95_ms) <= 1e-9 * max(solo.p95_ms, 1e-9)

    def test_qps_tol_early_stop_bounded_error(self):
        prof, dev, pl, sched = CASES[0]
        sizes = qsizes()
        q_exact, _ = max_sustainable_qps(pl, dev, sched, prof.sla_ms, sizes)
        q_tol, _ = max_sustainable_qps(pl, dev, sched, prof.sla_ms, sizes,
                                       qps_tol=0.05)
        assert q_tol <= q_exact + 1e-9
        assert q_tol >= q_exact * 0.90


class TestZeroSizeQueries:
    def test_split_guard(self):
        sizes = np.array([0, 100, 0, 65, 0])
        arrivals = np.linspace(0.0, 1.0, 5)
        sub_a, sub_s, qid = _split_queries(sizes, arrivals, 64)
        assert qid.tolist() == [1, 1, 3, 3]
        assert sub_s.tolist() == [64, 36, 64, 1]  # no remainder corruption
        assert (sub_s > 0).all()

    @pytest.mark.parametrize("engine", ["fast", "reference"])
    def test_no_negative_latency(self, engine):
        prof, dev, pl, sched = CASES[0]
        sizes = qsizes(80)
        sizes[::7] = 0  # zero-size queries finish at their arrival
        r = simulate(pl, dev, sched, 500.0, sizes, 0, engine=engine)
        assert r.qps > 0
        # p50/p95 computed over non-negative latencies only
        assert r.p50_ms >= 0.0

    def test_engines_agree_with_zero_sizes(self):
        prof, dev, pl, sched = CASES[0]
        sizes = qsizes(80)
        sizes[::5] = 0
        ref = simulate(pl, dev, sched, 800.0, sizes, 0, engine="reference")
        fast = simulate(pl, dev, sched, 800.0, sizes, 0, engine="fast")
        assert abs(ref.p95_ms - fast.p95_ms) <= 1e-6 * max(ref.p95_ms, 1e-9)
        assert abs(ref.qps - fast.qps) <= 1e-6 * ref.qps


class TestEventCoreBlocked:
    """Bitwise equality of the event-core blocked kernel against the
    retained scalar sweep — the kernel speculates (light-traffic merge,
    saturated round-robin) but must never change a single bit."""

    @staticmethod
    def _stream(seed, n, distinct, sorted_r=True, zero_frac=0.0):
        rng = np.random.default_rng(seed)
        ready = rng.exponential(0.3, n).cumsum() * rng.uniform(0.05, 2.0)
        if not sorted_r:
            ready = rng.permutation(ready)
        if distinct == 0:  # constant durations (saturated RR territory)
            dur = np.full(n, float(rng.uniform(0.01, 1.0)))
        else:
            dur = rng.choice(rng.uniform(0.01, 1.0, distinct), n)
        if zero_frac > 0.0:
            dur[rng.random(n) < zero_frac] = 0.0
        return ready, dur

    @settings(max_examples=80, deadline=None)
    @given(seed=st.integers(0, 10_000), n=st.integers(1, 400),
           k=st.integers(2, 12), distinct=st.integers(0, 6),
           block=st.sampled_from([3, 7, 33, 128, 8192]),
           carried=st.booleans(), sorted_r=st.booleans(),
           zeros=st.booleans())
    def test_bitwise_vs_sweep(self, seed, n, k, distinct, block, carried,
                              sorted_r, zeros):
        from repro.serving import event_core
        from repro.serving.engine import _sweep
        ready, dur = self._stream(seed, n, distinct, sorted_r,
                                  0.2 if zeros else 0.0)
        free0 = (np.random.default_rng(seed + 1).uniform(0.0, 5.0, k)
                 if carried else None)
        ref_e, ref_s = _sweep(ready, dur, k, free0, return_state=True)
        got_e, got_s = event_core.blocked_fifo_finish(
            ready, dur, k, free0=free0, block=block, return_state=True)
        assert np.array_equal(got_e, ref_e)
        assert np.array_equal(got_s, ref_s)
        got = event_core.blocked_fifo_finish(ready, dur, k, free0=free0,
                                             block=block)
        assert np.array_equal(got, ref_e)

    def test_engine_dispatch_is_bitwise(self):
        # auto-dispatch at n >= 4096 must not perturb fifo_finish results
        from repro.serving import engine
        rng = np.random.default_rng(3)
        n = 5000
        ready = rng.exponential(0.1, n).cumsum()
        dur = rng.choice(rng.uniform(0.01, 0.5, 5), n)
        engine.stats_reset()
        auto = fifo_finish(ready, dur, 4)
        assert engine.stats["blocked"] == 1
        assert np.array_equal(auto, fifo_finish(ready, dur, 4, slow=True))
        e, s = fifo_finish_state(ready, dur, 4, blocked=True)
        e2, s2 = engine._sweep(ready, dur, 4, return_state=True)
        assert np.array_equal(e, e2) and np.array_equal(s, s2)

    def test_block_seams_with_carried_state(self):
        # adversarial: block boundary exactly at a busy-period edge
        from repro.serving import event_core
        from repro.serving.engine import _sweep
        ready = np.concatenate([np.zeros(10), np.full(10, 100.0)])
        dur = np.ones(20)
        for block in (1, 2, 9, 10, 11, 19, 20, 21):
            for k in (2, 3, 7):
                ref = _sweep(ready, dur, k)
                got = event_core.blocked_fifo_finish(ready, dur, k,
                                                     block=block)
                assert np.array_equal(got, ref), (block, k)


class TestEventCoreFleet:
    """Fleet solver: many independent streams in one pass, bitwise-equal
    per stream to the scalar sweep (both via the jitted scan and via the
    sequential fallback)."""

    @staticmethod
    def _streams(seed, n_streams, ragged=True):
        rng = np.random.default_rng(seed)
        out = []
        for i in range(n_streams):
            n = int(rng.integers(50, 80)) if not ragged else \
                int(rng.integers(1, 120))
            r = rng.exponential(0.2, n).cumsum()
            d = rng.choice(rng.uniform(0.01, 0.8, 4), n)
            k = int(rng.choice([2, 2, 4, 8]))
            f0 = rng.uniform(0.0, 3.0, k) if i % 3 == 0 else None
            out.append((r, d, k, f0) if f0 is not None else (r, d, k))
        return out

    @pytest.mark.parametrize("use_jax", [None, False])
    @pytest.mark.parametrize("seed", [0, 7, 23])
    def test_bitwise_vs_sweep(self, seed, use_jax):
        from repro.serving import event_core
        from repro.serving.engine import _sweep
        if use_jax is None:
            pytest.importorskip("jax")
        streams = self._streams(seed, 24)
        got = event_core.fleet_fifo_finish(streams, use_jax=use_jax)
        for s, (e, state) in zip(streams, got):
            r, d, k = s[0], s[1], s[2]
            f0 = s[3] if len(s) > 3 else None
            ref_e, ref_s = _sweep(r, d, k, f0, return_state=True)
            assert np.array_equal(e, ref_e)
            assert np.array_equal(state, ref_s)

    def test_empty_and_narrow(self):
        from repro.serving import event_core
        from repro.serving.engine import _sweep
        assert event_core.fleet_fifo_finish([]) == []
        # a single stream is too narrow for the scan: sequential path,
        # still bitwise
        r = np.array([0.0, 0.1, 0.2, 0.3])
        d = np.array([1.0, 1.0, 1.0, 1.0])
        event_core.stats_reset()
        (e, s), = event_core.fleet_fifo_finish([(r, d, 2)])
        ref_e, ref_s = _sweep(r, d, 2, return_state=True)
        assert np.array_equal(e, ref_e) and np.array_equal(s, ref_s)
        assert event_core.stats["fleet_seq"] == 1

    def test_merge_event_streams_stable(self):
        from repro.serving import event_core
        a = np.array([0.0, 2.0, 2.0])
        b = np.array([2.0, 1.0])
        times, order = event_core.merge_event_streams(a, b)
        assert times.tolist() == [0.0, 1.0, 2.0, 2.0, 2.0]
        # ties: source a's events (indices < len(a)) come first
        assert order.tolist() == [0, 4, 1, 2, 3]


class TestSimCacheEnsure:
    def test_regrowth_is_prefix_stable(self):
        sizes = qsizes()
        a = SimCache(sizes, seed=5)
        b = SimCache(sizes, seed=5)
        gaps0 = a.unit_gaps.copy()
        sized0 = a.sized.copy()
        a.ensure(50_000)
        assert len(a.unit_gaps) >= 50_000
        assert np.array_equal(a.unit_gaps[:len(gaps0)], gaps0)
        assert np.array_equal(a.sized[:len(sized0)], sized0)
        # idempotent below capacity
        cap = len(a.unit_gaps)
        a.ensure(10)
        assert len(a.unit_gaps) == cap
        # a fresh cache never grown agrees on the shared prefix
        assert np.array_equal(b.unit_gaps, a.unit_gaps[:len(b.unit_gaps)])
