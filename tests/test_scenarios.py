"""The declarative scenario zoo (`repro.serving.scenarios`).

Three layers, mirroring the module's contract:

- **scenario matrix** — parametrized over the *full registry* (not a
  hand-kept list), every registered scenario serves a smoke day and is
  pinned on feasibility, series schema, query conservation, and
  same-seed bit-identical replays.  A new scenario arrives pre-covered
  the moment it is registered.
- **golden equivalence** — the re-declared `baseline_day` / `failure_day`
  scenarios (and the example's customized failure day) reproduce the
  previously hand-wired `bench_cluster.py` / `examples/cluster_day.py`
  days bit-for-bit, so `BENCH_cluster.json` metrics are provably
  unchanged by the migration.
- **spec serialization** — `from_dict(to_dict(spec)) == spec` as a
  hypothesis property over generated specs, plus actionable rejection of
  unknown keys, unknown event kinds, malformed timelines and bad types.
"""
import dataclasses
import json
import pathlib
import tempfile

import numpy as np
import pytest

from repro.configs.paper_models import PAPER_MODELS, paper_profile
from repro.core import profile_cache
from repro.core.cluster import TransitionConfig
from repro.core.devices import SERVER_TYPES
from repro.core.efficiency import build_table
from repro.serving import scenarios as sc
from repro.serving.cluster_runtime import (
    DayInputs,
    RuntimeConfig,
    failure_schedule,
    simulate_cluster_day,
)
from repro.serving.diurnal import diurnal_trace, load_increment_rate
from repro.serving.scenarios import (
    EVENT_TYPES,
    SMOKE_AVAILABILITY,
    SMOKE_SERVERS,
    SMOKE_STEPS,
    SMOKE_WORKLOADS,
    Event,
    ScenarioError,
    ScenarioSpec,
    WorkloadSpec,
    compile_scenario,
    full_scale,
    get_scenario,
    register,
    registry,
    run_scenario,
)

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:                                   # dev-only dependency
    HAS_HYPOTHESIS = False


@pytest.fixture(scope="module", autouse=True)
def hermetic_profiles():
    """Profile into a throwaway cache and an empty bundle memo, so the
    suite neither reads nor pollutes `artifacts/profiles/` (and compiled
    tables cannot leak in from another test module)."""
    mp = pytest.MonkeyPatch()
    tmp = pathlib.Path(tempfile.mkdtemp())
    mp.setattr(profile_cache, "PROFILE_DIR", tmp)
    mp.setattr(sc, "_BUNDLES", {})
    mp.setattr(sc, "_COLOC_TABLES", {})
    yield
    mp.undo()


def _assert_day_equal(a, b, path=""):
    """Recursive bitwise equality over simulate_cluster_day outputs."""
    if isinstance(a, dict):
        assert isinstance(b, dict) and a.keys() == b.keys(), path
        for k in a:
            _assert_day_equal(a[k], b[k], f"{path}.{k}")
    elif isinstance(a, np.ndarray) or isinstance(b, np.ndarray):
        assert np.array_equal(a, b), path
    elif isinstance(a, (list, tuple)):
        assert isinstance(b, (list, tuple)) and len(a) == len(b), path
        for i, (x, y) in enumerate(zip(a, b)):
            _assert_day_equal(x, y, f"{path}[{i}]")
    elif isinstance(a, float) and isinstance(b, float) \
            and np.isnan(a) and np.isnan(b):
        pass
    else:
        assert a == b, (path, a, b)


# ---------------------------------------------------------------------------
# the scenario matrix: every registered scenario, pinned automatically
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def zoo_days():
    """One compiled run per registered scenario (shared by the matrix)."""
    return {name: run_scenario(get_scenario(name)) for name in registry()}


class TestScenarioMatrix:
    def test_zoo_is_populated(self):
        """The registry carries the documented zoo, including the two
        golden re-declarations and the geo scenarios."""
        assert len(registry()) >= 9
        assert {"baseline_day", "failure_day", "geo_3region",
                "geo_partition", "geo_drain"} <= set(registry())

    @pytest.mark.parametrize("name", sorted(sc._REGISTRY))
    def test_scenario_smoke_day(self, name, zoo_days):
        """Feasibility + series schema + query conservation for every
        registered scenario — registration is the test plan."""
        spec = get_scenario(name)
        out = zoo_days[name]
        T = spec.n_steps
        if spec.regions is not None:
            # geo scenario: a GeoDayResult — one served day per region
            # plus origin-attributed SLA records (test_geo.py covers the
            # spill semantics; the matrix pins feasibility and schema)
            assert out.feasible, f"{name}: geo day infeasible"
            assert set(out.region_names) == {r.name for r in spec.regions}
            assert len(out.power) == T
            for rname in out.region_names:
                for wname, w in out.origin[rname].items():
                    assert 0.0 <= w["sla_attainment"] <= 1.0, (name, rname)
                    assert w["n_queries"] > 0, (name, rname, wname)
            json.dumps(out.to_dict())    # the bench writes this verbatim
            return
        assert out.feasible, f"{name}: day infeasible"
        assert out.series["interval_s"] > 0
        served = [w.name for w in spec.workloads
                  if w.name in out.series["per_workload"]]
        assert served, name
        for wname in served:
            s = out.series["per_workload"][wname]
            for key in ("p50_ms", "p95_ms", "p99_ms", "sla_attainment",
                        "meets_sla", "n_queries", "backlog_s", "bridged"):
                assert len(s[key]) == T, (name, wname, key)
            assert sum(s["n_queries"]) == \
                out.per_workload[wname]["n_queries"], (name, wname)
            assert all(0.0 <= a <= 1.0 for a in s["sla_attainment"]
                       if a is not None), (name, wname)
            assert all(b >= 0.0 for b in s["backlog_s"]), (name, wname)
        json.dumps(out.series)       # the bench writes this block verbatim

    @pytest.mark.parametrize("name", sorted(sc._REGISTRY))
    def test_scenario_deterministic(self, name, zoo_days):
        """Two independent compile+run passes are bit-identical — every
        source of randomness flows through seeds declared in the spec."""
        _assert_day_equal(zoo_days[name].to_dict(),
                          run_scenario(get_scenario(name)).to_dict())

    @pytest.mark.parametrize("name", sorted(sc._REGISTRY))
    def test_scenario_round_trips(self, name):
        """Every registered spec survives a JSON round trip exactly."""
        spec = get_scenario(name)
        assert ScenarioSpec.from_dict(
            json.loads(json.dumps(spec.to_dict()))) == spec


# ---------------------------------------------------------------------------
# golden equivalence: the re-declared days == the hand-wired days
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def hand_wired():
    """The exact pre-refactor wiring of bench_cluster.py --smoke /
    examples/cluster_day.py --smoke, kept verbatim as the oracle."""
    profiles = {n: paper_profile(n) for n in ("dlrm-rmc1", "dlrm-rmc3")}
    servers = {s: SERVER_TYPES[s] for s in ("T2", "T3", "T7")}
    table, records = build_table(profiles, servers,
                                 {"T2": 70, "T3": 15, "T7": 5})
    cap = (table.avail[:, None] * table.qps).sum(axis=0)
    traces = np.stack([diurnal_trace(0.09 * cap[m], seed=m, n_steps=24)
                       for m in range(len(table.workloads))])
    R = max(load_increment_rate(t) for t in traces)
    return table, records, profiles, servers, traces, R


class TestGoldenEquivalence:
    @pytest.mark.parametrize("policy", ["greedy", "hercules"])
    def test_baseline_day_matches_bench_wiring(self, hand_wired, policy):
        """The registered baseline_day == bench_cluster.py's runtime
        validation day, bit for bit (so BENCH_cluster*.json is pinned)."""
        table, records, profiles, servers, traces, R = hand_wired
        ref = simulate_cluster_day(
            DayInputs(table=table, records=records, profiles=profiles,
                      traces=traces, servers=servers, overprovision=R,
                      transitions=TransitionConfig()),
            policy=policy)
        comp = compile_scenario(get_scenario("baseline_day"))
        assert np.array_equal(comp.traces, traces)
        assert comp.overprovision == R
        _assert_day_equal(ref.to_dict(), comp.run(policy=policy).to_dict())

    def test_failure_day_matches_bench_wiring(self, hand_wired):
        """The registered failure_day == bench_cluster.py's fault-tolerance
        day (failure_schedule fail_prob=0.01 seed=7)."""
        table, records, profiles, servers, traces, R = hand_wired
        fails = failure_schedule(traces.shape[1], len(table.servers),
                                 fail_prob=0.01, seed=7)
        ref = simulate_cluster_day(
            DayInputs(table=table, records=records, profiles=profiles,
                      traces=traces, servers=servers, overprovision=R,
                      transitions=TransitionConfig(), failures=fails))
        comp = compile_scenario(get_scenario("failure_day"))
        assert comp.failures == fails
        _assert_day_equal(ref.to_dict(), comp.run().to_dict())

    def test_example_day_matches_example_wiring(self, hand_wired):
        """examples/cluster_day.py's customized failure day (2% / seed 0,
        including the --event-core re-serve) == the old hand wiring."""
        table, records, profiles, _, traces, R = hand_wired
        fails = failure_schedule(traces.shape[1], len(table.servers),
                                 fail_prob=0.02, seed=0)
        day = dataclasses.replace(
            get_scenario("failure_day"),
            events=(Event.create("random_failures", fail_prob=0.02,
                                 seed=0),))
        inputs = DayInputs(table=table, records=records, profiles=profiles,
                           traces=traces, overprovision=R,
                           transitions=TransitionConfig(), failures=fails)
        ref = simulate_cluster_day(inputs)
        _assert_day_equal(ref.to_dict(), run_scenario(day).to_dict())
        cap = 20_000
        ref_exact = simulate_cluster_day(
            inputs,
            config=RuntimeConfig(event_core=True, event_core_queries=cap))
        exact = run_scenario(dataclasses.replace(
            day, runtime={"event_core": True, "event_core_queries": cap}))
        _assert_day_equal(ref_exact.to_dict(), exact.to_dict())


# ---------------------------------------------------------------------------
# spec construction, registry, and full_scale
# ---------------------------------------------------------------------------


def _spec(**kw):
    base = dict(
        name="t",
        workloads=(WorkloadSpec("dlrm-rmc1"),
                   WorkloadSpec("dlrm-rmc3", trace_seed=1)),
        servers=SMOKE_SERVERS,
        availability=dict(SMOKE_AVAILABILITY),
        n_steps=SMOKE_STEPS,
    )
    base.update(kw)
    return ScenarioSpec(**base)


class TestSpecValidation:
    def test_rejects_unknown_workload(self):
        with pytest.raises(ScenarioError, match="unknown workload"):
            _spec(workloads=(WorkloadSpec("not-a-model"),))

    def test_rejects_duplicate_workloads(self):
        with pytest.raises(ScenarioError, match="duplicate workload"):
            _spec(workloads=(WorkloadSpec("dlrm-rmc1"),
                             WorkloadSpec("dlrm-rmc1")))

    def test_rejects_unknown_server(self):
        with pytest.raises(ScenarioError, match="unknown server type"):
            _spec(servers=("T2", "T99"), availability=None)

    def test_rejects_availability_outside_pool(self):
        with pytest.raises(ScenarioError, match="not in the pool"):
            _spec(availability={"T2": 70, "T10": 3})

    def test_rejects_unknown_policy(self):
        with pytest.raises(ScenarioError, match="unknown policy"):
            _spec(policy="magic")

    def test_rejects_short_day(self):
        with pytest.raises(ScenarioError, match="n_steps"):
            _spec(n_steps=1)

    def test_rejects_unknown_runtime_key(self):
        with pytest.raises(ScenarioError, match="hedge_quantile"):
            _spec(runtime={"hedge_quantil": 0.9})     # typo'd key

    def test_rejects_mistyped_transitions(self):
        with pytest.raises(ScenarioError, match="drain_s"):
            _spec(transitions={"drain_s": "fast"})

    def test_rejects_unknown_event_kind(self):
        with pytest.raises(ScenarioError, match="unknown event kind"):
            Event.create("earthquake", at=3)

    def test_rejects_missing_event_field(self):
        with pytest.raises(ScenarioError, match="missing required field"):
            Event.create("load_surge", start=1, end=3)   # no factor

    def test_rejects_unknown_event_field(self):
        with pytest.raises(ScenarioError, match="unknown key"):
            Event.create("model_push", workload="din", at=3, rampp=2)

    def test_rejects_out_of_range_window(self):
        with pytest.raises(ScenarioError, match="outside the day"):
            _spec(events=(Event.create("load_surge", start=4, end=99,
                                       factor=1.2),))

    def test_rejects_event_referencing_absent_workload(self):
        with pytest.raises(ScenarioError, match="not in this scenario"):
            _spec(events=(Event.create("model_push", workload="din",
                                       at=3),))

    def test_rejects_event_referencing_absent_server(self):
        with pytest.raises(ScenarioError, match="not in this scenario"):
            _spec(events=(Event.create("machine_failure", at=3,
                                       server="T10"),))

    def test_event_defaults_filled(self):
        ev = Event.create("model_push", workload="din", at=3)
        assert ev.params["ramp"] == 1
        assert ev.params["canary_frac"] == pytest.approx(0.02)

    def test_from_dict_rejects_unknown_spec_key(self):
        d = get_scenario("baseline_day").to_dict()
        d["n_stepz"] = 12
        with pytest.raises(ScenarioError, match="n_stepz"):
            ScenarioSpec.from_dict(d)

    def test_from_dict_rejects_malformed_timeline(self):
        d = get_scenario("baseline_day").to_dict()
        d["events"] = [{"at": 3}]                     # event without a kind
        with pytest.raises(ScenarioError, match="missing 'kind'"):
            ScenarioSpec.from_dict(d)

    def test_error_messages_name_the_alternatives(self):
        """Rejections must be actionable: they name what would be valid."""
        with pytest.raises(ScenarioError, match="dlrm-rmc1"):
            _spec(workloads=(WorkloadSpec("nope"),))
        with pytest.raises(ScenarioError, match="load_surge"):
            Event.create("surge", start=1, end=2, factor=2.0)
        with pytest.raises(ScenarioError, match="baseline_day"):
            get_scenario("no-such-scenario")


class TestRegistry:
    def test_register_rejects_duplicates_unless_replace(self):
        spec = _spec(name="baseline_day")
        with pytest.raises(ScenarioError, match="already registered"):
            register(spec)

    def test_register_and_replace(self):
        spec = _spec(name="tmp-registry-probe")
        try:
            register(spec)
            assert get_scenario("tmp-registry-probe") == spec
            spec2 = dataclasses.replace(spec, n_steps=12)
            register(spec2, replace=True)
            assert get_scenario("tmp-registry-probe").n_steps == 12
        finally:
            sc._REGISTRY.pop("tmp-registry-probe", None)
        assert "tmp-registry-probe" not in registry()


class TestFullScale:
    def test_full_scale_structure(self):
        """full_scale lifts to the whole paper zoo with benchmark trace
        seeding and proportionally rescaled event intervals — without
        profiling anything (structure only; the full table is a bench
        concern)."""
        spec = full_scale(get_scenario("flash_crowd"), n_steps=96)
        assert spec.workload_names() == tuple(PAPER_MODELS)
        assert [w.trace_seed for w in spec.workloads] == list(range(6))
        assert spec.servers is None and spec.availability is None
        assert spec.n_steps == 96
        (ev,) = spec.events
        base = get_scenario("flash_crowd").events[0]
        scale = 96 / SMOKE_STEPS
        assert ev.params["start"] == round(base.params["start"] * scale)
        assert ev.params["end"] == round(base.params["end"] * scale)
        assert ev.params["factor"] == base.params["factor"]

    def test_full_scale_keeps_load_frac(self):
        spec = full_scale(get_scenario("baseline_day"))
        assert all(w.load_frac == pytest.approx(sc.COMPARISON_FRAC)
                   for w in spec.workloads)


# ---------------------------------------------------------------------------
# hypothesis: serialization round trip over generated specs
# ---------------------------------------------------------------------------

if HAS_HYPOTHESIS:
    _frac = st.floats(0.01, 0.5, allow_nan=False, allow_infinity=False)
    _hour = st.floats(0.0, 24.0, allow_nan=False, allow_infinity=False)

    _workloads = st.lists(
        st.sampled_from(sorted(PAPER_MODELS)), min_size=1, max_size=3,
        unique=True,
    ).flatmap(lambda names: st.tuples(*[
        st.builds(WorkloadSpec, name=st.just(n), load_frac=_frac,
                  trace_seed=st.integers(0, 99), peak_hour=_hour,
                  shoulder_hour=_hour,
                  valley_frac=st.floats(0.0, 0.9, allow_nan=False),
                  jitter=st.floats(0.0, 0.1, allow_nan=False))
        for n in names]))

    def _events_for(spec: ScenarioSpec):
        names = st.sampled_from(list(spec.workload_names()))
        lo = st.integers(0, spec.n_steps - 2)
        window = st.tuples(lo, st.integers(1, 4)).map(
            lambda se: (se[0], min(se[0] + se[1], spec.n_steps)))
        surge = window.flatmap(lambda w: st.builds(
            Event.create, st.just("load_surge"), start=st.just(w[0]),
            end=st.just(w[1]), factor=st.floats(0.5, 2.0, allow_nan=False),
            workload=st.none() | names))
        push = st.builds(
            Event.create, st.just("model_push"), workload=names,
            at=lo, ramp=st.integers(1, 4),
            canary_frac=st.floats(0.0, 0.5, allow_nan=False,
                                  exclude_max=True))
        fail = st.builds(
            Event.create, st.just("random_failures"),
            fail_prob=st.floats(0.0, 0.2, allow_nan=False),
            seed=st.integers(0, 99))
        return st.lists(surge | push | fail, max_size=3).map(tuple)

    _specs = st.builds(
        ScenarioSpec,
        name=st.text(
            alphabet="abcdefghijklmnopqrstuvwxyz-", min_size=1, max_size=16),
        description=st.just(""),
        workloads=_workloads,
        servers=st.just(SMOKE_SERVERS),
        availability=st.just(dict(SMOKE_AVAILABILITY)) | st.none(),
        n_steps=st.integers(4, 48),
        seed=st.integers(0, 99),
        overprovision=st.none() | st.floats(0.0, 1.0, allow_nan=False),
        policy=st.sampled_from(["nh", "greedy", "hercules"]),
        runtime=st.just({}) | st.just({"hedge_quantile": 0.9}),
        transitions=st.just({}) | st.just({"hysteresis": 0.2}),
    ).flatmap(lambda s: _events_for(s).map(
        lambda evs: dataclasses.replace(s, events=evs)))

    @settings(max_examples=60, deadline=None)
    @given(spec=_specs)
    def test_spec_json_round_trip(spec):
        """from_dict(to_dict(spec)) == spec, through real JSON text."""
        assert ScenarioSpec.from_dict(
            json.loads(json.dumps(spec.to_dict()))) == spec

    @settings(max_examples=30, deadline=None)
    @given(spec=_specs, key=st.sampled_from(
        ["n_stepz", "workload", "extra", "oversubscription"]))
    def test_unknown_spec_keys_rejected(spec, key):
        d = spec.to_dict()
        d[key] = 1
        with pytest.raises(ScenarioError, match="unknown key"):
            ScenarioSpec.from_dict(d)

    @settings(max_examples=30, deadline=None)
    @given(spec=_specs, data=st.data())
    def test_malformed_event_timelines_rejected(spec, data):
        d = spec.to_dict()
        bad = data.draw(st.sampled_from([
            {"kind": "not-an-event", "at": 1},
            {"kind": "load_surge", "start": 0},        # missing end/factor
            {"kind": "machine_failure", "at": 0, "server": "T2",
             "window_frac": "half"},                   # wrong type
        ]))
        d["events"] = list(d["events"]) + [bad]
        with pytest.raises(ScenarioError):
            ScenarioSpec.from_dict(d)
else:  # pragma: no cover - exercised only without the dev deps
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_spec_json_round_trip():
        pass
