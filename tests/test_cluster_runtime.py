"""Stateful provisioning + the query-granular cluster serving runtime:
transition-delay accounting, hysteresis, elastic re-provisioning after
failures, router stream assignment, and PairService <-> fast-engine
equivalence."""
import pathlib
import tempfile

import numpy as np
import pytest

from repro.configs.paper_models import paper_profile
from repro.core import profile_cache
from repro.core.cluster import (
    EfficiencyTable,
    StatefulProvisioner,
    TransitionConfig,
)
from repro.core.devices import SERVER_TYPES
from repro.core.efficiency import build_table, default_query_sizes
from repro.core.partition import enumerate_placements
from repro.serving.cluster_runtime import (
    PairService,
    RuntimeConfig,
    simulate_cluster_day,
)
from repro.serving.diurnal import diurnal_trace, load_increment_rate
from repro.serving.router import QueryRouter, ServerSlot
from repro.serving.simulator import SchedConfig, SimCache, _run_plan


def _table1(qps=100.0, avail=20):
    return EfficiencyTable(("s0",), ("w0",), np.array([[qps]]),
                           np.array([[200.0]]), np.array([avail]))


class TestStatefulProvisioner:
    def test_hysteresis_suppresses_flapping(self):
        prov = StatefulProvisioner(_table1(), overprovision=0.05,
                                   transitions=TransitionConfig(hysteresis=0.10))
        s0 = prov.step(np.array([1000.0]))
        assert s0.resolved and s0.feasible and s0.capacity == 11
        # single-interval wiggles inside the band: held, zero churn
        for load in (1020.0, 980.0, 1005.0):
            s = prov.step(np.array([load]))
            assert not s.resolved and s.churn == 0
            assert (s.alloc == s0.alloc).all()
        # out-of-band growth: re-solve, servers added
        s = prov.step(np.array([1500.0]))
        assert s.resolved and s.added.sum() > 0 and s.removed.sum() == 0
        assert prov.n_holds == 3 and prov.n_resolves == 2

    def test_band_hold_requires_coverage(self):
        # inside the band but no longer covered (capacity lost) -> re-solve
        prov = StatefulProvisioner(_table1(avail=20), overprovision=0.05)
        prov.step(np.array([1000.0]))
        prov.alloc[0, 0] -= 2  # exogenous capacity loss
        s = prov.step(np.array([1000.0]))
        assert s.resolved and s.capacity == 11

    def test_transition_power_accounting(self):
        cfg = TransitionConfig(interval_s=900.0, model_load_s=120.0,
                               drain_s=150.0, hysteresis=0.0)
        t = _table1()
        prov = StatefulProvisioner(t, overprovision=0.0, transitions=cfg)
        s1 = prov.step(np.array([1000.0]))          # warm start: no transient
        assert s1.added.sum() == 0 and s1.power_w == 10 * 200.0
        s2 = prov.step(np.array([1500.0]))          # growth: adds, no drain
        assert s2.added.sum() == 5 and s2.removed.sum() == 0
        assert s2.power_w == 15 * 200.0
        s3 = prov.step(np.array([500.0]))           # shrink: drain power tail
        assert s3.added.sum() == 0 and s3.removed.sum() == 10
        assert s3.power_w == pytest.approx(
            5 * 200.0 + 10 * 200.0 * cfg.drain_s / cfg.interval_s)

    def test_fail_all_serving_takes_victim_and_forces_resolve(self):
        prov = StatefulProvisioner(_table1(avail=3), overprovision=0.0)
        s = prov.step(np.array([280.0]))
        assert s.capacity == 3  # the whole pool serves
        victims = prov.fail(0)
        assert victims == [(0, 0)]
        assert prov.avail[0] == 2 and prov.alloc[0, 0] == 2
        s2 = prov.step(np.array([280.0]))           # needs 3, only 2 left
        assert s2.resolved and not s2.feasible
        s3 = prov.step(np.array([150.0]))           # shrunken pool suffices
        assert s3.feasible

    def test_fail_spare_leaves_alloc_alone(self):
        prov = StatefulProvisioner(_table1(avail=20), overprovision=0.0,
                                   seed=0)
        prov.step(np.array([100.0]))  # 1 of 20 serving
        # 19 spares: overwhelmingly likely the victim is idle
        hits = sum(bool(prov.fail(0)) for _ in range(3))
        assert prov.avail[0] == 17
        assert prov.alloc[0, 0] + hits == 1


class TestRouterStream:
    def test_weight_proportional_and_deterministic(self):
        slots = [ServerSlot("a", 300.0), ServerSlot("b", 100.0)]
        r1 = QueryRouter(list(slots), seed=3)
        r2 = QueryRouter(list(slots), seed=3)
        arr = np.linspace(0.0, 1.0, 10_000)
        a1, a2 = r1.assign_stream(arr), r2.assign_stream(arr)
        assert (a1 == a2).all()
        frac = (a1 == 0).mean()
        assert abs(frac - 0.75) < 0.01

    def test_ready_and_retire_windows(self):
        slots = [ServerSlot("old", 100.0, retire_at=0.5),
                 ServerSlot("new", 100.0, ready_at=0.5)]
        router = QueryRouter(slots, seed=0)
        arr = np.linspace(0.0, 1.0, 1000, endpoint=False)
        a = router.assign_stream(arr)
        assert (a[arr < 0.5] == 0).all()
        assert (a[arr >= 0.5] == 1).all()

    def test_no_acceptor_raises(self):
        router = QueryRouter([ServerSlot("a", 100.0, ready_at=5.0)], seed=0)
        with pytest.raises(RuntimeError):
            router.assign_stream(np.array([0.0, 1.0]))


SIZES = default_query_sizes(300, seed=0)


class TestPairServiceMatchesEngine:
    """A slot receiving the whole CRN stream must reproduce the PR-2 fast
    engine bit-for-bit — the runtime's service model *is* the simulator."""

    def _check(self, workload, server, plan, sched):
        prof = paper_profile(workload)
        dev = SERVER_TYPES[server]
        cache = SimCache(SIZES, seed=0)
        rec = {"qps": 1000.0, "plan": plan, "m": sched.m, "d": sched.batch,
               "o": sched.o, "sd_sparse": sched.sd_sparse}
        svc = PairService(prof, dev, rec, cache)
        n = 400
        arrivals = np.cumsum(cache.unit_gaps[:n] * (1.0 / 900.0))
        got = svc.finish(np.arange(n), arrivals)
        pl = next(p for p in enumerate_placements(prof, dev) if p.plan == plan)
        want, _ = _run_plan(pl, dev, sched, arrivals, cache.sized[:n],
                            "fast", cache.tables, n)
        np.testing.assert_allclose(got, want, rtol=1e-12)

    def test_cpu_model(self):
        self._check("dlrm-rmc1", "T2", "cpu_model",
                    SchedConfig(batch=64, m=4, o=2))

    def test_cpu_sd(self):
        self._check("dlrm-rmc1", "T2", "cpu_sd",
                    SchedConfig(batch=64, m=8, o=2, sd_sparse=6))

    def test_accel_hot(self):
        self._check("dlrm-rmc3", "T7", "accel_hot",
                    SchedConfig(batch=256, m=2, o=2))


@pytest.fixture(scope="module")
def small_cluster():
    """Profiled 2-workload x 3-server setup (hermetic profile cache)."""
    mp = pytest.MonkeyPatch()
    tmp = pathlib.Path(tempfile.mkdtemp())
    mp.setattr(profile_cache, "PROFILE_DIR", tmp)
    profiles = {n: paper_profile(n) for n in ("dlrm-rmc1", "dlrm-rmc3")}
    servers = {s: SERVER_TYPES[s] for s in ("T2", "T3", "T7")}
    table, records = build_table(profiles, servers,
                                 {"T2": 70, "T3": 15, "T7": 5})
    yield table, records, profiles, servers
    mp.undo()


def _traces(table, frac, n_steps):
    cap = (table.avail[:, None] * table.qps).sum(axis=0)
    return np.stack([diurnal_trace(frac * cap[m], seed=m, n_steps=n_steps)
                     for m in range(len(table.workloads))])


class TestClusterRuntime:
    def test_sla_attained_at_benchmark_fraction(self, small_cluster):
        """At the benchmark's comparison load fraction, the runtime's
        achieved latency meets every workload's SLA for both hercules and
        greedy, and hercules provisions no more peak power."""
        table, records, profiles, servers = small_cluster
        traces = _traces(table, 0.09, 24)
        R = max(load_increment_rate(t) for t in traces)
        out = {}
        for pol in ("greedy", "hercules"):
            out[pol] = simulate_cluster_day(
                table, records, profiles, traces, policy=pol,
                servers=servers, overprovision=R)
            assert out[pol]["feasible"], pol
            assert out[pol]["all_meet_sla"], (pol, out[pol]["workloads"])
            for w in out[pol]["workloads"].values():
                assert w["sla_attainment"] >= 0.95
        assert out["hercules"]["peak_power_w"] <= \
            out["greedy"]["peak_power_w"] + 1e-6

    def test_flat_load_holds_allocation(self, small_cluster):
        """Hysteresis: jitter inside the band never re-provisions."""
        table, records, profiles, servers = small_cluster
        M = len(table.workloads)
        cap = (table.avail[:, None] * table.qps).sum(axis=0)
        rng = np.random.default_rng(0)
        flat = np.stack([
            0.08 * cap[m] * (1.0 + 0.02 * rng.standard_normal(12))
            for m in range(M)
        ])
        out = simulate_cluster_day(table, records, profiles, flat,
                                   policy="hercules", servers=servers,
                                   overprovision=0.10)
        assert out["resolves"] == 1 and out["holds"] == 11
        assert out["total_churn"] == 0 and out["all_meet_sla"]

    def test_failure_reroutes_and_reprovisions(self, small_cluster):
        """A serving machine dies mid-window: its unfinished queries retry
        on healthy slots, the provisioner re-solves on the shrunken pool,
        and the day stays feasible with SLAs met."""
        table, records, profiles, servers = small_cluster
        # single-type fleet sized so nearly every machine serves: the
        # victim of a type-wide failure is a serving box
        t1 = EfficiencyTable(("T2",), ("dlrm-rmc1",),
                             table.qps[:1, :1], table.power[:1, :1],
                             np.array([6]))
        cap = 6 * float(t1.qps[0, 0])
        # flat load needing 5 of the 6 machines: the failure victim is a
        # serving box (deterministic for this seed), and the surviving
        # spare lets the re-solve keep the day feasible
        traces = np.full((1, 8), 0.78 * cap)
        out = simulate_cluster_day(
            t1, records, profiles, traces, policy="hercules",
            servers=servers, overprovision=0.05,
            failures=[(2, 0, 0.5)], seed=1)
        assert out["feasible"]
        assert any("serving T2 failed" in e for e in out["events"])
        w = out["workloads"]["dlrm-rmc1"]
        assert w["n_retried"] > 0         # in-flight queries re-dispatched
        assert out["resolves"] >= 2       # elastic re-provision after loss
        # the spare absorbs the loss: steady capacity is restored
        assert out["capacity"][-1] == out["capacity"][0]
        # a day pinned at ~94% per-slot utilization plus a machine loss
        # dents the tail but the fleet keeps serving
        assert w["sla_attainment"] > 0.85

    def test_transition_delay_gates_new_slots(self, small_cluster):
        """A growth step's added servers only serve after model_load_s: with
        an absurd load delay the measured window never sees them, yet
        make-before-break draining keeps the day feasible and in-SLA."""
        table, records, profiles, servers = small_cluster
        traces = _traces(table, 0.09, 12)
        R = max(load_increment_rate(t) for t in traces)
        out = simulate_cluster_day(
            table, records, profiles, traces, policy="hercules",
            servers=servers, overprovision=R,
            transitions=TransitionConfig(model_load_s=600.0, drain_s=700.0))
        assert out["feasible"] and out["all_meet_sla"]
