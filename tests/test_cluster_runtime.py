"""Stateful provisioning + the query-granular cluster serving runtime:
transition-delay accounting, hysteresis, elastic re-provisioning after
failures, router stream assignment, and PairService <-> fast-engine
equivalence."""
import pathlib
import tempfile

import numpy as np
import pytest

from repro.configs.paper_models import paper_profile
from repro.core import profile_cache
from repro.core.cluster import (
    EfficiencyTable,
    StatefulProvisioner,
    TransitionConfig,
)
from repro.core.devices import SERVER_TYPES
from repro.core.efficiency import build_table, default_query_sizes
from repro.core.partition import enumerate_placements
from repro.serving.cluster_runtime import (
    DayInputs,
    PairService,
    RuntimeConfig,
    _state_abs,
    _state_residual,
    simulate_cluster_day,
)
from repro.serving.diurnal import diurnal_trace, load_increment_rate
from repro.serving.router import QueryRouter, ServerSlot
from repro.serving.simulator import SchedConfig, SimCache, _run_plan


def _table1(qps=100.0, avail=20):
    return EfficiencyTable(("s0",), ("w0",), np.array([[qps]]),
                           np.array([[200.0]]), np.array([avail]))


def _day(table, records, profiles, traces, *, policy="hercules",
         config=None, **inputs_kw):
    """Serve one day through the typed API: bundle the day's data into
    :class:`DayInputs`, keep policy/config as call-site arguments."""
    return simulate_cluster_day(
        DayInputs(table=table, records=records, profiles=profiles,
                  traces=traces, **inputs_kw),
        policy=policy, config=config)


class TestStatefulProvisioner:
    def test_hysteresis_suppresses_flapping(self):
        prov = StatefulProvisioner(_table1(), overprovision=0.05,
                                   transitions=TransitionConfig(hysteresis=0.10))
        s0 = prov.step(np.array([1000.0]))
        assert s0.resolved and s0.feasible and s0.capacity == 11
        # single-interval wiggles inside the band: held, zero churn
        for load in (1020.0, 980.0, 1005.0):
            s = prov.step(np.array([load]))
            assert not s.resolved and s.churn == 0
            assert (s.alloc == s0.alloc).all()
        # out-of-band growth: re-solve, servers added
        s = prov.step(np.array([1500.0]))
        assert s.resolved and s.added.sum() > 0 and s.removed.sum() == 0
        assert prov.n_holds == 3 and prov.n_resolves == 2

    def test_band_hold_requires_coverage(self):
        # inside the band but no longer covered (capacity lost) -> re-solve
        prov = StatefulProvisioner(_table1(avail=20), overprovision=0.05)
        prov.step(np.array([1000.0]))
        prov.alloc[0, 0] -= 2  # exogenous capacity loss
        s = prov.step(np.array([1000.0]))
        assert s.resolved and s.capacity == 11

    def test_transition_power_accounting(self):
        cfg = TransitionConfig(interval_s=900.0, model_load_s=120.0,
                               drain_s=150.0, hysteresis=0.0)
        t = _table1()
        prov = StatefulProvisioner(t, overprovision=0.0, transitions=cfg)
        s1 = prov.step(np.array([1000.0]))          # warm start: no transient
        assert s1.added.sum() == 0 and s1.power_w == 10 * 200.0
        s2 = prov.step(np.array([1500.0]))          # growth: adds, no drain
        assert s2.added.sum() == 5 and s2.removed.sum() == 0
        assert s2.power_w == 15 * 200.0
        s3 = prov.step(np.array([500.0]))           # shrink: drain power tail
        assert s3.added.sum() == 0 and s3.removed.sum() == 10
        assert s3.power_w == pytest.approx(
            5 * 200.0 + 10 * 200.0 * cfg.drain_s / cfg.interval_s)

    def test_fail_all_serving_takes_victim_and_forces_resolve(self):
        prov = StatefulProvisioner(_table1(avail=3), overprovision=0.0)
        s = prov.step(np.array([280.0]))
        assert s.capacity == 3  # the whole pool serves
        victims = prov.fail(0)
        assert victims == [(0, 0)]
        assert prov.avail[0] == 2 and prov.alloc[0, 0] == 2
        s2 = prov.step(np.array([280.0]))           # needs 3, only 2 left
        assert s2.resolved and not s2.feasible
        s3 = prov.step(np.array([150.0]))           # shrunken pool suffices
        assert s3.feasible

    def test_fail_spare_leaves_alloc_alone(self):
        prov = StatefulProvisioner(_table1(avail=20), overprovision=0.0,
                                   seed=0)
        prov.step(np.array([100.0]))  # 1 of 20 serving
        # 19 spares: overwhelmingly likely the victim is idle
        hits = sum(bool(prov.fail(0)) for _ in range(3))
        assert prov.avail[0] == 17
        assert prov.alloc[0, 0] + hits == 1


class TestRouterStream:
    def test_weight_proportional_and_deterministic(self):
        slots = [ServerSlot("a", 300.0), ServerSlot("b", 100.0)]
        r1 = QueryRouter(list(slots), seed=3)
        r2 = QueryRouter(list(slots), seed=3)
        arr = np.linspace(0.0, 1.0, 10_000)
        a1, a2 = r1.assign_stream(arr), r2.assign_stream(arr)
        assert (a1 == a2).all()
        frac = (a1 == 0).mean()
        assert abs(frac - 0.75) < 0.01

    def test_ready_and_retire_windows(self):
        slots = [ServerSlot("old", 100.0, retire_at=0.5),
                 ServerSlot("new", 100.0, ready_at=0.5)]
        router = QueryRouter(slots, seed=0)
        arr = np.linspace(0.0, 1.0, 1000, endpoint=False)
        a = router.assign_stream(arr)
        assert (a[arr < 0.5] == 0).all()
        assert (a[arr >= 0.5] == 1).all()

    def test_no_acceptor_raises(self):
        router = QueryRouter([ServerSlot("a", 100.0, ready_at=5.0)], seed=0)
        with pytest.raises(RuntimeError):
            router.assign_stream(np.array([0.0, 1.0]))


SIZES = default_query_sizes(300, seed=0)


class TestPairServiceMatchesEngine:
    """A slot receiving the whole CRN stream must reproduce the PR-2 fast
    engine bit-for-bit — the runtime's service model *is* the simulator."""

    def _check(self, workload, server, plan, sched):
        prof = paper_profile(workload)
        dev = SERVER_TYPES[server]
        cache = SimCache(SIZES, seed=0)
        rec = {"qps": 1000.0, "plan": plan, "m": sched.m, "d": sched.batch,
               "o": sched.o, "sd_sparse": sched.sd_sparse}
        svc = PairService(prof, dev, rec, cache)
        n = 400
        arrivals = np.cumsum(cache.unit_gaps[:n] * (1.0 / 900.0))
        got = svc.finish(np.arange(n), arrivals)
        pl = next(p for p in enumerate_placements(prof, dev) if p.plan == plan)
        want, _ = _run_plan(pl, dev, sched, arrivals, cache.sized[:n],
                            "fast", cache.tables, n)
        np.testing.assert_allclose(got, want, rtol=1e-12)

    def test_cpu_model(self):
        self._check("dlrm-rmc1", "T2", "cpu_model",
                    SchedConfig(batch=64, m=4, o=2))

    def test_cpu_sd(self):
        self._check("dlrm-rmc1", "T2", "cpu_sd",
                    SchedConfig(batch=64, m=8, o=2, sd_sparse=6))

    def test_accel_hot(self):
        self._check("dlrm-rmc3", "T7", "accel_hot",
                    SchedConfig(batch=256, m=2, o=2))


class TestContinuousTime:
    """Backlog carry-over: a stream split at any window boundary and
    re-served from the carried state must reproduce the unsplit run (the
    conservation property behind continuous-time windows)."""

    def _svc(self, workload, server, plan, sched, cache):
        rec = {"qps": 1000.0, "plan": plan, "m": sched.m, "d": sched.batch,
               "o": sched.o, "sd_sparse": sched.sd_sparse}
        return PairService(paper_profile(workload), SERVER_TYPES[server],
                           rec, cache)

    @pytest.mark.parametrize("plan,sched", [
        ("cpu_model", SchedConfig(batch=64, m=4, o=2)),
        ("cpu_sd", SchedConfig(batch=64, m=8, o=2, sd_sparse=6)),
    ])
    def test_window_split_equals_whole(self, plan, sched):
        cache = SimCache(SIZES, seed=0)
        svc = self._svc("dlrm-rmc1", "T2", plan, sched, cache)
        n = 250
        # overloaded rate, so backlog genuinely spans the boundary
        arr = np.cumsum(cache.unit_gaps[:2 * n] * (1.0 / 4000.0))
        whole = svc.finish(np.arange(2 * n), arr)
        st = _state_abs(svc.fresh_state(), 0.0)
        a1 = svc.finish(np.arange(n), arr[:n], state=st)
        w_end = float(arr[n - 1])
        st2 = _state_abs(_state_residual(st, w_end), w_end)
        a2 = svc.finish(np.arange(n, 2 * n), arr[n:], state=st2)
        np.testing.assert_allclose(np.concatenate([a1, a2]), whole,
                                   rtol=1e-12)
        # and the carried backlog was real: window 2 started loaded
        assert max(float(v.max()) for v in st.values()) > w_end

    def test_backlog_persists_across_intervals(self, small_cluster):
        """A fleet pinned just past its feasibility frontier (the re-solve
        is infeasible, the pool serves best-effort at ~103% utilization)
        accumulates backlog interval over interval under carry-over; the
        idle-pool reset showed a flat, flattering tail at the exact same
        offered load."""
        table, records, profiles, servers = small_cluster
        t1 = EfficiencyTable(("T2",), ("dlrm-rmc1",),
                             table.qps[:1, :1], table.power[:1, :1],
                             np.array([4]))
        cap = 4 * float(t1.qps[0, 0])
        traces = np.concatenate([[0.90], np.full(5, 1.03)])[None, :] * cap
        out = {}
        for label, cfg in (
            ("carry", RuntimeConfig(tail_feedback=False)),
            ("reset", RuntimeConfig(carry_backlog=False,
                                    hedge_live_queue=False,
                                    tail_feedback=False)),
        ):
            out[label] = _day(
                t1, records, profiles, traces,
                servers=servers, overprovision=0.05, config=cfg, seed=0)
        s_carry = out["carry"].series["per_workload"]["dlrm-rmc1"]
        s_reset = out["reset"].series["per_workload"]["dlrm-rmc1"]
        # carried backlog compounds; the reset runtime never sees it
        assert s_carry["p95_ms"][-1] > 5.0 * s_reset["p95_ms"][-1]
        assert s_carry["backlog_s"][-1] > 5.0 * s_reset["backlog_s"][-1]
        # monotone growth through the overloaded stretch
        assert s_carry["backlog_s"][1] < s_carry["backlog_s"][2] < \
            s_carry["backlog_s"][3]
        # day-level tail inherits the divergence
        assert out["carry"].per_workload["dlrm-rmc1"]["p99_ms"] >= \
            out["reset"].per_workload["dlrm-rmc1"]["p99_ms"]


class TestLiveQueueHedging:
    def test_hedge_rides_the_live_queue(self):
        """A hedge admitted into a busy alternate completes strictly later
        than the old unloaded-service model said it would: completion >=
        issue + solo_time, with equality only on an idle pool."""
        cache = SimCache(SIZES, seed=0)
        rec = {"qps": 1000.0, "plan": "cpu_model", "m": 4, "d": 64,
               "o": 2, "sd_sparse": 0}
        svc = PairService(paper_profile("dlrm-rmc1"), SERVER_TYPES["T2"],
                          rec, cache)
        n = 200
        prim = np.arange(n)
        arr = np.cumsum(cache.unit_gaps[:n] * (1.0 / 4000.0))  # overloaded
        hq = np.array([n + 5])
        t_issue = np.array([float(arr[n // 2])])  # lands mid-backlog
        merged_q = np.concatenate([prim, hq])
        merged_r = np.concatenate([arr, t_issue])
        order = np.argsort(merged_r, kind="stable")
        st = _state_abs(svc.fresh_state(), 0.0)
        f_all = svc.finish(merged_q[order], merged_r[order], state=st)
        pos = np.empty(len(merged_q), np.int64)
        pos[order] = np.arange(len(merged_q))
        f_hedge = float(f_all[pos[n]])
        solo = float(svc.solo_time(hq)[0])
        live_wait = f_hedge - float(t_issue[0])
        assert live_wait >= solo - 1e-12
        assert live_wait > 2.0 * solo  # the queue was busy: much slower
        # idle pool: the live-queue model degenerates to the unloaded time
        st_idle = _state_abs(svc.fresh_state(), 0.0)
        f_idle = svc.finish(hq, t_issue, state=st_idle)
        assert float(f_idle[0]) - float(t_issue[0]) == pytest.approx(
            solo, rel=1e-9)

    def test_hedge_assign_targets(self):
        slots = [ServerSlot("a", 100.0), ServerSlot("b", 300.0),
                 ServerSlot("c", 200.0, ready_at=10.0)]
        router = QueryRouter(slots, seed=0)
        prim = np.array([1, 0, 1])
        t_issue = np.array([0.0, 0.0, 20.0])
        alt = router.hedge_assign(prim, t_issue)
        # never the primary; fastest accepting slot at issue time
        assert alt.tolist() == [0, 1, 2]
        # failed + not-yet-ready slots can't take a duplicate
        router.mark_failed(slots[0])
        assert router.hedge_assign(np.array([1]),
                                   np.array([0.0])).tolist() == [-1]

    def test_day_tail_not_flattered_by_optimistic_hedges(self, small_cluster):
        table, records, profiles, servers = small_cluster
        traces = _traces(table, 0.09, 12)
        R = max(load_increment_rate(t) for t in traces)
        outs = {}
        for label, cfg in (
            ("live", RuntimeConfig()),
            ("optimistic", RuntimeConfig(hedge_live_queue=False)),
        ):
            outs[label] = _day(
                table, records, profiles, traces,
                servers=servers, overprovision=R, config=cfg)
        for name in table.workloads:
            live = outs["live"].per_workload[name]
            opt = outs["optimistic"].per_workload[name]
            # a live-queue hedge can never beat the unloaded-service model
            assert live["p99_ms"] >= opt["p99_ms"] - 1e-9
            assert live["n_hedged"] <= opt["n_hedged"]


class TestTailFeedback:
    def test_violation_vetoes_hold_and_boosts(self):
        cfg = TransitionConfig(hysteresis=0.50, feedback_boost=0.30)
        prov = StatefulProvisioner(_table1(), overprovision=0.0,
                                   transitions=cfg)
        s0 = prov.step(np.array([1000.0]))
        assert s0.capacity == 10
        s1 = prov.step(np.array([1000.0]), tail_ok=True)
        assert not s1.resolved            # in-band: held
        s2 = prov.step(np.array([1000.0]), tail_ok=False)
        assert s2.resolved                # violation vetoes the hold
        assert s2.capacity == 13          # 1000 * 1.3 -> 13 servers
        assert prov.n_tail_resolves == 1

    def test_boost_infeasible_falls_back_to_offered_load(self):
        """When the pool cannot fund the feedback headroom but can still
        cover the offered load, the re-solve serves the offered load
        rather than freezing on the stale (undersized) allocation."""
        prov = StatefulProvisioner(_table1(avail=10), overprovision=0.0)
        prov.step(np.array([500.0]))      # 5 of 10 serving
        s = prov.step(np.array([950.0]), tail_ok=False)
        # boosted target 1045 needs 11 > 10 servers; offered load fits
        assert s.feasible and s.capacity == 10
        assert prov.n_tail_resolves == 1

    def test_feedback_recovers_underprovisioned_day(self, small_cluster):
        """A fleet sized to offered load alone sits at ~95% utilization and
        diverges; achieved-tail feedback adds the machine the offered load
        cannot justify and the backlog drains."""
        table, records, profiles, servers = small_cluster
        t1 = EfficiencyTable(("T2",), ("dlrm-rmc1",),
                             table.qps[:1, :1], table.power[:1, :1],
                             np.array([6]))
        cap = 6 * float(t1.qps[0, 0])
        traces = np.full((1, 8), 0.60 * cap)
        outs = {}
        for label, cfg in (("fb", RuntimeConfig()),
                           ("nofb", RuntimeConfig(tail_feedback=False))):
            outs[label] = _day(
                t1, records, profiles, traces,
                servers=servers, overprovision=0.05, config=cfg, seed=1)
        fb, nofb = outs["fb"], outs["nofb"]
        assert fb.tail_resolves > 0 and nofb.tail_resolves == 0
        assert fb.capacity[-1] > fb.capacity[0]             # grew the fleet
        assert (nofb.capacity == nofb.capacity[0]).all()
        s_fb = fb.series["per_workload"]["dlrm-rmc1"]
        s_no = nofb.series["per_workload"]["dlrm-rmc1"]
        assert s_fb["p95_ms"][-1] < s_no["p95_ms"][-1]      # drained
        assert fb.per_workload["dlrm-rmc1"]["sla_attainment"] > \
            nofb.per_workload["dlrm-rmc1"]["sla_attainment"]


@pytest.fixture(scope="module")
def small_cluster():
    """Profiled 2-workload x 3-server setup (hermetic profile cache) —
    the same topology the scenario zoo registers its smoke specs on."""
    from repro.serving.scenarios import (
        SMOKE_AVAILABILITY,
        SMOKE_SERVERS,
        SMOKE_WORKLOADS,
    )
    mp = pytest.MonkeyPatch()
    tmp = pathlib.Path(tempfile.mkdtemp())
    mp.setattr(profile_cache, "PROFILE_DIR", tmp)
    profiles = {n: paper_profile(n) for n in SMOKE_WORKLOADS}
    servers = {s: SERVER_TYPES[s] for s in SMOKE_SERVERS}
    table, records = build_table(profiles, servers,
                                 dict(SMOKE_AVAILABILITY))
    yield table, records, profiles, servers
    mp.undo()


def _traces(table, frac, n_steps):
    cap = table.fleet_capacity()
    return np.stack([diurnal_trace(frac * cap[m], seed=m, n_steps=n_steps)
                     for m in range(len(table.workloads))])


class TestClusterRuntime:
    def test_sla_attained_at_benchmark_fraction(self, small_cluster):
        """At the benchmark's comparison load fraction, the runtime's
        achieved latency meets every workload's SLA for both hercules and
        greedy, and hercules provisions no more peak power."""
        table, records, profiles, servers = small_cluster
        traces = _traces(table, 0.09, 24)
        R = max(load_increment_rate(t) for t in traces)
        out = {}
        for pol in ("greedy", "hercules"):
            out[pol] = _day(
                table, records, profiles, traces, policy=pol,
                servers=servers, overprovision=R)
            assert out[pol].feasible, pol
            assert out[pol].all_meet_sla, (pol, out[pol].per_workload)
            for w in out[pol].per_workload.values():
                assert w["sla_attainment"] >= 0.95
        assert out["hercules"].peak_power_w <= \
            out["greedy"].peak_power_w + 1e-6

    def test_flat_load_holds_allocation(self, small_cluster):
        """Hysteresis: jitter inside the band never re-provisions."""
        table, records, profiles, servers = small_cluster
        M = len(table.workloads)
        cap = table.fleet_capacity()
        rng = np.random.default_rng(0)
        flat = np.stack([
            0.08 * cap[m] * (1.0 + 0.02 * rng.standard_normal(12))
            for m in range(M)
        ])
        out = _day(table, records, profiles, flat,
                   servers=servers, overprovision=0.10)
        assert out.resolves == 1 and out.holds == 11
        assert out.total_churn == 0 and out.all_meet_sla

    def test_failure_reroutes_and_reprovisions(self, small_cluster):
        """A serving machine dies mid-window: its unfinished queries retry
        on healthy slots, the provisioner re-solves on the shrunken pool,
        and the day stays feasible with SLAs met."""
        table, records, profiles, servers = small_cluster
        # single-type fleet sized so nearly every machine serves: the
        # victim of a type-wide failure is a serving box
        t1 = EfficiencyTable(("T2",), ("dlrm-rmc1",),
                             table.qps[:1, :1], table.power[:1, :1],
                             np.array([6]))
        cap = 6 * float(t1.qps[0, 0])
        # flat load needing 5 of the 6 machines: the failure victim is a
        # serving box (deterministic for this seed), and the surviving
        # spare lets the re-solve keep the day feasible
        traces = np.full((1, 8), 0.65 * cap)
        out = _day(
            t1, records, profiles, traces,
            servers=servers, overprovision=0.05,
            failures=[(2, 0, 0.5)], seed=1)
        assert out.feasible
        assert any("serving T2 failed" in e for e in out.events)
        w = out.per_workload["dlrm-rmc1"]
        assert w["n_retried"] > 0         # in-flight queries re-dispatched
        assert out.resolves >= 2          # elastic re-provision after loss
        # the spare absorbs the loss: steady capacity is restored
        assert out.capacity[-1] == out.capacity[0]
        # ~80% per-slot utilization plus a machine loss dents the tail but
        # the fleet keeps serving; the carried backlog from the failure
        # window drains again by the end of the day (continuous-time
        # recovery, not an idle-pool reset)
        assert w["sla_attainment"] > 0.85
        s = out.series["per_workload"]["dlrm-rmc1"]
        assert s["p95_ms"][-1] < max(s["p95_ms"][2:5])
        assert s["backlog_s"][-1] < max(s["backlog_s"][2:5])

    def test_transition_delay_gates_new_slots(self, small_cluster):
        """A growth step's added servers only serve after model_load_s: with
        an absurd load delay the measured window never sees them, yet
        make-before-break draining keeps the day feasible and in-SLA."""
        table, records, profiles, servers = small_cluster
        traces = _traces(table, 0.09, 12)
        R = max(load_increment_rate(t) for t in traces)
        out = _day(
            table, records, profiles, traces,
            servers=servers, overprovision=R,
            transitions=TransitionConfig(model_load_s=600.0, drain_s=700.0))
        assert out.feasible and out.all_meet_sla


class TestSeriesAndConservation:
    def test_series_schema_and_query_conservation(self, small_cluster):
        """The per-interval series is the Fig. 8b record: aligned with the
        trace, JSON-serializable, and query-conserving — every measured
        window accounts for its whole arrival stream exactly once through
        hysteresis holds, provisioning transitions and a mid-window
        machine failure (nothing lost, nothing double-served)."""
        import json

        table, records, profiles, servers = small_cluster
        traces = _traces(table, 0.09, 12)
        R = max(load_increment_rate(t) for t in traces)
        cfgt = TransitionConfig()
        out = _day(
            table, records, profiles, traces,
            servers=servers, overprovision=R,
            failures=[(3, 0, 0.4)], seed=0)
        assert any("failed" in e for e in out.events)
        T = traces.shape[1]
        assert out.series["interval_s"] == cfgt.interval_s
        for m, name in enumerate(table.workloads):
            s = out.series["per_workload"][name]
            for key in ("p50_ms", "p95_ms", "p99_ms", "sla_attainment",
                        "meets_sla", "n_queries", "backlog_s"):
                assert len(s[key]) == T, key
            expect = np.clip(traces[m] * cfgt.interval_s, 64,
                             1500).astype(int)
            assert s["n_queries"] == expect.tolist()
            assert sum(s["n_queries"]) == out.per_workload[name]["n_queries"]
            assert all(0.0 <= a <= 1.0 for a in s["sla_attainment"])
            assert all(b >= 0.0 for b in s["backlog_s"])
            assert 0.0 <= out.per_workload[name]["interval_sla_met_frac"] <= 1.0
        json.dumps(out.series)  # the bench writes this block verbatim


class TestEventCoreDay:
    """The batched event-ordered core (RuntimeConfig(event_core=True)):
    full-interval simulation, honest bridging flags, and bitwise agreement
    with the default path whenever the default's windows already cover
    their intervals."""

    @staticmethod
    def _flat_traces(table, qps, n_steps):
        M = len(table.workloads)
        return np.stack([diurnal_trace(qps, seed=m, n_steps=n_steps)
                         for m in range(M)])

    def test_bitwise_equal_when_windows_cover(self, small_cluster):
        """At a rate where the default path's windows span each interval
        uncapped (and with hedging suppressed), the event core must
        reproduce the default day bit for bit: same per-interval latency
        percentiles, query counts, attainment and power.  This pins the
        k==1-via-Lindley and fleet-kernel parity end to end through
        ``_finish_many``."""
        table, records, profiles, servers = small_cluster
        cfgt = TransitionConfig()
        # peak*interval under the default 1500-query window cap
        peak = 0.9 * 1500 / cfgt.interval_s
        traces = self._flat_traces(table, peak, 8)
        kw = dict(servers=servers, overprovision=0.3, seed=0)
        base = _day(
            table, records, profiles, traces, **kw,
            config=RuntimeConfig(hedge_factor=1e9))
        ev = _day(
            table, records, profiles, traces, **kw,
            config=RuntimeConfig(hedge_factor=1e9, event_core=True))
        assert base.peak_power_w == ev.peak_power_w
        for name in table.workloads:
            sb = base.series["per_workload"][name]
            se = ev.series["per_workload"][name]
            for key in ("p50_ms", "p95_ms", "p99_ms", "n_queries",
                        "sla_attainment", "backlog_s"):
                assert sb[key] == se[key], (name, key)
            assert not any(sb["bridged"])
            assert not any(se["bridged"])

    def test_full_interval_retires_the_bridge(self, small_cluster):
        """At benchmark load the default path caps each window at 1500
        queries and bridges the remainder by stationarity; the event core
        simulates every arrival of the interval and reports no bridging."""
        table, records, profiles, servers = small_cluster
        cfgt = TransitionConfig()
        # 40 qps: 24x the default 1500-query window, yet cheap to simulate
        traces = self._flat_traces(table, 40.0, 6)
        cap = 60_000
        assert float(traces.max()) * cfgt.interval_s < cap
        base = _day(table, records, profiles, traces,
                    servers=servers, overprovision=0.3, seed=0)
        ev = _day(
            table, records, profiles, traces,
            servers=servers, overprovision=0.3, seed=0,
            config=RuntimeConfig(event_core=True, event_core_queries=cap))
        assert ev.feasible
        for m, name in enumerate(table.workloads):
            sb = base.series["per_workload"][name]
            se = ev.series["per_workload"][name]
            assert any(sb["bridged"])          # default truncates + bridges
            assert not any(se["bridged"])      # event core covers the day
            expect = np.clip(traces[m] * cfgt.interval_s, 64, cap)
            assert se["n_queries"] == expect.astype(int).tolist()
            # provisioning decisions ride the same efficiency table
            assert base.peak_power_w == ev.peak_power_w
        assert ev.all_meet_sla, ev.per_workload

    def test_capped_event_day_stays_honest(self, small_cluster):
        """If event_core_queries still truncates the interval, the bridged
        flag must say so — the exactness claim is never silently faked."""
        table, records, profiles, servers = small_cluster
        traces = _traces(table, 0.09, 4)
        ev = _day(
            table, records, profiles, traces,
            servers=servers, overprovision=0.3, seed=0,
            config=RuntimeConfig(event_core=True, event_core_queries=2000))
        for name in table.workloads:
            se = ev.series["per_workload"][name]
            assert all(se["bridged"])
            assert se["n_queries"] == [2000] * traces.shape[1]

    def test_event_ordered_hedges_fire(self, small_cluster):
        """Full-interval populations surface real stragglers; the
        event-ordered pass admits their duplicates into live queues and
        the day still closes feasibly with sane latencies."""
        table, records, profiles, servers = small_cluster
        traces = _traces(table, 0.09, 6)
        ev = _day(
            table, records, profiles, traces,
            servers=servers, overprovision=0.3, seed=0,
            config=RuntimeConfig(event_core=True,
                                 event_core_queries=40_000))
        assert ev.feasible
        n_hedged = sum(w["n_hedged"] for w in ev.per_workload.values())
        assert n_hedged > 0
        for w in ev.per_workload.values():
            assert w["p99_ms"] > 0.0 and np.isfinite(w["p99_ms"])
