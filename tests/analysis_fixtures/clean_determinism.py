"""Clean counterpart for the determinism pass: zero findings expected.

Seeded-Generator threading, virtual clocks, order-normalized sets — the
discipline the simulated paths actually follow.
"""
import numpy as np


def seeded_service_times(seed, n):
    rng = np.random.default_rng(seed)
    child = np.random.default_rng(rng.integers(2**63))
    return rng.exponential(1.0, n), child.normal(size=n)


def spawned_streams(seed, k):
    seq = np.random.SeedSequence(seed)
    return [np.random.Generator(np.random.PCG64(s))
            for s in seq.spawn(k)]


def virtual_clock_step(state, dt):
    # simulated time comes from the event loop, never the wall clock
    return {"now": state["now"] + dt}


def normalized_set_use(queries):
    # sorted() makes set iteration order-stable
    ordered = sorted({q.model for q in queries})
    membership = "q7" in {q.qid for q in queries}   # unordered use: fine
    return ordered, membership
