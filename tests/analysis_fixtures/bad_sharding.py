"""Known-bad corpus for the sharding-consistency pass.

Never imported or executed — parsed by tests/test_analysis.py, which
asserts each line carrying an expect-marker comment is flagged with
exactly the named rule.
"""
import jax
from jax.sharding import PartitionSpec as P

from repro.dist import logical


def constrain_typos(x, mesh):
    x = logical.constrain(x, ("btch", "model"))  # expect: sharding-unknown-logical-axis
    x = logical.constrain(x, ("batch", "residual_sq"))  # expect: sharding-unknown-logical-axis
    return x


def spec_typos(mesh):
    spec = P("modle", None)  # expect: sharding-unknown-mesh-axis
    other = P(None, "mdl")  # expect: sharding-unknown-mesh-axis
    return spec, other


def rule_table_typos(mesh, fn, x):
    with logical.axis_rules(mesh, {
        "batch": "data",
        "typo_axis": "model",  # expect: sharding-unknown-logical-axis
        "heads": "modell",  # expect: sharding-unknown-mesh-axis
    }):
        rules = {"batch": ("pod", "data")}
        rules["kv_sq"] = ("model",)  # expect: sharding-unknown-logical-axis
        return fn(x), rules


def collective_typos(x):
    y = jax.lax.psum(x, "modle")  # expect: sharding-unknown-mesh-axis
    i = jax.lax.axis_index("pods")  # expect: sharding-unknown-mesh-axis
    return y, i


def _replicated(ndim):
    return P(*([None] * ndim))


def silent_fallback_spec_tree(leaves, spec_leaves, treedef):
    if len(leaves) != len(spec_leaves):  # expect: sharding-silent-fallback
        fitted = [_replicated(len(l.shape)) for l in leaves]
    else:
        fitted = spec_leaves
    return jax.tree_util.tree_unflatten(treedef, fitted)
