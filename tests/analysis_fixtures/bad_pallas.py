"""Known-bad corpus for the pallas-kernel pass (parsed, never run)."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 128


def _branchy_kernel(x_ref, o_ref):
    if x_ref[0, 0] > 0:  # expect: pallas-ref-branch
        o_ref[...] = x_ref[...]
    else:
        o_ref[...] = -x_ref[...]


def arity_mismatch(x):
    return pl.pallas_call(  # expect: pallas-no-interpret
        _branchy_kernel,
        grid=(4, 4),
        in_specs=[
            pl.BlockSpec((8, 8), lambda i: (i, 0)),  # expect: pallas-grid-blockspec-rank
        ],
        out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, j, 0)),  # expect: pallas-grid-blockspec-rank
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)


def _ok_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def closure_capture(x, interpret=False):
    offset = x.shape[0] // 2
    return pl.pallas_call(
        _ok_kernel,
        grid=(4,),
        in_specs=[
            pl.BlockSpec((8, 8), lambda i: (i, offset)),  # expect: pallas-index-map-closure
        ],
        out_specs=pl.BlockSpec((8, 8), lambda i: (i + TILE, 0)),  # expect: pallas-index-map-closure
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)


def interpret_hardcoded_off(x):
    return pl.pallas_call(  # expect: pallas-no-interpret
        _ok_kernel,
        grid=(4,),
        in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((8, 8), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=False,
    )(x)
