"""Clean counterpart for the sharding pass: zero findings expected."""
import warnings

import jax
from jax.sharding import PartitionSpec as P

from repro.dist import logical


def constrain_ok(x, mesh):
    x = logical.constrain(x, ("batch", "model"))
    return logical.constrain(x, ("kv_seq", None))


def specs_ok():
    return P("model", None), P(None, ("pod", "data"))


def rule_table_ok(mesh, fn, x):
    with logical.axis_rules(mesh, {"batch": ("pod", "data"),
                                   "heads": "model"}):
        rules = {"batch": ("data",)}
        rules["kv_seq"] = ("data", "model")
        return fn(x), rules


def collectives_ok(x):
    return jax.lax.psum(x, "model"), jax.lax.axis_index("pod")


def runtime_axes_pass_through(x, mesh):
    # computed axis names are out of static reach — never flagged
    return jax.lax.psum(x, tuple(mesh.axis_names))


def _replicated(ndim):
    return P(*([None] * ndim))


def guarded_fallback(leaves, spec_leaves, treedef):
    # warning makes the divergence visible: not a silent fallback
    if len(leaves) != len(spec_leaves):
        warnings.warn("optimizer tree diverged from params")
        fitted = [_replicated(len(l.shape)) for l in leaves]
    else:
        fitted = spec_leaves
    return jax.tree_util.tree_unflatten(treedef, fitted)
