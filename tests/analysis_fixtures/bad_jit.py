"""Known-bad corpus for the jit-purity pass (parsed, never run)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map


@jax.jit
def noisy_step(state, batch):
    print("step", batch)  # expect: jit-purity-print
    loss = jnp.mean((state - batch) ** 2)
    scale = loss.item()  # expect: jit-purity-host-sync
    return state - scale * batch


@functools.partial(jax.jit, static_argnames=("lr",))
def host_math(params, grads, lr):
    norm = np.linalg.norm(grads)  # expect: jit-purity-host-numpy
    if float(params) > 0:  # expect: jit-purity-host-sync
        return params - lr * grads / norm
    return params


def _shard_body(x):
    print("shard", x)  # expect: jit-purity-print
    return jax.lax.psum(x, "model"), x.tolist()  # expect: jit-purity-host-sync


def run_sharded(mesh, x, specs):
    return shard_map(_shard_body, mesh=mesh, in_specs=specs,
                     out_specs=specs)(x)
