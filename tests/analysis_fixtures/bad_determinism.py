"""Known-bad corpus for the determinism pass (parsed, never run).

The fixture path contains ``analysis_fixtures`` which is inside the pass's
simulated-path scope by construction.
"""
import random
import time

import numpy as np


def unseeded_draws(n):
    a = np.random.rand(n)  # expect: determinism-global-rng
    b = np.random.randint(0, 10, size=n)  # expect: determinism-global-rng
    np.random.seed(0)  # expect: determinism-global-rng
    return a, b


def stdlib_random(items):
    random.shuffle(items)  # expect: determinism-stdlib-random
    return items, random.random()  # expect: determinism-stdlib-random


def wall_clock_latency():
    t0 = time.time()  # expect: determinism-wall-clock
    t1 = time.perf_counter()  # expect: determinism-wall-clock
    return t1 - t0


def set_order_leaks(queries):
    order = []
    for q in {"a", "b", "c"}:  # expect: determinism-set-order
        order.append(q)
    ids = [hash(q) for q in set(queries)]  # expect: determinism-set-order
    total = sum({0.1, 0.2, 0.3})  # expect: determinism-set-order
    return order, ids, total
