"""Clean counterpart for the jit-purity pass: zero findings expected."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map


@jax.jit
def pure_step(state, batch):
    loss = jnp.mean((state - batch) ** 2)
    jax.debug.print("loss {l}", l=loss)     # traced-safe print
    return state - 0.1 * batch, loss


@functools.partial(jax.jit, static_argnames=("hd", "causal"))
def static_host_math(q, k, hd, causal):
    # np on a static python int is host math at trace time: fine
    scale = 1.0 / np.sqrt(hd)
    s = (q @ k.T) * scale
    if causal:                               # branch on a static arg: fine
        s = jnp.tril(s)
    return s


def _shard_body(x):
    return jax.lax.psum(x, "model")


def run_sharded(mesh, x, specs):
    return shard_map(_shard_body, mesh=mesh, in_specs=specs,
                     out_specs=specs)(x)


def host_side_logging(metrics):
    # not a jitted scope: host syncs are allowed
    print("loss:", float(metrics["loss"]), metrics["acc"].item())
