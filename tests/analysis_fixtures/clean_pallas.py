"""Clean counterpart for the pallas pass: zero findings expected.

Mirrors the repo's real kernel idioms: lambda-default capture, partial-
wrapped kernels, interpret= plumbed through.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scale_kernel(x_ref, o_ref, *, gain):
    o_ref[...] = x_ref[...] * gain


def scaled_copy(x, *, gain=2.0, interpret=False):
    group = 4
    grid = (x.shape[0] // 8, x.shape[1] // 8)
    kernel = functools.partial(_scale_kernel, gain=gain)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((8, 8), lambda i, j: (i, j)),
            # sanctioned capture: bound as a lambda default at definition
            pl.BlockSpec((8, 8), lambda i, j, g=group: (i // g, j)),
        ],
        out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, x)


def _masked_kernel(x_ref, o_ref):
    # data-dependent select stays inside jnp.where / pl.when, not Python if
    x = x_ref[...]
    o_ref[...] = jnp.where(x > 0, x, 0.0)


def relu_tiled(x, interpret=False):
    return pl.pallas_call(
        _masked_kernel,
        grid=(x.shape[0] // 8,),
        in_specs=[pl.BlockSpec((8, x.shape[1]), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((8, x.shape[1]), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)
