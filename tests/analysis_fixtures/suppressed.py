"""Suppression-handling corpus: every finding here carries a repro-ignore
comment and must land in the suppressed list, not the report.

An expect-suppressed marker names what each line suppresses (asserted by
tests/test_analysis.py).
"""
import random
import time

import numpy as np


def benchmark_jitter(n):
    # justified: fixture models an *intentionally* noisy arrival process
    a = np.random.rand(n)  # repro: ignore[determinism-global-rng]  # expect-suppressed: determinism-global-rng
    return a


def wall_clock_probe():
    return time.time()  # repro: ignore[determinism-wall-clock]  # expect-suppressed: determinism-wall-clock


def bare_ignore_suppresses_all(items):
    random.shuffle(items)  # repro: ignore  # expect-suppressed: determinism-stdlib-random
    return items


def multi_rule_line(n):
    t = time.time(); x = np.random.rand(n)  # repro: ignore[determinism-wall-clock, determinism-global-rng]  # expect-suppressed: determinism-wall-clock, determinism-global-rng
    return t, x


def wrong_rule_does_not_suppress(n):
    # suppressing an unrelated rule leaves the finding active
    return np.random.rand(n)  # repro: ignore[determinism-wall-clock]  # expect: determinism-global-rng
