"""Hypothesis property tests for the split-softmax merge (``lse_combine``).

The merge is the shared correctness oracle of the on-chip chunk combine
(kernels/flash_attention/flash_decode.py) and the cross-shard combine
(repro.dist.decode): it must be permutation-invariant over the merge axis
(all-gather order across a multi-axis shard is unspecified), associative
under hierarchical (chunk-then-shard) merging, and agree with a dense
log-sum-exp reference when the partials come from chunks of one score
matrix."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev-only dependency (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.flash_decode import NEG_INF, lse_combine


def _random_partials(rng, n, group, hd, with_empty=False):
    """Partials as the decode kernel emits them: m is a max of logits, l a
    positive denominator, o a weighted value sum; optionally some entries
    are the empty partial (m=NEG_INF, l=0, o=0) a fully-masked shard emits."""
    m = rng.normal(scale=3.0, size=(n, group, 1)).astype(np.float32)
    l = rng.uniform(0.1, 4.0, (n, group, 1)).astype(np.float32)
    o = rng.normal(size=(n, group, hd)).astype(np.float32)
    if with_empty and n > 1:
        k = rng.integers(1, n)
        idx = rng.choice(n, size=k, replace=False)
        m[idx], l[idx], o[idx] = NEG_INF, 0.0, 0.0
    return jnp.asarray(m), jnp.asarray(l), jnp.asarray(o)


def _finalize(l, o):
    return np.asarray(o / np.maximum(l[..., :1], 1e-30))


@settings(max_examples=50, deadline=None)
@given(
    n=st.integers(2, 12),
    group=st.integers(1, 4),
    hd=st.integers(1, 16),
    with_empty=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_lse_combine_permutation_invariant(n, group, hd, with_empty, seed):
    rng = np.random.default_rng(seed)
    m, l, o = _random_partials(rng, n, group, hd, with_empty)
    perm = rng.permutation(n)
    _, l_a, o_a = lse_combine(m, l, o, axis=0)
    _, l_b, o_b = lse_combine(m[perm], l[perm], o[perm], axis=0)
    np.testing.assert_allclose(_finalize(l_a, o_a), _finalize(l_b, o_b),
                               rtol=1e-5, atol=1e-6)


@settings(max_examples=50, deadline=None)
@given(
    n=st.integers(2, 16),
    split=st.integers(1, 15),
    group=st.integers(1, 3),
    hd=st.integers(1, 8),
    with_empty=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_lse_combine_hierarchical_associative(n, split, group, hd, with_empty,
                                              seed):
    """chunk-then-shard == flat: merging each sub-range first, then merging
    the merged partials, matches one flat merge (the distributed decode is
    exactly this two-level tree)."""
    split = min(split, n - 1)
    rng = np.random.default_rng(seed)
    m, l, o = _random_partials(rng, n, group, hd, with_empty)
    _, l_f, o_f = lse_combine(m, l, o, axis=0)
    m1, l1, o1 = lse_combine(m[:split], l[:split], o[:split], axis=0)
    m2, l2, o2 = lse_combine(m[split:], l[split:], o[split:], axis=0)
    _, l_h, o_h = lse_combine(jnp.stack([m1, m2]), jnp.stack([l1, l2]),
                              jnp.stack([o1, o2]), axis=0)
    np.testing.assert_allclose(_finalize(l_f, o_f), _finalize(l_h, o_h),
                               rtol=1e-5, atol=1e-6)
    # the combined (m, l) themselves agree, so any deeper tree nests too
    np.testing.assert_allclose(np.asarray(l_f), np.asarray(l_h),
                               rtol=1e-5, atol=1e-6)


@settings(max_examples=50, deadline=None)
@given(
    n_chunks=st.integers(1, 8),
    bk=st.integers(1, 16),
    group=st.integers(1, 3),
    hd=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_lse_combine_matches_dense_softmax(n_chunks, bk, group, hd, seed):
    """Partials built from chunks of one dense score matrix merge to the
    dense softmax-weighted value sum (log-sum-exp reference)."""
    rng = np.random.default_rng(seed)
    s = rng.normal(scale=2.0, size=(group, n_chunks * bk)).astype(np.float32)
    vals = rng.normal(size=(n_chunks * bk, hd)).astype(np.float32)

    ms, ls, os_ = [], [], []
    for c in range(n_chunks):
        sc = s[:, c * bk:(c + 1) * bk]
        m_c = sc.max(axis=1, keepdims=True)
        p = np.exp(sc - m_c)
        ms.append(m_c)
        ls.append(p.sum(axis=1, keepdims=True))
        os_.append(p @ vals[c * bk:(c + 1) * bk])
    m = jnp.asarray(np.stack(ms))
    l = jnp.asarray(np.stack(ls))
    o = jnp.asarray(np.stack(os_))

    _, l_c, o_c = lse_combine(m, l, o, axis=0)
    got = _finalize(l_c, o_c)

    probs = np.exp(s - s.max(axis=1, keepdims=True))
    probs /= probs.sum(axis=1, keepdims=True)
    want = probs @ vals
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
