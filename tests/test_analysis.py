"""Tests for repro.analysis (the static analyzer itself).

The known-bad corpus in ``tests/analysis_fixtures/`` carries its own
oracle: every line that must be flagged ends with ``# expect: rule`` (and
suppressed findings with ``# expect-suppressed: rule``).  The tests assert
the analyzer reports *exactly* that set — same file, same line, same rule —
so both false negatives and false positives fail.

Pure host tests: the analyzer imports no jax.
"""
import json
import os
import re
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import RepoFacts, analyze_file, analyze_paths, rule_catalog
from repro.analysis.core import suppressed_rules

REPO = Path(__file__).resolve().parent.parent
FIXTURES = REPO / "tests" / "analysis_fixtures"
FACTS = RepoFacts.discover([FIXTURES])

EXPECT_RE = re.compile(r"#\s*expect:\s*([\w\-, ]+)")
EXPECT_SUP_RE = re.compile(r"#\s*expect-suppressed:\s*([\w\-, ]+)")

BAD_FIXTURES = sorted(p.name for p in FIXTURES.glob("bad_*.py"))
CLEAN_FIXTURES = sorted(p.name for p in FIXTURES.glob("clean_*.py"))


def _expected(path: Path, regex) -> set:
    out = set()
    for lineno, text in enumerate(path.read_text().splitlines(), start=1):
        m = regex.search(text)
        if m:
            for rule in m.group(1).split(","):
                out.add((lineno, rule.strip()))
    return out


def test_corpus_is_nontrivial():
    # the issue requires >= 2 known-bad snippets per pass
    assert len(BAD_FIXTURES) >= 4 and len(CLEAN_FIXTURES) >= 4
    per_file = {
        name: _expected(FIXTURES / name, EXPECT_RE) for name in BAD_FIXTURES
    }
    assert all(len(v) >= 2 for v in per_file.values()), per_file


@pytest.mark.parametrize("name", BAD_FIXTURES)
def test_bad_fixture_flagged_at_expected_lines(name):
    path = FIXTURES / name
    active, suppressed = analyze_file(path, FACTS)
    got = {(f.line, f.rule) for f in active}
    assert got == _expected(path, EXPECT_RE)
    assert not suppressed


@pytest.mark.parametrize("name", CLEAN_FIXTURES)
def test_clean_fixture_has_zero_findings(name):
    active, suppressed = analyze_file(FIXTURES / name, FACTS)
    assert active == [] and suppressed == []


def test_suppression_fixture():
    path = FIXTURES / "suppressed.py"
    active, suppressed = analyze_file(path, FACTS)
    assert {(f.line, f.rule) for f in active} == _expected(path, EXPECT_RE)
    assert {(f.line, f.rule) for f in suppressed} == _expected(
        path, EXPECT_SUP_RE
    )


def test_suppression_comment_parsing():
    assert suppressed_rules("x = 1") is None
    assert suppressed_rules("x = 1  # repro: ignore") == {"*"}
    assert suppressed_rules("x  # repro: ignore[a-rule]") == {"a-rule"}
    assert suppressed_rules("x  # repro: ignore[a, b-c]") == {"a", "b-c"}
    assert suppressed_rules("x  # repro:ignore[a]") == {"a"}


def test_repo_facts_track_sharding_module():
    # the vocabulary must come from dist/sharding.py's rule tables, exactly
    assert FACTS.source and FACTS.source.endswith("dist/sharding.py")
    assert FACTS.logical_axes == frozenset(
        {"batch", "model", "seq", "residual_seq", "embed", "heads",
         "kv_heads", "ffn", "vocab", "expert", "kv_seq", "nodes"}
    )
    assert FACTS.mesh_axes == frozenset({"data", "model", "pod"})


def test_rule_catalog_covers_all_four_passes():
    rules = rule_catalog()
    prefixes = {r.split("-")[0] for r in rules}
    assert {"sharding", "pallas", "determinism", "jit"} <= prefixes
    assert all(desc for desc in rules.values())


def test_repo_tree_is_clean():
    # the acceptance invariant, pinned as a test: the analyzer exits clean
    # on the real tree (fixtures excluded by default)
    paths = [REPO / d for d in ("src", "tests", "benchmarks")
             if (REPO / d).exists()]
    report = analyze_paths(paths, facts=FACTS)
    assert report.findings == [] and report.errors == []
    assert report.n_files > 80


def _run_cli(*args, cwd=REPO):
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, env=env, cwd=cwd, timeout=120,
    )


def test_cli_exit_codes_and_output():
    bad = str(FIXTURES / "bad_determinism.py")
    r = _run_cli(bad)
    assert r.returncode == 1
    assert "bad_determinism.py:13: determinism-global-rng:" in r.stdout
    assert _run_cli(bad, "--exit-zero").returncode == 0
    assert _run_cli(str(FIXTURES / "clean_jit.py")).returncode == 0


def test_cli_json_report(tmp_path):
    out = tmp_path / "report.json"
    r = _run_cli(str(FIXTURES / "suppressed.py"), "--json", str(out))
    assert r.returncode == 1
    data = json.loads(out.read_text())
    assert {f["rule"] for f in data["findings"]} == {"determinism-global-rng"}
    assert len(data["suppressed"]) == 5
    assert set(data["rules"]) == set(rule_catalog())
    assert data["facts"]["mesh_axes"] == ["data", "model", "pod"]


def test_cli_list_rules():
    r = _run_cli("--list-rules")
    assert r.returncode == 0
    assert "sharding-silent-fallback:" in r.stdout
    assert "pallas-no-interpret:" in r.stdout


def test_parse_error_is_reported_not_fatal(tmp_path):
    bad = tmp_path / "broken.py"
    bad.write_text("def f(:\n")
    report = analyze_paths([bad], facts=FACTS)
    assert report.findings == []
    assert len(report.errors) == 1 and report.errors[0].rule == "parse-error"
