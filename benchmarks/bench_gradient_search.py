"""Paper Fig. 11/12 + Algorithm 1: gradient-based search vs exhaustive,
plus the engine before/after comparison (``BENCH_search.json``).

Verifies the convexity-exploiting walk finds (near-)optimal configs while
visiting a fraction of P(M+D+O), and measures the vectorized engine + CRN
rate-sweep speedup against the retained reference path (the pre-refactor
per-sub-query heapq loops).

CLI:
  (default)              gradient vs exhaustive CSV rows (fast engine)
  --smoke                CI perf-smoke subset under a wall-clock budget
  --compare-reference    fast vs reference engine -> BENCH_search.json
"""
from __future__ import annotations

import argparse
import json
import pathlib
import platform

from benchmarks.common import emit, query_sizes, timer
from repro.configs.paper_models import paper_profile
from repro.core.devices import SERVER_TYPES
from repro.core.gradient_search import BATCH_GRID, _mk_sched, gradient_search
from repro.core.partition import enumerate_placements
from repro.serving.simulator import SimCache, max_sustainable_qps

REPO = pathlib.Path(__file__).resolve().parents[1]
CASES = (("dlrm-rmc1", "T2"), ("dlrm-rmc3", "T7"))
O_GRID = (1, 2, 4)


def exhaustive(prof, dev, sizes, o_grid=O_GRID, engine="fast"):
    best = 0.0
    evals = 0
    cache = SimCache(sizes, 0)
    for pl in enumerate_placements(prof, dev):
        grid = o_grid if pl.plan.startswith("cpu") else (1,)
        for o in grid:
            m_max = dev.cpu.cores if pl.plan.startswith("cpu") else (
                dev.accel.max_colocate if dev.accel else 1)
            for m in range(1, m_max + 1):
                for d in BATCH_GRID:
                    sched = _mk_sched(pl.plan, dev, m, d, o)
                    if sched is None:
                        continue
                    qps, _ = max_sustainable_qps(pl, dev, sched, prof.sla_ms,
                                                 sizes, cache=cache,
                                                 engine=engine)
                    evals += 1
                    best = max(best, qps)
    return best, evals


def run(smoke: bool = False):
    sizes = query_sizes(300)
    cases = CASES[:1] if smoke else CASES
    for model, server in cases:
        prof = paper_profile(model)
        dev = SERVER_TYPES[server]
        with timer() as t:
            res = gradient_search(prof, dev, sizes, o_grid=O_GRID)
        with timer() as t_ex:
            best, ex_evals = exhaustive(prof, dev, sizes)
        gap = res.qps / max(best, 1e-9)
        emit(f"alg1_{model}_{server}", t.us,
             f"gradient={res.qps:.0f};exhaustive={best:.0f};"
             f"optimality={gap:.1%};evals={res.evals}/{ex_evals};"
             f"search_speedup={t_ex.us/max(t.us,1):.1f}x")


def compare_reference(out: str = "BENCH_search.json"):
    """Fast vs reference engine, end to end, same host/process: wall time,
    per-config qps agreement, and argmax identity per (workload, server)."""
    sizes = query_sizes(300)
    rows = []
    for model, server in CASES:
        prof = paper_profile(model)
        dev = SERVER_TYPES[server]
        with timer() as t_ref:
            r_ref = gradient_search(prof, dev, sizes, o_grid=O_GRID,
                                    engine="reference")
        with timer() as t_fast:
            r_fast = gradient_search(prof, dev, sizes, o_grid=O_GRID,
                                     engine="fast")
        key = lambda r: (r.placement.plan, r.sched.m, r.sched.batch, r.sched.o)
        rows.append({
            "workload": model,
            "server": server,
            "reference_s": t_ref.us / 1e6,
            "fast_s": t_fast.us / 1e6,
            "speedup": t_ref.us / max(t_fast.us, 1),
            "qps_reference": r_ref.qps,
            "qps_fast": r_fast.qps,
            "qps_rel_err": abs(r_fast.qps - r_ref.qps) / max(r_ref.qps, 1e-9),
            "argmax_reference": key(r_ref),
            "argmax_fast": key(r_fast),
            "same_argmax": key(r_ref) == key(r_fast),
            "evals": r_fast.evals,
        })
        print(f"{model}/{server}: reference {rows[-1]['reference_s']:.1f}s -> "
              f"fast {rows[-1]['fast_s']:.1f}s "
              f"({rows[-1]['speedup']:.1f}x, qps_rel_err "
              f"{rows[-1]['qps_rel_err']:.2e}, same_argmax "
              f"{rows[-1]['same_argmax']})", flush=True)
    total_ref = sum(r["reference_s"] for r in rows)
    total_fast = sum(r["fast_s"] for r in rows)
    blob = {
        "benchmark": "gradient_search end-to-end (o_grid=(1,2,4), 300 sizes)",
        "host": platform.processor() or platform.machine(),
        "cases": rows,
        "total_reference_s": total_ref,
        "total_fast_s": total_fast,
        "total_speedup": total_ref / max(total_fast, 1e-9),
    }
    path = REPO / out
    path.write_text(json.dumps(blob, indent=1))
    print(f"total: {total_ref:.1f}s -> {total_fast:.1f}s "
          f"({blob['total_speedup']:.1f}x) -> {path}")
    return blob


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI perf smoke: first case only, fast engine")
    ap.add_argument("--compare-reference", action="store_true",
                    help="measure fast vs reference engine -> BENCH_search.json")
    args = ap.parse_args()
    if args.compare_reference:
        compare_reference()
    else:
        print("name,us_per_call,derived")
        run(smoke=args.smoke)


if __name__ == "__main__":
    main()
