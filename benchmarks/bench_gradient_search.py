"""Paper Fig. 11/12 + Algorithm 1: gradient-based search vs exhaustive.

Verifies the convexity-exploiting walk finds (near-)optimal configs while
visiting a fraction of P(M+D+O)."""
from __future__ import annotations

from benchmarks.common import emit, query_sizes, timer
from repro.configs.paper_models import paper_profile
from repro.core.devices import SERVER_TYPES
from repro.core.gradient_search import BATCH_GRID, _mk_sched, gradient_search
from repro.core.partition import enumerate_placements
from repro.serving.simulator import max_sustainable_qps


def exhaustive(prof, dev, sizes, o_grid=(1, 2, 4)):
    best = 0.0
    evals = 0
    for pl in enumerate_placements(prof, dev):
        grid = o_grid if pl.plan.startswith("cpu") else (1,)
        for o in grid:
            m_max = dev.cpu.cores if pl.plan.startswith("cpu") else (
                dev.accel.max_colocate if dev.accel else 1)
            for m in range(1, m_max + 1):
                for d in BATCH_GRID:
                    sched = _mk_sched(pl.plan, dev, m, d, o)
                    if sched is None:
                        continue
                    qps, _ = max_sustainable_qps(pl, dev, sched, prof.sla_ms,
                                                 sizes)
                    evals += 1
                    best = max(best, qps)
    return best, evals


def run():
    sizes = query_sizes(300)
    for model, server in [("dlrm-rmc1", "T2"), ("dlrm-rmc3", "T7")]:
        prof = paper_profile(model)
        dev = SERVER_TYPES[server]
        with timer() as t:
            res = gradient_search(prof, dev, sizes, o_grid=(1, 2, 4))
        with timer() as t_ex:
            best, ex_evals = exhaustive(prof, dev, sizes)
        gap = res.qps / max(best, 1e-9)
        emit(f"alg1_{model}_{server}", t.us,
             f"gradient={res.qps:.0f};exhaustive={best:.0f};"
             f"optimality={gap:.1%};evals={res.evals}/{ex_evals};"
             f"search_speedup={t_ex.us/max(t.us,1):.1f}x")


if __name__ == "__main__":
    run()
