"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Two corrections on top of the raw dry-run numbers:

1. **Scan trip count**: XLA's cost_analysis counts a while-loop body ONCE;
   the LM cells scan over layers, so raw FLOPs under-count by ~L. The fix
   lowers the same cell at n_layers=1 and n_layers=2 on the same mesh:
   body = c(2) - c(1), outside = c(1) - body, total = outside + L * body.
   Exact for uniform layers. (Collective bytes parsed from the HLO text
   have the same once-per-body property and get the same correction.)

2. **MODEL_FLOPS**: the analytic useful compute — 6·N·D (train) /
   2·N_active·tokens (+ KV attention reads) for LM; per-item operator
   profiles for recsys/GNN — compared against corrected HLO FLOPs x chips
   to expose remat/redundancy waste.

Run (needs the 512-device flag, hence a fresh process):
    PYTHONPATH=src python -m benchmarks.roofline [--mesh single]
Writes artifacts/roofline/<mesh>.json + artifacts/roofline/table_<mesh>.md.
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import pathlib  # noqa: E402

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts"

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def _cost_of(arch_id, shape_name, mesh_kind, cfg_override=None):
    from repro.launch.dryrun import collective_bytes
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_cell

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    cell = build_cell(arch_id, shape_name, mesh=mesh,
                      multi_pod=(mesh_kind == "multi"),
                      cfg_override=cfg_override)
    compiled = cell.lower().compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll = collective_bytes(compiled.as_text())
    coll_total = sum(v for k, v in coll.items() if not k.endswith("_count"))
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(coll_total),
    }


def corrected_costs(arch_id, shape_name, mesh_kind):
    """Scan-corrected per-device costs for one cell."""
    from repro.common.types import ArchKind
    from repro.configs.registry import get_arch

    arch = get_arch(arch_id)
    raw = json.loads(
        (ART / "dryrun" / f"{arch_id}__{shape_name}__{mesh_kind}.json").read_text()
    )
    base = {
        "flops": raw["flops_per_device"],
        "bytes": raw["bytes_per_device"],
        "coll": raw["collective_bytes_per_device"],
    }
    if arch.KIND not in (ArchKind.LM_DENSE, ArchKind.LM_MOE):
        return base, raw  # no scan: raw numbers are already exact

    L = arch.FULL.n_layers
    c1 = _cost_of(arch_id, shape_name, mesh_kind,
                  dataclasses.replace(arch.FULL, n_layers=1, unroll_layers=True))
    c2 = _cost_of(arch_id, shape_name, mesh_kind,
                  dataclasses.replace(arch.FULL, n_layers=2, unroll_layers=True))
    corrected = {}
    for k in ("flops", "bytes", "coll"):
        body = max(c2[k] - c1[k], 0.0)
        outside = max(c1[k] - body, 0.0)
        corrected[k] = outside + L * body
    return corrected, raw


def model_flops(arch_id, shape_name) -> float:
    """Analytic useful FLOPs for the whole cell (all chips)."""
    from repro.common.types import ArchKind
    from repro.configs.registry import get_arch
    from repro.core.workload import profile_gnn, profile_recsys

    arch = get_arch(arch_id)
    shape = next(s for s in arch.SHAPES if s.name == shape_name)
    if arch.KIND in (ArchKind.LM_DENSE, ArchKind.LM_MOE):
        cfg = arch.FULL
        n_active = cfg.active_param_count()
        S, B = shape["seq_len"], shape["global_batch"]
        L, kvh, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
        if shape.step == "train":
            tokens = S * B
            attn = 12 * L * cfg.n_heads * hd * S * tokens / 2  # fwd+bwd QK/AV
            return 6.0 * n_active * tokens + attn
        if shape.step == "prefill":
            tokens = S * B
            attn = 4 * L * cfg.n_heads * hd * S * tokens / 2
            return 2.0 * n_active * tokens + attn
        # decode: one token per sequence against an S-entry cache
        tokens = B
        attn = 4 * L * cfg.n_heads * hd * S * tokens
        return 2.0 * n_active * tokens + attn
    if arch.KIND == ArchKind.RECSYS:
        prof = profile_recsys(arch.FULL, sla_ms=50.0)
        per_item = prof.totals()["flops"]
        items = shape.get("n_candidates") or shape["batch"]
        mult = 3.0 if shape.step == "train" else 1.0
        return per_item * items * mult
    # GNN
    cfgs = arch.SHAPE_CONFIGS[shape_name]
    d = dict(shape.dims)
    if cfgs.mode == "full":
        n, e = d["n_nodes"], d["n_edges"]
        f = 2.0 * e * cfgs.d_feat + 2.0 * 2.0 * n * cfgs.d_feat * cfgs.d_hidden
        f += 2.0 * e * cfgs.d_hidden + 2.0 * 2.0 * n * cfgs.d_hidden * cfgs.d_hidden
        f += 2.0 * n * cfgs.d_hidden * cfgs.n_classes
        return 3.0 * f
    prof = profile_gnn(cfgs, sla_ms=50.0, d_feat=cfgs.d_feat)
    items = d.get("batch_nodes") or d.get("batch", 1)
    return 3.0 * prof.totals()["flops"] * items


def analyse(mesh_kind: str, cells=None) -> list[dict]:
    from repro.configs.registry import get_arch, list_archs

    rows = []
    if cells is None:
        cells = [(a, s.name) for a in list_archs() for s in get_arch(a).SHAPES]
    for arch_id, shape_name in cells:
        cor, raw = corrected_costs(arch_id, shape_name, mesh_kind)
        n_dev = raw["n_devices"]
        t_c = cor["flops"] / PEAK_FLOPS
        t_m = cor["bytes"] / HBM_BW
        t_x = cor["coll"] / ICI_BW
        terms = {"compute": t_c, "memory": t_m, "collective": t_x}
        bottleneck = max(terms, key=terms.get)
        mf = model_flops(arch_id, shape_name)
        useful = mf / max(cor["flops"] * n_dev, 1e-9)
        t_total = max(t_c, t_m, t_x)
        rows.append({
            "arch": arch_id, "shape": shape_name, "mesh": mesh_kind,
            "n_devices": n_dev,
            "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
            "bottleneck": bottleneck,
            "model_flops": mf,
            "hlo_flops_total": cor["flops"] * n_dev,
            "useful_ratio": useful,
            # roofline fraction: useful compute time / bound step time
            "roofline_fraction": (mf / n_dev / PEAK_FLOPS) / max(t_total, 1e-12),
            "corrected": cor,
        })
        print(f"{arch_id:18s} {shape_name:14s} "
              f"C={t_c*1e3:9.3f}ms M={t_m*1e3:9.3f}ms X={t_x*1e3:9.3f}ms "
              f"-> {bottleneck:10s} useful={useful:6.1%} "
              f"roofline={rows[-1]['roofline_fraction']:6.1%}", flush=True)
    return rows


def write_table(rows, mesh_kind):
    out = ART / "roofline"
    out.mkdir(parents=True, exist_ok=True)
    (out / f"{mesh_kind}.json").write_text(json.dumps(rows, indent=1))
    lines = [
        "| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
        "bottleneck | MODEL/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']*1e3:.3f} | "
            f"{r['t_memory_s']*1e3:.3f} | {r['t_collective_s']*1e3:.3f} | "
            f"{r['bottleneck']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.1%} |"
        )
    (out / f"table_{mesh_kind}.md").write_text("\n".join(lines) + "\n")
    print(f"wrote {out}/table_{mesh_kind}.md")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    args = ap.parse_args()
    cells = [(args.arch, args.shape)] if args.arch else None
    rows = analyse(args.mesh, cells)
    if cells is None:
        write_table(rows, args.mesh)


if __name__ == "__main__":
    main()
