"""Paper Fig. 6/7: accelerator co-location + query fusion vs
DeepRecSys/Baymax, with the latency/utilization breakdown."""
from __future__ import annotations

from benchmarks.common import emit, query_sizes, timer
from repro.configs.paper_models import paper_profile
from repro.core.baselines import baymax_qps, deeprecsys_qps
from repro.core.devices import SERVER_TYPES
from repro.core.gradient_search import gradient_search
from repro.serving.simulator import max_sustainable_qps, simulate


def run():
    sizes = query_sizes()
    dev = SERVER_TYPES["T7"]
    for model in ("dlrm-rmc3", "mt-wnd", "din"):
        prof = paper_profile(model)
        with timer() as t:
            q_drs, s_drs, pl_drs = deeprecsys_qps(prof, dev, sizes)
            q_bay, s_bay, pl_bay = baymax_qps(prof, dev, sizes)
            res = gradient_search(prof, dev, sizes)
        emit(f"fig6_{model}_T7", t.us,
             f"deeprecsys={q_drs:.0f};baymax={q_bay:.0f};"
             f"hercules={res.qps:.0f};"
             f"colo_gain={q_bay/max(q_drs,1):.2f}x;"
             f"fusion_gain={res.qps/max(q_bay,1):.2f}x")
        # Fig 7: breakdown at 70% of hercules load on the baseline config
        if s_drs is not None:
            r = simulate(pl_drs, dev, s_drs, max(q_drs, 1.0) * 0.7, sizes)
            emit(f"fig7_breakdown_{model}", 0.0,
                 f"link_util={r.utils['link']:.2f};"
                 f"engine_util={r.utils['engine']:.2f};p95={r.p95_ms:.1f}ms")


if __name__ == "__main__":
    run()
