"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. The dry-run/roofline numbers
(EXPERIMENTS.md §Dry-run/§Roofline) come from ``repro.launch.dryrun`` and
``benchmarks.roofline`` which need a fresh 512-device process each; this
aggregator summarizes their cached artifacts instead of re-lowering.
"""
from __future__ import annotations

import json
import pathlib

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts"


def _summarize_artifacts() -> None:
    dd = ART / "dryrun"
    if dd.exists():
        cells = sorted(dd.glob("*.json"))
        ok = len(cells)
        per_mesh = {}
        for c in cells:
            mesh = c.stem.split("__")[-1]
            per_mesh[mesh] = per_mesh.get(mesh, 0) + 1
        print(f"dryrun_cells,0.00,compiled={ok};" +
              ";".join(f"{k}={v}" for k, v in sorted(per_mesh.items())))
    for tag, fname in (("baseline", "baseline_single.json"),
                       ("optimized", "single.json")):
        p = ART / "roofline" / fname
        if p.exists():
            rows = json.loads(p.read_text())
            worst = min(rows, key=lambda r: r["roofline_fraction"])
            by_bn = {}
            for r in rows:
                by_bn[r["bottleneck"]] = by_bn.get(r["bottleneck"], 0) + 1
            print(f"roofline_{tag},0.00,cells={len(rows)};" +
                  ";".join(f"{k}={v}" for k, v in sorted(by_bn.items())) +
                  f";worst={worst['arch']}/{worst['shape']}")


def main() -> None:
    print("name,us_per_call,derived")
    _summarize_artifacts()

    from benchmarks import (
        bench_accel_scheduling,
        bench_cluster,
        bench_gradient_search,
        bench_host_scheduling,
        bench_kernels,
        bench_server_explore,
        bench_task_scheduler,
    )

    bench_kernels.run()
    bench_host_scheduling.run()
    bench_accel_scheduling.run()
    bench_gradient_search.run()
    bench_server_explore.run()
    bench_task_scheduler.run()
    bench_cluster.run()


if __name__ == "__main__":
    main()
