"""Kernel micro-benchmarks: reference-path wall time on this host (the
Pallas kernels target TPU; interpret-mode timing is not meaningful, so the
CSV reports the jnp oracle throughput used for simulator calibration)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels.embedding_bag.ref import hot_embedding_bag_ref
from repro.kernels.flash_attention.ref import attention_ref


def _time(fn, *args, n=5):
    warm = fn(*args)  # single warmup call (compile), reused for the sync
    if isinstance(warm, tuple):
        warm[0].block_until_ready()
    else:
        jax.block_until_ready(warm)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def run():
    key = jax.random.PRNGKey(0)
    table = jax.random.normal(key, (100_000, 64))
    ids = jax.random.randint(key, (2048, 32), -1, 100_000)
    f = jax.jit(hot_embedding_bag_ref)
    us = _time(f, table, ids)
    gb = 2048 * 32 * 64 * 4 / 1e9
    emit("kernel_embedding_bag_ref", us, f"gather_GBps={gb/(us*1e-6):.1f}")

    q = jax.random.normal(key, (1, 1024, 8, 64), jnp.float32)
    k = jax.random.normal(key, (1, 1024, 2, 64), jnp.float32)
    v = jax.random.normal(key, (1, 1024, 2, 64), jnp.float32)
    f2 = jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True))
    us = _time(f2, q, k, v)
    fl = 4 * 1024 * 1024 * 8 * 64 / 2
    emit("kernel_attention_ref", us, f"GFLOPs={fl/(us*1e-6)/1e9:.1f}")


if __name__ == "__main__":
    run()
