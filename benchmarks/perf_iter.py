"""Hillclimb driver: measure one cell's roofline terms with config overrides.

    PYTHONPATH=src python -m benchmarks.perf_iter --arch llama3.2-3b \
        --shape train_4k --set attn_impl=chunked --set attn_chunk=512

Prints the three scan-corrected roofline terms, to be recorded as one
hypothesis->change->before/after entry in EXPERIMENTS.md §Perf.
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import dataclasses  # noqa: E402

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg field override key=value")
    args = ap.parse_args()

    from benchmarks.roofline import _cost_of
    from repro.common.types import ArchKind
    from repro.configs.registry import get_arch

    arch = get_arch(args.arch)
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        cur = getattr(arch.FULL, k)
        if isinstance(cur, bool):
            v = v in ("1", "true", "True")
        elif isinstance(cur, int):
            v = int(v)
        elif isinstance(cur, float):
            v = float(v)
        overrides[k] = v
    cfg = dataclasses.replace(arch.FULL, **overrides) if overrides else arch.FULL

    if arch.KIND in (ArchKind.LM_DENSE, ArchKind.LM_MOE):
        L = cfg.n_layers
        c1 = _cost_of(args.arch, args.shape, args.mesh,
                      dataclasses.replace(cfg, n_layers=1, unroll_layers=True))
        c2 = _cost_of(args.arch, args.shape, args.mesh,
                      dataclasses.replace(cfg, n_layers=2, unroll_layers=True))
        cor = {}
        for k in ("flops", "bytes", "coll"):
            body = max(c2[k] - c1[k], 0.0)
            cor[k] = max(c1[k] - body, 0.0) + L * body
    else:
        cor = _cost_of(args.arch, args.shape, args.mesh, cfg if overrides else None)

    t_c = cor["flops"] / PEAK_FLOPS
    t_m = cor["bytes"] / HBM_BW
    t_x = cor["coll"] / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    print(f"{args.arch} x {args.shape} overrides={overrides}")
    print(f"  compute    {t_c*1e3:10.3f} ms")
    print(f"  memory     {t_m*1e3:10.3f} ms")
    print(f"  collective {t_x*1e3:10.3f} ms")
    print(f"  bottleneck {max(terms, key=terms.get)}")


if __name__ == "__main__":
    main()
