"""Paper Fig. 4/5: host-side (m x o) trade-off + op-parallelism idle cycles."""
from __future__ import annotations

from benchmarks.common import emit, query_sizes, timer
from repro.configs.paper_models import paper_profile
from repro.core.devices import SERVER_TYPES
from repro.core.partition import enumerate_placements
from repro.core.perfmodel import cpu_stage_time
from repro.serving.simulator import SchedConfig, max_sustainable_qps


def run():
    sizes = query_sizes()
    prof = paper_profile("dlrm-rmc1")
    dev = SERVER_TYPES["T2"]
    pl = enumerate_placements(prof, dev)[0]
    base = None
    for m, o in [(20, 1), (10, 2), (5, 4), (4, 5)]:
        with timer() as t:
            qps, res = max_sustainable_qps(
                pl, dev, SchedConfig(batch=64, m=m, o=o), prof.sla_ms, sizes)
        if base is None:
            base = qps
        emit(f"fig4_rmc1_T2_{m}x{o}", t.us,
             f"qps={qps:.0f};vs20x1={qps/base:.2f}x;"
             f"power={res.avg_power_w if res else 0:.0f}W")

    # Fig 5c: idle-cycle growth with op-parallel workers (list-scheduling
    # bound on the dependency levels; idle = 1 - work/(elapsed*workers))
    for model in ("dlrm-rmc1", "dlrm-rmc3", "din"):
        p = paper_profile(model)
        t1 = cpu_stage_time(p.ops, 256, 1, dev, active_threads=1)
        for w in (2, 3, 4):
            tw = cpu_stage_time(p.ops, 256, w, dev, active_threads=1)
            idle = max(0.0, 1.0 - t1 / (tw * w))
            emit(f"fig5_idle_{model}_w{w}", tw * 1e6, f"idle={idle:.0%}")


if __name__ == "__main__":
    run()
