"""Diagnostic: top collectives per cell — the §Perf profiling tool.

    PYTHONPATH=src python -m benchmarks.collectives --arch llama3.2-3b \
        --shape train_4k [--layers 2]
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import collections  # noqa: E402
import dataclasses  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--layers", type=int, default=0,
                    help="unrolled layer override for LM cells")
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args()

    from repro.common.types import ArchKind
    from repro.configs.registry import get_arch
    from repro.launch.dryrun import _COLL_RE, _shape_bytes
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_cell

    arch = get_arch(args.arch)
    override = None
    if args.layers and arch.KIND in (ArchKind.LM_DENSE, ArchKind.LM_MOE):
        override = dataclasses.replace(arch.FULL, n_layers=args.layers,
                                       unroll_layers=True)
    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    cell = build_cell(args.arch, args.shape, mesh=mesh,
                      multi_pod=(args.mesh == "multi"), cfg_override=override)
    hlo = cell.lower().compile().as_text()
    agg = collections.Counter()
    cnt = collections.Counter()
    for m in _COLL_RE.finditer(hlo):
        b = _shape_bytes(m.group(1))
        key = (m.group(2), m.group(1)[:70])
        agg[key] += b
        cnt[key] += 1
    total = sum(agg.values())
    print(f"total result-bytes {total:.3e} across "
          f"{sum(cnt.values())} collective ops")
    for (kind, shape), b in agg.most_common(args.top):
        print(f"{kind:20s} n={cnt[(kind, shape)]:3d} bytes={b:.3e}  {shape}")


if __name__ == "__main__":
    main()
