"""Shared benchmark helpers: query sizes, CSV emission, timing."""
from __future__ import annotations

import time

import numpy as np


def query_sizes(n: int = 500, seed: int = 0) -> np.ndarray:
    """Paper Fig. 2b distribution."""
    r = np.random.default_rng(seed)
    return np.clip(r.lognormal(np.log(64), 1.1, n).astype(np.int64), 1, 1024)


def emit(name: str, us_per_call: float, derived: str = ""):
    """CSV row contract for benchmarks.run: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.2f},{derived}")


class timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.us = (time.perf_counter() - self.t0) * 1e6
