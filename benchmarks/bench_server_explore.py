"""Paper Fig. 15: 6 workloads x 10+1 server types — QPS and QPS-per-Watt
classification table (reads the cached offline-profiling artifact)."""
from __future__ import annotations

from benchmarks.common import emit, timer
from repro.configs.paper_models import PAPER_MODELS, paper_profile
from repro.core.efficiency import build_table


def run():
    profiles = {name: paper_profile(name) for name in PAPER_MODELS}
    with timer() as t:
        table, records = build_table(profiles)
    emit("fig15_table_build", t.us, f"pairs={len(records)}")
    for j, w in enumerate(table.workloads):
        best_qps = table.servers[int(table.qps[:, j].argmax())]
        eff = table.qps[:, j] / table.power[:, j]
        best_eff = table.servers[int(eff.argmax())]
        emit(f"fig15_{w}", 0.0,
             f"best_qps={best_qps};best_qps_per_watt={best_eff};"
             f"qps_range={table.qps[:, j].min():.0f}-{table.qps[:, j].max():.0f}")
    # paper claims: NMP best for memory-bound DLRMs, GPU for compute-bound
    for w, expect in [("dlrm-rmc1", ("T3", "T4", "T5", "T8", "T9", "T10")),
                      ("mt-wnd", ("T6", "T7", "T8", "T9", "T10", "T11-v5e"))]:
        j = table.workloads.index(w)
        eff = table.qps[:, j] / table.power[:, j]
        best = table.servers[int(eff.argmax())]
        emit(f"fig15_check_{w}", 0.0,
             f"best={best};matches_paper_class={best in expect}")


if __name__ == "__main__":
    run()
