"""Paper Fig. 14: Hercules vs baseline (DeepRecSys CPU / Baymax accel)
latency-bounded throughput for the six models across server types."""
from __future__ import annotations

from benchmarks.common import emit, query_sizes, timer
from repro.configs.paper_models import PAPER_MODELS, paper_profile
from repro.core.baselines import baymax_qps, deeprecsys_qps
from repro.core.devices import SERVER_TYPES
from repro.core.gradient_search import gradient_search

SERVERS = ("T2", "T3", "T7")


def run():
    sizes = query_sizes(400)
    for model in PAPER_MODELS:
        prof = paper_profile(model)
        for server in SERVERS:
            dev = SERVER_TYPES[server]
            with timer() as t:
                # baselines hit the persistent profile cache across runs;
                # the hercules search is timed live (fast engine)
                if dev.has_accel:
                    q_base, _, _ = baymax_qps(prof, dev, sizes, use_cache=True)
                    base_name = "baymax"
                else:
                    q_base, _, _ = deeprecsys_qps(prof, dev, sizes,
                                                  use_cache=True)
                    base_name = "deeprecsys"
                res = gradient_search(prof, dev, sizes, o_grid=(1, 2, 5))
            emit(f"fig14_{model}_{server}", t.us,
                 f"baseline({base_name})={q_base:.0f};hercules={res.qps:.0f};"
                 f"speedup={res.qps/max(q_base,1):.2f}x;plan={res.placement.plan}")


if __name__ == "__main__":
    run()
