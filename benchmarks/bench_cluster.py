"""Paper Fig. 8/16/17: cluster provisioning — NH vs greedy vs Hercules over
the diurnal day, plus the model-evolution study and the continuous-time
query-granular runtime validation (``BENCH_cluster.json``).

The provisioning comparison alone trusts the efficiency table's QPS column;
the validation section re-serves the same day through
``repro.serving.cluster_runtime`` (stateful provisioning, transition
delays, hysteresis, routed Poisson query streams, per-slot backlog carried
across intervals, live-queue hedging) and records *achieved* per-workload
p99 / SLA attainment — day-level and per interval (the paper's Fig. 8b
reports SLA *over the day*, not an aggregate) — next to the provisioned
power and capacity of every policy.

CLI:
  (default)   full table (6 workloads x 11 servers, 96 intervals)
              -> BENCH_cluster.json
  --smoke     reduced table (2 workloads x 3 servers, 24 intervals)
              -> BENCH_cluster_smoke.json; the CI bench-gate compares it
              against benchmarks/baselines/BENCH_cluster_smoke.json
  --out PATH  override the output path
"""
from __future__ import annotations

import argparse
import json
import pathlib

import numpy as np

from benchmarks.common import emit, timer
from repro.configs.paper_models import PAPER_MODELS, paper_profile
from repro.core.cluster import EfficiencyTable, TransitionConfig, provision_day
from repro.core.devices import SERVER_TYPES
from repro.core.efficiency import build_table
from repro.serving.cluster_runtime import failure_schedule, simulate_cluster_day
from repro.serving.diurnal import diurnal_trace, load_increment_rate

ROOT = pathlib.Path(__file__).resolve().parents[1]

# Peak load per workload = 9% of its fleet-wide best-case capacity (the
# highest point where the heterogeneity-oblivious baseline is still
# feasible, so all three policies are comparable).
COMPARISON_FRAC = 0.09

# The reduced bench-gate configuration (matches examples/cluster_day.py
# --smoke and the tests' `small_cluster` fixture, so the profile cache is
# shared across all three).
SMOKE_WORKLOADS = ("dlrm-rmc1", "dlrm-rmc3")
SMOKE_SERVERS = ("T2", "T3", "T7")
SMOKE_AVAIL = {"T2": 70, "T3": 15, "T7": 5}
SMOKE_STEPS = 24


def _scaled_loads(table: EfficiencyTable, frac: float, seeds,
                  n_steps: int = 96) -> np.ndarray:
    """Diurnal traces scaled so the aggregate is provisionable."""
    cap = (table.avail[:, None] * table.qps).sum(axis=0)
    M = len(table.workloads)
    return np.stack([
        diurnal_trace(frac * cap[m], seed=seeds[m], n_steps=n_steps)
        for m in range(M)
    ])


def run(smoke: bool = False, out: str | None = None):
    if smoke:
        profiles = {n: paper_profile(n) for n in SMOKE_WORKLOADS}
        servers = {s: SERVER_TYPES[s] for s in SMOKE_SERVERS}
        table, records = build_table(profiles, servers, SMOKE_AVAIL)
        n_steps = SMOKE_STEPS
        out = out or "BENCH_cluster_smoke.json"
    else:
        profiles = {name: paper_profile(name) for name in PAPER_MODELS}
        servers = None
        table, records = build_table(profiles)
        n_steps = 96
        out = out or "BENCH_cluster.json"

    traces = _scaled_loads(table, COMPARISON_FRAC,
                           seeds=list(range(len(table.workloads))),
                           n_steps=n_steps)
    R = max(load_increment_rate(t) for t in traces)

    # Fig 17: provisioning-only snapshot (trusts the QPS column).
    results = {}
    for pol in ("nh", "greedy", "hercules"):
        with timer() as t:
            results[pol] = provision_day(table, traces, policy=pol,
                                         overprovision=R)
        r = results[pol]
        emit(f"fig17_{pol}", t.us,
             f"peak_power={r['peak_power_w']/1e3:.1f}kW;"
             f"avg_power={r['avg_power_w']/1e3:.1f}kW;"
             f"peak_cap={r['peak_capacity']};feasible={r['feasible']}")
    g, h, n = results["greedy"], results["hercules"], results["nh"]
    emit("fig17_savings", 0.0,
         f"hercules_vs_greedy_power_peak={1-h['peak_power_w']/g['peak_power_w']:.1%};"
         f"hercules_vs_greedy_cap_peak={1-h['peak_capacity']/max(g['peak_capacity'],1):.1%};"
         f"greedy_vs_nh_power_peak={1-g['peak_power_w']/n['peak_power_w']:.1%}")

    # Query-granular validation: serve the same day through the
    # continuous-time cluster runtime (stateful provisioning + routed
    # Poisson streams + backlog carry-over) and check the savings hold with
    # every workload actually meeting its SLA — in aggregate and interval
    # by interval (the Fig. 8b analogue).
    transitions = TransitionConfig()
    bench = {
        "comparison_frac": COMPARISON_FRAC,
        "overprovision": float(R),
        "n_steps": int(traces.shape[1]),
        "smoke": bool(smoke),
        "transitions": {
            "interval_s": transitions.interval_s,
            "model_load_s": transitions.model_load_s,
            "drain_s": transitions.drain_s,
            "hysteresis": transitions.hysteresis,
            "feedback_boost": transitions.feedback_boost,
        },
        "policies": {},
    }
    runtime = {}
    for pol in ("nh", "greedy", "hercules"):
        with timer() as t:
            runtime[pol] = simulate_cluster_day(
                table, records, profiles, traces, policy=pol,
                servers=servers, overprovision=R, transitions=transitions)
        r = runtime[pol]
        bench["policies"][pol] = {
            k: r[k] for k in (
                "peak_power_w", "avg_power_w", "peak_capacity",
                "avg_capacity", "feasible", "all_meet_sla", "resolves",
                "holds", "tail_resolves", "total_churn", "workloads")
        }
        # the SLA-over-the-day record (per-interval attainment/tail series
        # under backlog carry-over) — the query-granular Fig. 8b
        bench["policies"][pol]["sla_over_day"] = {
            name: {
                "sla_attainment": s["sla_attainment"],
                "meets_sla": s["meets_sla"],
                "p99_ms": s["p99_ms"],
                "backlog_s": s["backlog_s"],
            }
            for name, s in r["series"]["per_workload"].items()
        }
        worst = min(w["sla_attainment"] for w in r["workloads"].values())
        worst_frac = min(w["interval_sla_met_frac"]
                         for w in r["workloads"].values())
        emit(f"runtime_{pol}", t.us,
             f"peak_power={r['peak_power_w']/1e3:.1f}kW;"
             f"all_meet_sla={r['all_meet_sla']};"
             f"min_attainment={worst:.4f};"
             f"min_interval_sla_frac={worst_frac:.4f};"
             f"resolves={r['resolves']};holds={r['holds']};"
             f"churn={r['total_churn']}")
    gh, hh = runtime["greedy"], runtime["hercules"]
    saving = 1 - hh["peak_power_w"] / gh["peak_power_w"]
    all_intervals_met = all(
        all(v for v in s["meets_sla"] if v is not None)
        for s in hh["series"]["per_workload"].values())
    validated = bool(
        hh["feasible"] and hh["all_meet_sla"] and gh["all_meet_sla"]
        and hh["peak_power_w"] < gh["peak_power_w"])
    bench["savings"] = {
        "hercules_vs_greedy_power_peak": float(saving),
        "hercules_vs_greedy_cap_peak":
            float(1 - hh["peak_capacity"] / max(gh["peak_capacity"], 1)),
        "validated_at_query_granularity": validated,
        "hercules_all_intervals_meet_sla": bool(all_intervals_met),
    }
    emit("runtime_savings", 0.0,
         f"hercules_vs_greedy_power_peak={saving:.1%};validated={validated};"
         f"all_intervals_met={all_intervals_met}")

    # Fault tolerance: the same day with mid-day machine failures — the
    # runtime re-routes in-window, carries the disruption's backlog into
    # the following intervals, and the provisioner re-solves elastically
    # (with achieved-tail feedback when the carried backlog bites).
    fails = failure_schedule(traces.shape[1], len(table.servers),
                             fail_prob=0.01, seed=7)
    with timer() as t:
        rf = simulate_cluster_day(
            table, records, profiles, traces, policy="hercules",
            servers=servers, overprovision=R, transitions=transitions,
            failures=fails)
    bench["hercules_with_failures"] = {
        "n_failures": len(fails),
        "feasible": rf["feasible"],
        "all_meet_sla": rf["all_meet_sla"],
        "n_retried": int(sum(w["n_retried"] for w in rf["workloads"].values())),
        "tail_resolves": rf["tail_resolves"],
        "events": rf["events"],
        "peak_power_w": rf["peak_power_w"],
    }
    emit("runtime_hercules_failures", t.us,
         f"n_failures={len(fails)};feasible={rf['feasible']};"
         f"all_meet_sla={rf['all_meet_sla']};"
         f"retried={bench['hercules_with_failures']['n_retried']};"
         f"tail_resolves={rf['tail_resolves']}")

    out_path = pathlib.Path(out)
    if not out_path.is_absolute():
        out_path = ROOT / out_path
    out_path.write_text(json.dumps(bench, indent=1))
    emit("bench_cluster_json", 0.0, str(out_path))

    if smoke:
        return bench

    # Beyond-paper: maximum sustainable peak-load fraction per policy —
    # the LP keeps the fleet feasible well past the greedy collapse point.
    for pol in ("nh", "greedy", "hercules"):
        lo = 0.0
        for frac in (0.06, 0.09, 0.12, 0.15, 0.18, 0.22, 0.26):
            tr = _scaled_loads(table, frac, seeds=list(range(6)))
            r = provision_day(table, tr, policy=pol,
                              overprovision=max(load_increment_rate(t) for t in tr))
            if r["feasible"]:
                lo = frac
        emit(f"fig17_max_load_{pol}", 0.0, f"max_feasible_frac={lo:.2f}")

    # Fig 16: model evolution — traffic shifts from DLRMs to DIN/DIEN/WnD
    old = [table.workloads.index(w) for w in ("dlrm-rmc1", "dlrm-rmc2", "dlrm-rmc3")]
    new = [table.workloads.index(w) for w in ("din", "dien", "mt-wnd")]
    for shift in (0.0, 0.2, 0.5, 1.0):
        tr = traces.copy()
        moved = tr[old] * shift
        tr[old] -= moved
        tr[new] += moved
        r = provision_day(table, tr, policy="hercules", overprovision=R)
        emit(f"fig16_evolution_shift{int(shift*100)}", 0.0,
             f"peak_power={r['peak_power_w']/1e3:.1f}kW;"
             f"avg_cap={r['avg_capacity']:.0f};feasible={r['feasible']}")
    return bench


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced table + short day -> BENCH_cluster_smoke"
                         ".json (CI bench-gate input)")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default depends on --smoke)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke, out=args.out)


if __name__ == "__main__":
    main()
