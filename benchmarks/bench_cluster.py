"""Paper Fig. 8/16/17: cluster provisioning — NH vs greedy vs Hercules over
the diurnal day, plus the model-evolution study."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timer
from repro.configs.paper_models import PAPER_MODELS, paper_profile
from repro.core.cluster import EfficiencyTable, provision_day
from repro.core.efficiency import build_table
from repro.serving.diurnal import diurnal_trace, load_increment_rate


def _scaled_loads(table: EfficiencyTable, frac: float, seeds) -> np.ndarray:
    """Diurnal traces scaled so the aggregate is provisionable."""
    cap = (table.avail[:, None] * table.qps).sum(axis=0)
    M = len(table.workloads)
    return np.stack([
        diurnal_trace(frac * cap[m] / M * M / M if False else frac * cap[m],
                      seed=seeds[m], n_steps=96)
        for m in range(M)
    ])


def run():
    profiles = {name: paper_profile(name) for name in PAPER_MODELS}
    table, _ = build_table(profiles)

    # Fig 17: accelerated cluster, all six workloads, one-day snapshot.
    # Peak load per workload = 9% of its fleet-wide best-case capacity
    # (the highest point where the heterogeneity-oblivious baseline is
    # still feasible, so all three policies are comparable).
    traces = _scaled_loads(table, 0.09, seeds=list(range(6)))
    R = max(load_increment_rate(t) for t in traces)
    results = {}
    for pol in ("nh", "greedy", "hercules"):
        with timer() as t:
            results[pol] = provision_day(table, traces, policy=pol,
                                         overprovision=R)
        r = results[pol]
        emit(f"fig17_{pol}", t.us,
             f"peak_power={r['peak_power_w']/1e3:.1f}kW;"
             f"avg_power={r['avg_power_w']/1e3:.1f}kW;"
             f"peak_cap={r['peak_capacity']};feasible={r['feasible']}")
    g, h, n = results["greedy"], results["hercules"], results["nh"]
    emit("fig17_savings", 0.0,
         f"hercules_vs_greedy_power_peak={1-h['peak_power_w']/g['peak_power_w']:.1%};"
         f"hercules_vs_greedy_cap_peak={1-h['peak_capacity']/max(g['peak_capacity'],1):.1%};"
         f"greedy_vs_nh_power_peak={1-g['peak_power_w']/n['peak_power_w']:.1%}")

    # Beyond-paper: maximum sustainable peak-load fraction per policy —
    # the LP keeps the fleet feasible well past the greedy collapse point.
    for pol in ("nh", "greedy", "hercules"):
        lo = 0.0
        for frac in (0.06, 0.09, 0.12, 0.15, 0.18, 0.22, 0.26):
            tr = _scaled_loads(table, frac, seeds=list(range(6)))
            r = provision_day(table, tr, policy=pol,
                              overprovision=max(load_increment_rate(t) for t in tr))
            if r["feasible"]:
                lo = frac
        emit(f"fig17_max_load_{pol}", 0.0, f"max_feasible_frac={lo:.2f}")

    # Fig 16: model evolution — traffic shifts from DLRMs to DIN/DIEN/WnD
    old = [table.workloads.index(w) for w in ("dlrm-rmc1", "dlrm-rmc2", "dlrm-rmc3")]
    new = [table.workloads.index(w) for w in ("din", "dien", "mt-wnd")]
    for shift in (0.0, 0.2, 0.5, 1.0):
        tr = traces.copy()
        moved = tr[old] * shift
        tr[old] -= moved
        tr[new] += moved
        r = provision_day(table, tr, policy="hercules", overprovision=R)
        emit(f"fig16_evolution_shift{int(shift*100)}", 0.0,
             f"peak_power={r['peak_power_w']/1e3:.1f}kW;"
             f"avg_cap={r['avg_capacity']:.0f};feasible={r['feasible']}")


if __name__ == "__main__":
    run()
