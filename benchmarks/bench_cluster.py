"""Paper Fig. 8/16/17: cluster provisioning — NH vs greedy vs Hercules over
the diurnal day, plus the model-evolution study and the continuous-time
query-granular runtime validation (``BENCH_cluster.json``).

The provisioning comparison alone trusts the efficiency table's QPS column;
the validation section re-serves the same day through
``repro.serving.cluster_runtime`` (stateful provisioning, transition
delays, hysteresis, routed Poisson query streams, per-slot backlog carried
across intervals, live-queue hedging) and records *achieved* per-workload
p99 / SLA attainment — day-level and per interval (the paper's Fig. 8b
reports SLA *over the day*, not an aggregate) — next to the provisioned
power and capacity of every policy.

CLI:
  (default)   full table (6 workloads x 11 servers, 96 intervals)
              -> BENCH_cluster.json
  --smoke     reduced table (2 workloads x 3 servers, 24 intervals)
              -> BENCH_cluster_smoke.json; the CI bench-gate compares it
              against benchmarks/baselines/BENCH_cluster_smoke.json
  --out PATH  override the output path
"""
from __future__ import annotations

import argparse
import json
import pathlib

import numpy as np

import time

import dataclasses

from benchmarks.common import emit, timer
from repro.core.cluster import provision_day
from repro.serving import engine, event_core
from repro.serving.cluster_runtime import simulate_cluster_day
from repro.serving.scenarios import (
    COMPARISON_FRAC,
    EVENT_TYPES,
    WorkloadSpec,
    compile_scenario,
    full_scale,
    get_scenario,
    registry,
)

ROOT = pathlib.Path(__file__).resolve().parents[1]


def bench_event_kernel(n_jobs: int = 100_000, seed: int = 0) -> dict:
    """Event-core kernels vs the sequential scalar sweep at n = 1e5 jobs.

    Two records, each bitwise-checked against ``engine._sweep`` before
    timing counts (a fast wrong kernel must never produce a bench row):

    - ``saturated``: one k-server stream under sustained overload with
      near-constant service times — the regime of every overloaded
      bisection probe, where the blocked kernel's round-robin
      speculation replaces the heap sweep with two ``np.add.accumulate``
      passes.  This is the >= 5x headline the CI gate pins.
    - ``fleet``: 512 independent slot streams (k-homogeneous groups,
      k in {2,4,8,16} — one pool config's slots share k) through one
      ``fleet_fifo_finish`` call vs one sweep per stream.  End-to-end,
      including the per-call padding/packing and host<->XLA copies the
      runtime also pays; the jit compile (first call) is excluded —
      steady state is what every interval after the first costs."""
    rng = np.random.default_rng(seed)
    sweep = engine._sweep

    # -- saturated blocked kernel -------------------------------------
    k = 8
    r_sat = rng.exponential(1.0, n_jobs).cumsum()
    d_sat = np.full(n_jobs, 1.5 * k)        # util 1.5: sustained overload
    blocked = event_core.blocked_fifo_finish
    assert np.array_equal(blocked(r_sat, d_sat, k), sweep(r_sat, d_sat, k))
    sat_kernel_s, sat_sweep_s = _timed_pair(
        lambda: blocked(r_sat, d_sat, k), lambda: sweep(r_sat, d_sat, k))
    sat = {
        "n_jobs": int(n_jobs),
        "k": k,
        "kernel_s": float(sat_kernel_s),
        "sweep_s": float(sat_sweep_s),
        "speedup": float(sat_sweep_s / sat_kernel_s),
    }
    emit("event_core_saturated", sat_kernel_s * 1e6,
         f"speedup={sat['speedup']:.1f}x;jobs={n_jobs};k={k};"
         f"ns_per_job={sat_kernel_s / n_jobs * 1e9:.0f}")

    # -- fleet solver --------------------------------------------------
    ks = [2, 4, 8, 16]
    n_streams = 512
    per = 2 * n_jobs // n_streams
    streams = []
    for i in range(n_streams):
        kk = ks[i % len(ks)]
        n = int(per * rng.uniform(0.8, 1.2))
        r = rng.exponential(1.0, n).cumsum() * (1.0 / (1.1 * kk))
        d = rng.choice(rng.uniform(0.5, 1.5, 6), n)
        streams.append((r, d, kk, rng.uniform(0.0, 2.0, kk)))
    jobs = sum(len(s[0]) for s in streams)
    fleet = event_core.fleet_fifo_finish
    for (r, d, kk, f0), (e, st) in zip(streams, fleet(streams)):  # + warm
        ref_e, ref_s = sweep(r, d, kk, free0=f0, return_state=True)
        assert np.array_equal(e, ref_e) and np.array_equal(st, ref_s)
    fl_kernel_s, fl_sweep_s = _timed_pair(
        lambda: fleet(streams),
        lambda: [sweep(r, d, kk, free0=f0, return_state=True)
                 for r, d, kk, f0 in streams])
    fl = {
        "n_streams": n_streams,
        "n_jobs": int(jobs),
        "ks": ks,
        "kernel_s": float(fl_kernel_s),
        "sweep_s": float(fl_sweep_s),
        "speedup": float(fl_sweep_s / fl_kernel_s),
        "jax": bool(event_core.stats["fleet_jax"] > 0),
    }
    emit("event_core_fleet", fl_kernel_s * 1e6,
         f"speedup={fl['speedup']:.1f}x;jobs={jobs};"
         f"streams={n_streams};jax={fl['jax']};"
         f"ns_per_job={fl_kernel_s / jobs * 1e9:.0f}")
    return {"saturated": sat, "fleet": fl}


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _timed_pair(fn_a, fn_b, reps: int = 5) -> tuple[float, float]:
    """Best-of-``reps`` for two contenders, interleaved A/B so transient
    machine load hits both sides alike and the *ratio* stays stable."""
    best_a = best_b = float("inf")
    for _ in range(reps):
        best_a = min(best_a, _timed(fn_a))
        best_b = min(best_b, _timed(fn_b))
    return best_a, best_b


def run(smoke: bool = False, out: str | None = None):
    # The whole day is declared, not wired: the registered `baseline_day`
    # scenario IS the bench-gate configuration (2 workloads x 3 servers,
    # 24 intervals), and full_scale() lifts it to the paper zoo (all 6
    # workloads x 11 server types, 96 intervals).
    if smoke:
        day = get_scenario("baseline_day")
        out = out or "BENCH_cluster_smoke.json"
    else:
        day = full_scale(get_scenario("baseline_day"), n_steps=96)
        out = out or "BENCH_cluster.json"

    comp = compile_scenario(day)
    table, traces, R = comp.table, comp.traces, comp.overprovision

    # Fig 17: provisioning-only snapshot (trusts the QPS column).
    results = {}
    for pol in ("nh", "greedy", "hercules"):
        with timer() as t:
            results[pol] = provision_day(table, traces, policy=pol,
                                         overprovision=R)
        r = results[pol]
        emit(f"fig17_{pol}", t.us,
             f"peak_power={r['peak_power_w']/1e3:.1f}kW;"
             f"avg_power={r['avg_power_w']/1e3:.1f}kW;"
             f"peak_cap={r['peak_capacity']};feasible={r['feasible']}")
    g, h, n = results["greedy"], results["hercules"], results["nh"]
    emit("fig17_savings", 0.0,
         f"hercules_vs_greedy_power_peak={1-h['peak_power_w']/g['peak_power_w']:.1%};"
         f"hercules_vs_greedy_cap_peak={1-h['peak_capacity']/max(g['peak_capacity'],1):.1%};"
         f"greedy_vs_nh_power_peak={1-g['peak_power_w']/n['peak_power_w']:.1%}")

    # Query-granular validation: serve the same day through the
    # continuous-time cluster runtime (stateful provisioning + routed
    # Poisson streams + backlog carry-over) and check the savings hold with
    # every workload actually meeting its SLA — in aggregate and interval
    # by interval (the Fig. 8b analogue).
    transitions = comp.transitions
    bench = {
        "comparison_frac": COMPARISON_FRAC,
        "overprovision": float(R),
        "n_steps": int(traces.shape[1]),
        "smoke": bool(smoke),
        "transitions": {
            "interval_s": transitions.interval_s,
            "model_load_s": transitions.model_load_s,
            "drain_s": transitions.drain_s,
            "hysteresis": transitions.hysteresis,
            "feedback_boost": transitions.feedback_boost,
        },
        "policies": {},
        # the registered scenario zoo: check_bench.py pins these names, so
        # silently dropping a scenario from the registry fails the gate
        "scenarios": {
            "registered": list(registry()),
            "event_kinds": sorted(EVENT_TYPES),
            "descriptions": {n: get_scenario(n).description
                             for n in registry()},
        },
    }
    runtime = {}
    for pol in ("nh", "greedy", "hercules"):
        engine.stats_reset()
        with timer() as t:
            runtime[pol] = comp.run(policy=pol)
        r = runtime[pol]
        rd = r.to_dict()
        bench["policies"][pol] = {
            k: rd[k] for k in (
                "peak_power_w", "avg_power_w", "peak_capacity",
                "avg_capacity", "feasible", "all_meet_sla", "resolves",
                "holds", "tail_resolves", "total_churn", "workloads")
        }
        # the SLA-over-the-day record (per-interval attainment/tail series
        # under backlog carry-over) — the query-granular Fig. 8b
        bench["policies"][pol]["sla_over_day"] = {
            name: {
                "sla_attainment": s["sla_attainment"],
                "meets_sla": s["meets_sla"],
                "p99_ms": s["p99_ms"],
                "backlog_s": s["backlog_s"],
            }
            for name, s in r.series["per_workload"].items()
        }
        worst = min(w["sla_attainment"] for w in r.per_workload.values())
        worst_frac = min(w["interval_sla_met_frac"]
                         for w in r.per_workload.values())
        # per-bench engine path mix (which FIFO solver served the day)
        mix = "/".join(f"{k}:{v}" for k, v in engine.stats.items() if v)
        bench["policies"][pol]["engine_path_mix"] = {
            k: v for k, v in engine.stats.items() if v}
        emit(f"runtime_{pol}", t.us,
             f"peak_power={r.peak_power_w/1e3:.1f}kW;"
             f"all_meet_sla={r.all_meet_sla};"
             f"min_attainment={worst:.4f};"
             f"min_interval_sla_frac={worst_frac:.4f};"
             f"resolves={r.resolves};holds={r.holds};"
             f"churn={r.total_churn};mix={mix}")
    gh, hh = runtime["greedy"], runtime["hercules"]
    saving = 1 - hh.peak_power_w / gh.peak_power_w
    all_intervals_met = all(
        all(v for v in s["meets_sla"] if v is not None)
        for s in hh.series["per_workload"].values())
    validated = bool(
        hh.feasible and hh.all_meet_sla and gh.all_meet_sla
        and hh.peak_power_w < gh.peak_power_w)
    bench["savings"] = {
        "hercules_vs_greedy_power_peak": float(saving),
        "hercules_vs_greedy_cap_peak":
            float(1 - hh.peak_capacity / max(gh.peak_capacity, 1)),
        "validated_at_query_granularity": validated,
        "hercules_all_intervals_meet_sla": bool(all_intervals_met),
    }
    emit("runtime_savings", 0.0,
         f"hercules_vs_greedy_power_peak={saving:.1%};validated={validated};"
         f"all_intervals_met={all_intervals_met}")

    # Fault tolerance: the registered `failure_day` scenario — the same
    # day plus a seeded failure schedule; the runtime re-routes in-window,
    # carries the disruption's backlog into the following intervals, and
    # the provisioner re-solves elastically (with achieved-tail feedback
    # when the carried backlog bites).
    fday = get_scenario("failure_day") if smoke \
        else full_scale(get_scenario("failure_day"), n_steps=96)
    comp_f = compile_scenario(fday)
    with timer() as t:
        rf = comp_f.run()
    bench["hercules_with_failures"] = {
        "n_failures": len(comp_f.failures),
        "feasible": rf.feasible,
        "all_meet_sla": rf.all_meet_sla,
        "n_retried": int(sum(w["n_retried"]
                             for w in rf.per_workload.values())),
        "tail_resolves": rf.tail_resolves,
        "events": rf.events,
        "peak_power_w": rf.peak_power_w,
    }
    emit("runtime_hercules_failures", t.us,
         f"n_failures={len(comp_f.failures)};feasible={rf.feasible};"
         f"all_meet_sla={rf.all_meet_sla};"
         f"retried={bench['hercules_with_failures']['n_retried']};"
         f"tail_resolves={rf.tail_resolves}")

    # Event-ordered core: the fleet kernel micro-bench (the >= 5x gate)
    # and the hercules day re-served through the batched event core —
    # every interval simulated query by query up to event_core_queries
    # (vs the default 1500-query bridged window), hedges admitted in
    # global event order.  The exact day's tail vs the bridged day's tail
    # is the record the docs quote.
    engine.stats_reset()
    bench["event_core"] = {"kernels": bench_event_kernel()}
    cap = 20_000 if smoke else 200_000
    engine.stats_reset()
    comp_e = compile_scenario(dataclasses.replace(
        day, runtime={"event_core": True, "event_core_queries": cap}))
    with timer() as t:
        re_ = comp_e.run()
    mix = {k: v for k, v in event_core.stats.items() if v}
    day = {
        "event_core_queries": cap,
        "feasible": re_.feasible,
        "all_meet_sla": re_.all_meet_sla,
        "peak_power_w": re_.peak_power_w,
        "wall_s": t.us / 1e6,
        "path_mix": mix,
        "workloads": {},
    }
    total_exact = 0
    for name, w in re_.per_workload.items():
        wb = runtime["hercules"].per_workload[name]
        se = re_.series["per_workload"][name]
        day["workloads"][name] = {
            "n_queries": w["n_queries"],
            "n_queries_bridged_run": wb["n_queries"],
            "p99_ms_exact": w["p99_ms"],
            "p99_ms_bridged": wb["p99_ms"],
            "n_hedged": w["n_hedged"],
            "intervals_still_capped": int(sum(se["bridged"])),
        }
        total_exact += w["n_queries"]
    bench["event_core"]["day"] = day
    emit("runtime_hercules_event", t.us,
         f"feasible={re_.feasible};all_meet_sla={re_.all_meet_sla};"
         f"queries={total_exact};cap_per_interval={cap};"
         f"fleet_jobs={mix.get('fleet_jobs', 0)};"
         f"peak_power={re_.peak_power_w/1e3:.1f}kW")

    # Geo: the registered 3-region day served twice from one compile —
    # follow-the-sun (phase-shifted peaks + capacity/RTT-aware spill, each
    # region re-provisioned against its *post-spill* load) vs the
    # per-region-isolated Hercules baseline.  SLA is judged at the origin:
    # every spilled query carries its link RTT.  check_bench.py pins the
    # global-peak-power win with every origin meeting SLA every interval.
    comp_g = compile_scenario(get_scenario("geo_3region"))
    with timer() as t:
        rg_fs = comp_g.run(mode="follow_sun")
    wall_fs = t.us / 1e6
    with timer() as t:
        rg_iso = comp_g.run(mode="isolated")
    geo_win = 1.0 - rg_fs.peak_power_w / rg_iso.peak_power_w
    bench["geo_day"] = {
        "scenario": "geo_3region",
        "regions": list(rg_fs.region_names),
        "follow_sun": rg_fs.to_dict(),
        "isolated": rg_iso.to_dict(),
        "follow_sun_vs_isolated_power_peak": float(geo_win),
        "wall_s": float(wall_fs + t.us / 1e6),
    }
    emit("runtime_geo_follow_sun", wall_fs * 1e6,
         f"peak_power={rg_fs.peak_power_w/1e3:.1f}kW;"
         f"win_vs_isolated={geo_win:.1%};"
         f"all_meet_sla={rg_fs.all_meet_sla};"
         f"all_intervals={rg_fs.all_intervals_meet_sla};"
         f"spilled={rg_fs.n_spilled};"
         f"spill_qps_mean={rg_fs.spilled_qps_mean:.0f}")
    emit("runtime_geo_isolated", t.us,
         f"peak_power={rg_iso.peak_power_w/1e3:.1f}kW;"
         f"all_meet_sla={rg_iso.all_meet_sla};"
         f"lost_qps_mean={rg_iso.lost_qps_mean:.0f}")

    # Co-location: the registered recsys+LM day served twice from one
    # compile — interference-aware shared machines (repro.core.colocation)
    # vs the single-tenant Hercules packing of the same inputs.
    # check_bench.py's check_colo pins the peak-provisioned-power win with
    # every tenant meeting its SLA in every measured interval.
    comp_c = compile_scenario(get_scenario("colo_recsys_lm"))
    with timer() as t:
        rc = comp_c.run()
    wall_c = t.us / 1e6
    solo = dataclasses.replace(comp_c.inputs, colocation=None)
    with timer() as t:
        rs = simulate_cluster_day(solo, policy=comp_c.spec.policy,
                                  config=comp_c.config)
    colo_win = 1.0 - rc.peak_power_w / rs.peak_power_w

    def _day_summary(r):
        return {
            "feasible": r.feasible,
            "all_meet_sla": r.all_meet_sla,
            "peak_power_w": r.peak_power_w,
            "avg_power_w": r.avg_power_w,
            "peak_capacity": r.peak_capacity,
            "total_churn": r.total_churn,
            "per_workload": r.per_workload,
        }

    bench["colo_day"] = {
        "scenario": "colo_recsys_lm",
        "colocated": _day_summary(rc),
        "single_tenant": _day_summary(rs),
        "co_capacity": [int(c) for c in rc.co_capacity],
        "colocated_vs_single_power_peak": float(colo_win),
        "wall_s": float(wall_c + t.us / 1e6),
    }
    emit("runtime_colo_day", wall_c * 1e6,
         f"peak_power={rc.peak_power_w/1e3:.2f}kW;"
         f"win_vs_single_tenant={colo_win:.1%};"
         f"all_meet_sla={rc.all_meet_sla};"
         f"shared_machine_intervals={int((rc.co_capacity > 0).sum())}")
    emit("runtime_colo_single_tenant", t.us,
         f"peak_power={rs.peak_power_w/1e3:.2f}kW;"
         f"all_meet_sla={rs.all_meet_sla}")

    out_path = pathlib.Path(out)
    if not out_path.is_absolute():
        out_path = ROOT / out_path
    out_path.write_text(json.dumps(bench, indent=1))
    emit("bench_cluster_json", 0.0, str(out_path))

    if smoke:
        return bench

    # Beyond-paper: maximum sustainable peak-load fraction per policy —
    # the LP keeps the fleet feasible well past the greedy collapse point.
    # Each probe is the full-zoo baseline day re-declared at a different
    # load fraction (the bundle/table is compiled once and memoized).
    for pol in ("nh", "greedy", "hercules"):
        lo = 0.0
        for frac in (0.06, 0.09, 0.12, 0.15, 0.18, 0.22, 0.26):
            probe = compile_scenario(dataclasses.replace(
                day, workloads=tuple(
                    dataclasses.replace(w, load_frac=frac)
                    for w in day.workloads)))
            r = provision_day(table, probe.traces, policy=pol,
                              overprovision=probe.overprovision)
            if r["feasible"]:
                lo = frac
        emit(f"fig17_max_load_{pol}", 0.0, f"max_feasible_frac={lo:.2f}")

    # Fig 16: model evolution — traffic shifts from DLRMs to DIN/DIEN/WnD
    old = [table.workloads.index(w) for w in ("dlrm-rmc1", "dlrm-rmc2", "dlrm-rmc3")]
    new = [table.workloads.index(w) for w in ("din", "dien", "mt-wnd")]
    for shift in (0.0, 0.2, 0.5, 1.0):
        tr = traces.copy()
        moved = tr[old] * shift
        tr[old] -= moved
        tr[new] += moved
        r = provision_day(table, tr, policy="hercules", overprovision=R)
        emit(f"fig16_evolution_shift{int(shift*100)}", 0.0,
             f"peak_power={r['peak_power_w']/1e3:.1f}kW;"
             f"avg_cap={r['avg_capacity']:.0f};feasible={r['feasible']}")
    return bench


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced table + short day -> BENCH_cluster_smoke"
                         ".json (CI bench-gate input)")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default depends on --smoke)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke, out=args.out)


if __name__ == "__main__":
    main()
