"""Paper Fig. 8/16/17: cluster provisioning — NH vs greedy vs Hercules over
the diurnal day, plus the model-evolution study and the query-granular
runtime validation (``BENCH_cluster.json``).

The provisioning comparison alone trusts the efficiency table's QPS column;
the validation section re-serves the same day through
``repro.serving.cluster_runtime`` (stateful provisioning, transition
delays, hysteresis, routed Poisson query streams) and records *achieved*
per-workload p99 / SLA attainment next to the provisioned power and
capacity of every policy — the paper's savings claims at query granularity.
"""
from __future__ import annotations

import json
import pathlib

import numpy as np

from benchmarks.common import emit, timer
from repro.configs.paper_models import PAPER_MODELS, paper_profile
from repro.core.cluster import EfficiencyTable, TransitionConfig, provision_day
from repro.core.efficiency import build_table
from repro.serving.cluster_runtime import failure_schedule, simulate_cluster_day
from repro.serving.diurnal import diurnal_trace, load_increment_rate

ROOT = pathlib.Path(__file__).resolve().parents[1]

# Peak load per workload = 9% of its fleet-wide best-case capacity (the
# highest point where the heterogeneity-oblivious baseline is still
# feasible, so all three policies are comparable).
COMPARISON_FRAC = 0.09


def _scaled_loads(table: EfficiencyTable, frac: float, seeds) -> np.ndarray:
    """Diurnal traces scaled so the aggregate is provisionable."""
    cap = (table.avail[:, None] * table.qps).sum(axis=0)
    M = len(table.workloads)
    return np.stack([
        diurnal_trace(frac * cap[m], seed=seeds[m], n_steps=96)
        for m in range(M)
    ])


def run():
    profiles = {name: paper_profile(name) for name in PAPER_MODELS}
    table, records = build_table(profiles)

    # Fig 17: accelerated cluster, all six workloads, one-day snapshot.
    traces = _scaled_loads(table, COMPARISON_FRAC, seeds=list(range(6)))
    R = max(load_increment_rate(t) for t in traces)
    results = {}
    for pol in ("nh", "greedy", "hercules"):
        with timer() as t:
            results[pol] = provision_day(table, traces, policy=pol,
                                         overprovision=R)
        r = results[pol]
        emit(f"fig17_{pol}", t.us,
             f"peak_power={r['peak_power_w']/1e3:.1f}kW;"
             f"avg_power={r['avg_power_w']/1e3:.1f}kW;"
             f"peak_cap={r['peak_capacity']};feasible={r['feasible']}")
    g, h, n = results["greedy"], results["hercules"], results["nh"]
    emit("fig17_savings", 0.0,
         f"hercules_vs_greedy_power_peak={1-h['peak_power_w']/g['peak_power_w']:.1%};"
         f"hercules_vs_greedy_cap_peak={1-h['peak_capacity']/max(g['peak_capacity'],1):.1%};"
         f"greedy_vs_nh_power_peak={1-g['peak_power_w']/n['peak_power_w']:.1%}")

    # Query-granular validation: serve the same day through the cluster
    # runtime (stateful provisioning + routed Poisson streams) and check the
    # savings hold with every workload actually meeting its SLA.
    transitions = TransitionConfig()
    bench = {
        "comparison_frac": COMPARISON_FRAC,
        "overprovision": float(R),
        "n_steps": int(traces.shape[1]),
        "transitions": {
            "interval_s": transitions.interval_s,
            "model_load_s": transitions.model_load_s,
            "drain_s": transitions.drain_s,
            "hysteresis": transitions.hysteresis,
        },
        "policies": {},
    }
    runtime = {}
    for pol in ("nh", "greedy", "hercules"):
        with timer() as t:
            runtime[pol] = simulate_cluster_day(
                table, records, profiles, traces, policy=pol,
                overprovision=R, transitions=transitions)
        r = runtime[pol]
        bench["policies"][pol] = {
            k: r[k] for k in (
                "peak_power_w", "avg_power_w", "peak_capacity",
                "avg_capacity", "feasible", "all_meet_sla", "resolves",
                "holds", "total_churn", "workloads")
        }
        worst = min(w["sla_attainment"] for w in r["workloads"].values())
        emit(f"runtime_{pol}", t.us,
             f"peak_power={r['peak_power_w']/1e3:.1f}kW;"
             f"all_meet_sla={r['all_meet_sla']};"
             f"min_attainment={worst:.4f};"
             f"resolves={r['resolves']};holds={r['holds']};"
             f"churn={r['total_churn']}")
    gh, hh = runtime["greedy"], runtime["hercules"]
    saving = 1 - hh["peak_power_w"] / gh["peak_power_w"]
    validated = bool(
        hh["feasible"] and hh["all_meet_sla"] and gh["all_meet_sla"]
        and hh["peak_power_w"] < gh["peak_power_w"])
    bench["savings"] = {
        "hercules_vs_greedy_power_peak": float(saving),
        "hercules_vs_greedy_cap_peak":
            float(1 - hh["peak_capacity"] / max(gh["peak_capacity"], 1)),
        "validated_at_query_granularity": validated,
    }
    emit("runtime_savings", 0.0,
         f"hercules_vs_greedy_power_peak={saving:.1%};validated={validated}")

    # Fault tolerance: the same day with mid-day machine failures — the
    # runtime re-routes in-window and the provisioner re-solves elastically.
    fails = failure_schedule(traces.shape[1], len(table.servers),
                             fail_prob=0.01, seed=7)
    with timer() as t:
        rf = simulate_cluster_day(
            table, records, profiles, traces, policy="hercules",
            overprovision=R, transitions=transitions, failures=fails)
    bench["hercules_with_failures"] = {
        "n_failures": len(fails),
        "feasible": rf["feasible"],
        "all_meet_sla": rf["all_meet_sla"],
        "n_retried": int(sum(w["n_retried"] for w in rf["workloads"].values())),
        "events": rf["events"],
        "peak_power_w": rf["peak_power_w"],
    }
    emit("runtime_hercules_failures", t.us,
         f"n_failures={len(fails)};feasible={rf['feasible']};"
         f"all_meet_sla={rf['all_meet_sla']};"
         f"retried={bench['hercules_with_failures']['n_retried']}")

    (ROOT / "BENCH_cluster.json").write_text(json.dumps(bench, indent=1))
    emit("bench_cluster_json", 0.0, str(ROOT / "BENCH_cluster.json"))

    # Beyond-paper: maximum sustainable peak-load fraction per policy —
    # the LP keeps the fleet feasible well past the greedy collapse point.
    for pol in ("nh", "greedy", "hercules"):
        lo = 0.0
        for frac in (0.06, 0.09, 0.12, 0.15, 0.18, 0.22, 0.26):
            tr = _scaled_loads(table, frac, seeds=list(range(6)))
            r = provision_day(table, tr, policy=pol,
                              overprovision=max(load_increment_rate(t) for t in tr))
            if r["feasible"]:
                lo = frac
        emit(f"fig17_max_load_{pol}", 0.0, f"max_feasible_frac={lo:.2f}")

    # Fig 16: model evolution — traffic shifts from DLRMs to DIN/DIEN/WnD
    old = [table.workloads.index(w) for w in ("dlrm-rmc1", "dlrm-rmc2", "dlrm-rmc3")]
    new = [table.workloads.index(w) for w in ("din", "dien", "mt-wnd")]
    for shift in (0.0, 0.2, 0.5, 1.0):
        tr = traces.copy()
        moved = tr[old] * shift
        tr[old] -= moved
        tr[new] += moved
        r = provision_day(table, tr, policy="hercules", overprovision=R)
        emit(f"fig16_evolution_shift{int(shift*100)}", 0.0,
             f"peak_power={r['peak_power_w']/1e3:.1f}kW;"
             f"avg_cap={r['avg_capacity']:.0f};feasible={r['feasible']}")


if __name__ == "__main__":
    run()
